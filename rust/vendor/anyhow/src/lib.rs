//! Vendored minimal subset of the `anyhow` API.
//!
//! The offline build environment carries no crates.io registry, so Lamina
//! ships the slice of `anyhow` it actually uses: an opaque string-backed
//! [`Error`], the [`Result`] alias, the [`anyhow!`]/[`bail!`] macros, and
//! the [`Context`] extension trait. Semantics match upstream closely enough
//! for this crate's usage: `?` converts any `std::error::Error` into
//! [`Error`], and `context`/`with_context` prefix the message.

use std::fmt;

/// Opaque error: a rendered message (the upstream version keeps the source
/// chain; this shim renders eagerly, which is all Lamina's callers need).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (chain formatting upstream) degrades to the plain message.
        write!(f, "{}", self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` —
// exactly like upstream — so this blanket `From` is coherent and `?` works
// on any std error type.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring upstream's `Context` trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(,)?) => { $crate::Error::msg(format!($fmt)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context() {
        let e: Error = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let e: Error = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");

        let r: std::result::Result<(), &str> = Err("inner");
        let c = r.context("outer").unwrap_err();
        assert_eq!(c.to_string(), "outer: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let c = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(c.to_string(), "outer 1: inner");

        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_returns() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn alternate_format_is_safe() {
        let e: Error = anyhow!("msg");
        assert_eq!(format!("{e:#}"), "msg");
        assert_eq!(format!("{e:?}"), "msg");
    }
}
