//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The real crate links `xla_extension` (the PJRT C++ runtime) and executes
//! AOT-lowered HLO on a CPU PJRT client. This build environment has neither
//! the native library nor the AOT artifacts, so this stub provides the exact
//! API surface `runtime::engine` compiles against and fails *cleanly* at
//! [`PjRtClient::cpu`] — the first runtime call on the PJRT path. Every test
//! and bench that needs PJRT already gates on `artifacts/manifest.json`
//! existing, so under CI the stub is never executed, only type-checked.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate).

use std::fmt;

/// Error type mirroring xla-rs's, rendered as a message.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: xla_extension (PJRT) is not available in this offline build; \
         install the real `xla` crate + runtime to execute AOT artifacts"
    )))
}

/// Element types the artifact loader distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host dtypes transferable to device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer args; returns per-device output lists.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal (tensor or tuple).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Array shape: dims + element type.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (stub: text parsing always fails).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("offline"));
    }
}
