//! Native block-table kernel vs the gather + reference path, at every KV
//! storage dtype.
//!
//! The native kernels (`kernels::paged_attn*`) read the paged arena in
//! place with a **one-pass online-softmax** recurrence; the oracle
//! (`kernels::reference`) consumes the arena's **gathered** dense K/V with
//! a plain two-pass softmax. The two re-associate the softmax sums (and
//! the unrolled `mul_add` dots re-associate products), so they are *not*
//! bit-identical; floating-point reassociation on O(1) inputs perturbs
//! results at the last few ulps.
//!
//! **Documented tolerance choice (per ISSUE 3):** we assert
//! `|native − reference| ≤ 1e-5 · max(1, |reference|)`. Inputs are PRNG
//! values in [-1, 1); normalised attention outputs are convex combinations
//! of them (O(1), so the bound is effectively absolute 1e-5 there), while
//! the *unnormalised* partial state `(A, S)` grows with the token count —
//! the `max(1, |·|)` factor keeps the bound meaningful at ~100 f32 ulps for
//! any magnitude. **This same bound holds at every `--kv-dtype`**, because
//! `gather` widens the *stored* codes — native and reference consume
//! bit-identical KV values whatever the storage format, so their
//! difference is pure reassociation, not quantization error.
//!
//! **Derived quantization bounds (ISSUE 4):** quantization error is
//! asserted separately, comparing a quantized-arena pipeline against an
//! f32-arena ground truth fed the same append stream. With inputs in
//! [-1, 1), `hd = 4` and softmax scale `1/√hd = 0.5`:
//!
//! * **f16** — per-element storage error `δ ≤ 2⁻¹¹ ≈ 4.9e-4` (RNE,
//!   relative to |x| < 1). Score error `|Δs| ≤ hd·δ·0.5 ≈ 9.8e-4`;
//!   softmax total-variation `Σ|Δw| ≤ 2·max|Δs|`; output error
//!   `≤ 2·9.8e-4·|v|max + δ ≈ 2.5e-3`. Asserted at `TOL_F16 = 4e-3`
//!   (~1.6× margin).
//! * **int8** — per-element error: a fresh write rounds within `scale/2 ≤
//!   3.9e-3`; each in-block requantization (a later token in the same
//!   `(block, head)` region raising the running max) adds ≤ `s_new/2`.
//!   The worst case is block_size-dependent — `(block_size/2)·maxabs/127`
//!   over a full chain of raises (see `kvcache::quant`) — and **these
//!   tests run at block_size ≤ 4** (quant-error cases pin bs = 4; the
//!   same-arena property sweeps bs ∈ {1, 4, 16} but its tolerance is the
//!   reassociation bound, not this one), so `δ ≤ 2·maxabs/127 ≈ 1.6e-2`.
//!   Same propagation: output error `≤ 2·(hd·δ·0.5) + δ ≈ 8e-2`. Asserted
//!   at `TOL_INT8 = 1e-1` (~1.25× margin over the bs=4 worst case;
//!   typical error is ~5× smaller since requant chains are rare and
//!   roundings are random-signed). A bs=16 quant-error test would need
//!   the bound rescaled to `8·maxabs/127`.
//!
//! The derived-bound comparisons use *normalised* outputs (full attention
//! and prefill), where the O(1) convex-combination argument applies; the
//! overlap path's quantized correctness is covered by the same-arena
//! property above. What IS asserted bit-exact: the native kernel against
//! itself across thread counts AND across the per-call-spawn vs
//! persistent-pool executors (row arithmetic is sequential per row, so
//! parallelism must not change a single bit).
//!
//! Sequences are randomised like `kv_paged.rs`: decode appends, prefill
//! chunks, retirement and slot reuse over random lens/buckets/block sizes.

use lamina::kernels::{
    combine_new_token, paged_attn, paged_attn_prev, paged_prefill, reference, Par,
};
use lamina::kvcache::{ArenaCfg, KvDtype, PagedKvArena, PAD_SLOT};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;
use lamina::util::threadpool::ScopedPool;

const LAYERS: usize = 2;
const KHS: usize = 2;
const G: usize = 2;
const HS: usize = KHS * G;
const HD: usize = 4;
const MAX_SEQ: usize = 64;
const SLOTS: usize = 5;
const LEN_CAP: usize = 40;
const TOL: f32 = 1e-5;
/// Derived f16 storage-error bound (see module docs).
const TOL_F16: f32 = 4e-3;
/// Derived int8 storage-error bound (see module docs).
const TOL_INT8: f32 = 1e-1;

fn rand_kv(rng: &mut Rng, rows: usize) -> HostTensor {
    let data: Vec<f32> = (0..rows * KHS * HD).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    HostTensor::f32(vec![rows, KHS, HD], data)
}

fn rand_q(rng: &mut Rng, rows: usize) -> HostTensor {
    let data: Vec<f32> = (0..rows * HS * HD).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    HostTensor::f32(vec![rows, HS, HD], data)
}

fn assert_close_at(got: &HostTensor, want: &HostTensor, tol: f32, tag: &str) {
    assert_eq!(got.shape(), want.shape(), "{tag}: shape");
    for (i, (a, b)) in got.as_f32().iter().zip(want.as_f32()).enumerate() {
        let bound = tol * b.abs().max(1.0);
        assert!(
            (a - b).abs() <= bound,
            "{tag}: elem {i} got {a} vs want {b} (|Δ| > {bound})"
        );
    }
}

fn assert_close(got: &HostTensor, want: &HostTensor, tag: &str) {
    assert_close_at(got, want, TOL, tag);
}

/// Compare native full attention against gather + two-pass reference for a
/// random wave, and assert executor bit-determinism (threads and pool).
fn check_attention(
    arena: &mut PagedKvArena,
    pool: &ScopedPool,
    lens: &[usize],
    rng: &mut Rng,
    tag: &str,
) {
    let bucket = rng.usize(1, SLOTS + 1);
    let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
    rng.shuffle(&mut slots);
    slots.truncate(bucket);
    let mut row_lens = vec![0i32; bucket];
    for (b, s) in slots.iter_mut().enumerate() {
        let have = lens[*s as usize];
        if have == 0 || rng.chance(0.15) {
            *s = PAD_SLOT;
            // pads carry lens1 = 1 on the real wire (leader lens 0 + 1)
            row_lens[b] = 1;
        } else {
            // attend a random valid prefix (usually everything cached)
            row_lens[b] = if rng.chance(0.7) { have } else { rng.usize(1, have + 1) } as i32;
        }
    }
    let seq_bucket = [16usize, 32, 64][rng.usize(0, 3)];
    let layer = rng.usize(0, LAYERS);
    let q = rand_q(rng, bucket);

    let native = paged_attn(arena, &slots, layer, &q, &row_lens, seq_bucket, Par::Threads(1));
    let native_mt = paged_attn(arena, &slots, layer, &q, &row_lens, seq_bucket, Par::Threads(4));
    assert_eq!(
        native.as_f32(),
        native_mt.as_f32(),
        "{tag}: thread count changed bits"
    );
    let native_pool = paged_attn(arena, &slots, layer, &q, &row_lens, seq_bucket, Par::Pool(pool));
    assert_eq!(
        native.as_f32(),
        native_pool.as_f32(),
        "{tag}: persistent pool changed bits"
    );

    // reference path: gather into dense [bucket, KHS, seq, HD] (widening
    // any quantized storage to the same values the kernel dequantizes),
    // two-pass. Clamp each row's lens to the seq bucket like the kernels'
    // mask does.
    let (kc, vc) = arena.gather(&slots, layer, bucket, seq_bucket);
    let ref_lens: Vec<i32> = row_lens.iter().map(|&l| l.min(seq_bucket as i32)).collect();
    let want = reference::decode_attention_ref(&q, &kc, &vc, &ref_lens);
    assert_close(&native, &want, tag);
}

/// Overlap-path equivalence: `attn_prev` (before append) + `combine` (after)
/// must match both the native full pass and the reference full pass.
fn check_overlap(
    arena: &mut PagedKvArena,
    lens: &mut [usize],
    rng: &mut Rng,
    tag: &str,
) {
    // rows over live slots (no pads here; the wire sends pads lens 0 which
    // both paths turn into "new token only" — covered by unit tests)
    let bucket = rng.usize(1, SLOTS + 1);
    let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
    rng.shuffle(&mut slots);
    slots.truncate(bucket);
    if slots.iter().any(|&s| lens[s as usize] + 1 > LEN_CAP) {
        return;
    }
    let row_lens: Vec<i32> = slots.iter().map(|&s| lens[s as usize] as i32).collect();
    let seq_bucket = 64;
    let q = rand_q(rng, bucket);

    let prev = paged_attn_prev(arena, &slots, 0, &q, &row_lens, seq_bucket, Par::Threads(2));

    // reference partial over the gathered cache must agree
    {
        let (kc, vc) = arena.gather(&slots, 0, bucket, seq_bucket);
        let (ra, rs, rm) = reference::partial_attention_ref(&q, &kc, &vc, &row_lens);
        assert_close(&prev.a, &ra, &format!("{tag}: partial A"));
        assert_close(&prev.s, &rs, &format!("{tag}: partial S"));
        assert_close(&prev.m, &rm, &format!("{tag}: partial m"));
    }

    // append the step's K/V on every layer (protocol: layer 0 grows tables)
    let mut step_k0 = None;
    for layer in 0..LAYERS {
        let k = rand_kv(rng, bucket);
        let v = rand_kv(rng, bucket);
        arena.append_step(&slots, layer, &k, &v, &row_lens);
        if layer == 0 {
            step_k0 = Some((k, v));
        }
    }
    let (k0, v0) = step_k0.unwrap();

    let combined = combine_new_token(&q, &k0, &v0, &prev);
    let lens1: Vec<i32> = row_lens.iter().map(|&l| l + 1).collect();
    let full = paged_attn(arena, &slots, 0, &q, &lens1, seq_bucket, Par::Threads(2));
    // the full pass reads the new token back from *storage* (quantized),
    // while combine folds the exact wire tensor — so this comparison sees
    // one token's storage error on quantized arenas; bound accordingly
    let tol = match arena.dtype() {
        KvDtype::F32 => TOL,
        KvDtype::F16 => TOL_F16,
        KvDtype::Int8 => TOL_INT8,
    };
    assert_close_at(&combined, &full, tol, &format!("{tag}: prev+combine vs full"));

    for &s in &slots {
        lens[s as usize] += 1;
    }
}

/// Chunked prefill: native in-place kernel vs reference over gathered cache.
fn check_prefill(arena: &mut PagedKvArena, lens: &mut [usize], rng: &mut Rng, tag: &str) {
    let slot = rng.usize(0, SLOTS) as u32;
    let cached = if rng.chance(0.4) { 0 } else { lens[slot as usize] };
    let t = rng.usize(1, 7);
    if cached + t > LEN_CAP {
        return;
    }
    let seq_bucket = 64;
    let q = rand_q(rng, t);
    for layer in 0..LAYERS {
        let k = rand_kv(rng, t);
        let v = rand_kv(rng, t);
        if layer == 0 {
            // compute BEFORE append, exactly like the worker does
            let native = paged_prefill(arena, slot, 0, &q, &k, &v, cached, seq_bucket, Par::Threads(2));
            let native_mt =
                paged_prefill(arena, slot, 0, &q, &k, &v, cached, seq_bucket, Par::Threads(1));
            assert_eq!(native.as_f32(), native_mt.as_f32(), "{tag}: prefill thread bits");
            let (kc_b, vc_b) = arena.gather(&[slot], 0, 1, seq_bucket);
            let kc = kc_b.reshape(vec![KHS, seq_bucket, HD]);
            let vc = vc_b.reshape(vec![KHS, seq_bucket, HD]);
            let n = if cached == 0 { 0 } else { cached.min(arena.len_tokens(slot)) };
            let want = reference::chunked_prefill_ref(&q, &kc, &vc, n, &k, &v);
            assert_close(&native, &want, &format!("{tag}: prefill"));
        }
        arena.append_chunk(slot, layer, &k, &v, cached, t);
    }
    lens[slot as usize] = cached + t;
}

fn run_case(seed: u64, block_size: usize, dtype: KvDtype, ops: usize) {
    let mut rng = Rng::new(seed);
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: LAYERS,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size,
        initial_blocks: 2, // force on-demand growth
        dtype,
    });
    let pool = ScopedPool::new(3);
    let mut lens = vec![0usize; SLOTS];

    for op in 0..ops {
        let tag = format!("bs={block_size} dtype={} seed={seed:#x} op={op}", dtype.name());
        match rng.usize(0, 100) {
            // plain decode step: append on all layers, then compare full
            // attention on a random layer
            0..=44 => {
                let bucket = rng.usize(1, SLOTS + 1);
                let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
                rng.shuffle(&mut slots);
                slots.truncate(bucket);
                let mut step_lens = vec![0i32; bucket];
                for (b, s) in slots.iter_mut().enumerate() {
                    if rng.chance(0.2) || lens[*s as usize] + 1 > LEN_CAP {
                        *s = PAD_SLOT;
                    } else {
                        step_lens[b] = lens[*s as usize] as i32;
                    }
                }
                for layer in 0..LAYERS {
                    let k = rand_kv(&mut rng, bucket);
                    let v = rand_kv(&mut rng, bucket);
                    arena.append_step(&slots, layer, &k, &v, &step_lens);
                }
                for &s in &slots {
                    if s != PAD_SLOT {
                        lens[s as usize] += 1;
                    }
                }
                check_attention(&mut arena, &pool, &lens, &mut rng, &tag);
            }
            // overlap path (prev + combine) incl. its own appends
            45..=64 => check_overlap(&mut arena, &mut lens, &mut rng, &tag),
            // chunked prefill
            65..=84 => check_prefill(&mut arena, &mut lens, &mut rng, &tag),
            // retirement
            85..=92 => {
                let slot = rng.usize(0, SLOTS) as u32;
                arena.retire(slot);
                lens[slot as usize] = 0;
            }
            // slot reuse without retire (leader restarts at position 0)
            _ => {
                let slot = rng.usize(0, SLOTS);
                lens[slot] = 0;
            }
        }
    }
}

#[test]
fn prop_native_kernel_matches_gather_plus_reference() {
    for &bs in &[1usize, 4, 16] {
        for rep in 0..4 {
            run_case(0x7e57 + rep * 6151 + bs as u64, bs, KvDtype::F32, 50);
        }
    }
}

/// The same property at quantized storage: native reads the compact lanes,
/// the reference reads the gather-widened values — bit-identical inputs,
/// so the 1e-5 reassociation tolerance holds unchanged.
#[test]
fn prop_native_kernel_matches_reference_at_f16() {
    for &bs in &[1usize, 4, 16] {
        for rep in 0..2 {
            run_case(0xf16 + rep * 6151 + bs as u64, bs, KvDtype::F16, 40);
        }
    }
}

#[test]
fn prop_native_kernel_matches_reference_at_int8() {
    for &bs in &[1usize, 4, 16] {
        for rep in 0..2 {
            run_case(0x1e8 + rep * 6151 + bs as u64, bs, KvDtype::Int8, 40);
        }
    }
}

/// Quantization-error property: a quantized-arena pipeline vs an f32-arena
/// ground truth, fed byte-identical append streams. Normalised outputs
/// (full attention + prefill) must stay within the derived storage bounds
/// documented at the top of this file.
fn run_quant_error_case(seed: u64, dtype: KvDtype, tol: f32) {
    let mut rng = Rng::new(seed);
    let mk = |dtype| {
        PagedKvArena::new(ArenaCfg {
            layers: 1,
            kv_heads: KHS,
            head_dim: HD,
            max_seq: MAX_SEQ,
            slots: SLOTS,
            block_size: 4,
            initial_blocks: 2,
            dtype,
        })
    };
    let mut gold = mk(KvDtype::F32);
    let mut quant = mk(dtype);
    let mut lens = vec![0usize; SLOTS];

    for op in 0..60 {
        let tag = format!("quant-err dtype={} seed={seed:#x} op={op}", dtype.name());
        if rng.chance(0.25) && lens.iter().any(|&l| l > 0) {
            // full attention over a random live wave
            let live: Vec<u32> = (0..SLOTS as u32).filter(|&s| lens[s as usize] > 0).collect();
            let bucket = rng.usize(1, live.len() + 1);
            let slots = &live[..bucket];
            let row_lens: Vec<i32> = slots.iter().map(|&s| lens[s as usize] as i32).collect();
            let q = rand_q(&mut rng, bucket);
            let want = paged_attn(&gold, slots, 0, &q, &row_lens, 64, Par::Threads(1));
            let got = paged_attn(&quant, slots, 0, &q, &row_lens, 64, Par::Threads(1));
            assert_close_at(&got, &want, tol, &tag);
        } else if rng.chance(0.3) {
            // prefill chunk through both arenas, compare chunk outputs
            let slot = rng.usize(0, SLOTS) as u32;
            let cached = lens[slot as usize];
            let t = rng.usize(1, 6);
            if cached + t > LEN_CAP {
                continue;
            }
            let q = rand_q(&mut rng, t);
            let k = rand_kv(&mut rng, t);
            let v = rand_kv(&mut rng, t);
            let want = paged_prefill(&gold, slot, 0, &q, &k, &v, cached, 64, Par::Threads(1));
            let got = paged_prefill(&quant, slot, 0, &q, &k, &v, cached, 64, Par::Threads(1));
            assert_close_at(&got, &want, tol, &format!("{tag}: prefill"));
            gold.append_chunk(slot, 0, &k, &v, cached, t);
            quant.append_chunk(slot, 0, &k, &v, cached, t);
            lens[slot as usize] = cached + t;
        } else if rng.chance(0.1) {
            let slot = rng.usize(0, SLOTS) as u32;
            gold.retire(slot);
            quant.retire(slot);
            lens[slot as usize] = 0;
        } else {
            // decode append on every live-or-fresh slot
            let slots: Vec<u32> = (0..SLOTS as u32).collect();
            let step_lens: Vec<i32> = slots
                .iter()
                .map(|&s| lens[s as usize] as i32)
                .collect();
            if lens.iter().any(|&l| l + 1 > LEN_CAP) {
                continue;
            }
            let k = rand_kv(&mut rng, SLOTS);
            let v = rand_kv(&mut rng, SLOTS);
            gold.append_step(&slots, 0, &k, &v, &step_lens);
            quant.append_step(&slots, 0, &k, &v, &step_lens);
            for l in lens.iter_mut() {
                *l += 1;
            }
        }
    }
}

#[test]
fn prop_f16_storage_error_within_derived_bound() {
    for rep in 0..3 {
        run_quant_error_case(0xab5 + rep * 7919, KvDtype::F16, TOL_F16);
    }
}

#[test]
fn prop_int8_storage_error_within_derived_bound() {
    for rep in 0..3 {
        run_quant_error_case(0x8b17 + rep * 7919, KvDtype::Int8, TOL_INT8);
    }
}

#[test]
fn native_attention_is_copy_free_and_charges_kv_reads() {
    use lamina::runtime::host::{copies, kv_reads};
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: 1,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: 2,
        block_size: 4,
        initial_blocks: 2,
        dtype: KvDtype::F32,
    });
    let mut rng = Rng::new(0xc0ffee);
    for t in 0..10 {
        let k = rand_kv(&mut rng, 2);
        arena.append_step(&[0, 1], 0, &k, &k, &[t, t]);
    }
    let q = rand_q(&mut rng, 2);
    // `copies` is process-global and other tests run in parallel, so probe
    // with a retry: a run of the native kernel during which the counter
    // did not move proves the kernel itself charges nothing.
    let mut clean = false;
    for _ in 0..50 {
        let before = copies::total();
        let reads_before = kv_reads::total();
        let out = paged_attn(&arena, &[0, 1], 0, &q, &[10, 10], 16, Par::Threads(2));
        assert_eq!(out.shape(), &[2, HS, HD]);
        let read = kv_reads::total() - reads_before;
        // 2 rows × 3 blocks × block_bytes — ≥, because parallel tests may
        // also charge the global counter
        assert!(
            read >= (2 * arena.kv_read_bytes(10)) as u64,
            "kernel must charge its KV working set (read {read})"
        );
        if copies::total() == before {
            clean = true;
            break;
        }
    }
    assert!(clean, "native kernel must not charge host copies");
}

/// The bytes-read working set shrinks with the storage dtype: 2× at f16,
/// ≈4× at int8 — the tentpole's bandwidth claim, checked at the arena
/// accounting level (the bench suite checks the live counter).
#[test]
fn kv_read_bytes_drop_with_quantized_storage() {
    let mk = |dtype| {
        PagedKvArena::new(ArenaCfg {
            layers: 1,
            kv_heads: 2,
            head_dim: 64,
            max_seq: 512,
            slots: 1,
            block_size: 16,
            initial_blocks: 4,
            dtype,
        })
    };
    let f32b = mk(KvDtype::F32).kv_read_bytes(100) as f64;
    let f16b = mk(KvDtype::F16).kv_read_bytes(100) as f64;
    let i8b = mk(KvDtype::Int8).kv_read_bytes(100) as f64;
    assert!(f32b / f16b >= 1.99, "f16 read reduction {:.2}×", f32b / f16b);
    assert!(f32b / i8b >= 3.0, "int8 read reduction {:.2}×", f32b / i8b);
}
