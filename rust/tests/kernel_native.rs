//! Native block-table kernel vs the gather + reference path.
//!
//! The native kernels (`kernels::paged_attn*`) read the paged arena in
//! place with a **one-pass online-softmax** recurrence; the oracle
//! (`kernels::reference`) consumes the arena's **gathered** dense K/V with
//! a plain two-pass softmax. The two re-associate the softmax sums, so
//! they are *not* bit-identical; floating-point reassociation on O(1)
//! inputs perturbs results at the last few ulps.
//!
//! **Documented tolerance choice (per ISSUE 3):** we assert
//! `|native − reference| ≤ 1e-5 · max(1, |reference|)`. Inputs are PRNG
//! values in [-1, 1); normalised attention outputs are convex combinations
//! of them (O(1), so the bound is effectively absolute 1e-5 there), while
//! the *unnormalised* partial state `(A, S)` grows with the token count —
//! the `max(1, |·|)` factor keeps the bound meaningful at ~100 f32 ulps for
//! any magnitude. What IS asserted bit-exact: the native kernel against
//! itself across thread counts (row arithmetic is sequential per row, so
//! parallelism must not change a single bit).
//!
//! Sequences are randomised like `kv_paged.rs`: decode appends, prefill
//! chunks, retirement and slot reuse over random lens/buckets/block sizes.

use lamina::kernels::{
    combine_new_token, paged_attn, paged_attn_prev, paged_prefill, reference,
};
use lamina::kvcache::{ArenaCfg, PagedKvArena, PAD_SLOT};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;

const LAYERS: usize = 2;
const KHS: usize = 2;
const G: usize = 2;
const HS: usize = KHS * G;
const HD: usize = 4;
const MAX_SEQ: usize = 64;
const SLOTS: usize = 5;
const LEN_CAP: usize = 40;
const TOL: f32 = 1e-5;

fn rand_kv(rng: &mut Rng, rows: usize) -> HostTensor {
    let data: Vec<f32> = (0..rows * KHS * HD).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    HostTensor::f32(vec![rows, KHS, HD], data)
}

fn rand_q(rng: &mut Rng, rows: usize) -> HostTensor {
    let data: Vec<f32> = (0..rows * HS * HD).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    HostTensor::f32(vec![rows, HS, HD], data)
}

fn assert_close(got: &HostTensor, want: &HostTensor, tag: &str) {
    assert_eq!(got.shape(), want.shape(), "{tag}: shape");
    for (i, (a, b)) in got.as_f32().iter().zip(want.as_f32()).enumerate() {
        let bound = TOL * b.abs().max(1.0);
        assert!(
            (a - b).abs() <= bound,
            "{tag}: elem {i} native {a} vs reference {b} (|Δ| > {bound})"
        );
    }
}

/// Compare native full attention against gather + two-pass reference for a
/// random wave, and assert thread-count bit-determinism.
fn check_attention(arena: &mut PagedKvArena, lens: &[usize], rng: &mut Rng, tag: &str) {
    let bucket = rng.usize(1, SLOTS + 1);
    let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
    rng.shuffle(&mut slots);
    slots.truncate(bucket);
    let mut row_lens = vec![0i32; bucket];
    for (b, s) in slots.iter_mut().enumerate() {
        let have = lens[*s as usize];
        if have == 0 || rng.chance(0.15) {
            *s = PAD_SLOT;
            // pads carry lens1 = 1 on the real wire (leader lens 0 + 1)
            row_lens[b] = 1;
        } else {
            // attend a random valid prefix (usually everything cached)
            row_lens[b] = if rng.chance(0.7) { have } else { rng.usize(1, have + 1) } as i32;
        }
    }
    let seq_bucket = [16usize, 32, 64][rng.usize(0, 3)];
    let layer = rng.usize(0, LAYERS);
    let q = rand_q(rng, bucket);

    let native = paged_attn(arena, &slots, layer, &q, &row_lens, seq_bucket, 1);
    let native_mt = paged_attn(arena, &slots, layer, &q, &row_lens, seq_bucket, 4);
    assert_eq!(
        native.as_f32(),
        native_mt.as_f32(),
        "{tag}: thread count changed bits"
    );

    // reference path: gather into dense [bucket, KHS, seq, HD], two-pass.
    // Clamp each row's lens to the seq bucket like the kernels' mask does.
    let (kc, vc) = arena.gather(&slots, layer, bucket, seq_bucket);
    let ref_lens: Vec<i32> = row_lens.iter().map(|&l| l.min(seq_bucket as i32)).collect();
    let want = reference::decode_attention_ref(&q, &kc, &vc, &ref_lens);
    assert_close(&native, &want, tag);
}

/// Overlap-path equivalence: `attn_prev` (before append) + `combine` (after)
/// must match both the native full pass and the reference full pass.
fn check_overlap(
    arena: &mut PagedKvArena,
    lens: &mut [usize],
    rng: &mut Rng,
    tag: &str,
) {
    // rows over live slots (no pads here; the wire sends pads lens 0 which
    // both paths turn into "new token only" — covered by unit tests)
    let bucket = rng.usize(1, SLOTS + 1);
    let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
    rng.shuffle(&mut slots);
    slots.truncate(bucket);
    if slots.iter().any(|&s| lens[s as usize] + 1 > LEN_CAP) {
        return;
    }
    let row_lens: Vec<i32> = slots.iter().map(|&s| lens[s as usize] as i32).collect();
    let seq_bucket = 64;
    let q = rand_q(rng, bucket);

    let prev = paged_attn_prev(arena, &slots, 0, &q, &row_lens, seq_bucket, 2);

    // reference partial over the gathered cache must agree
    {
        let (kc, vc) = arena.gather(&slots, 0, bucket, seq_bucket);
        let (ra, rs, rm) = reference::partial_attention_ref(&q, &kc, &vc, &row_lens);
        assert_close(&prev.a, &ra, &format!("{tag}: partial A"));
        assert_close(&prev.s, &rs, &format!("{tag}: partial S"));
        assert_close(&prev.m, &rm, &format!("{tag}: partial m"));
    }

    // append the step's K/V on every layer (protocol: layer 0 grows tables)
    let mut step_k0 = None;
    for layer in 0..LAYERS {
        let k = rand_kv(rng, bucket);
        let v = rand_kv(rng, bucket);
        arena.append_step(&slots, layer, &k, &v, &row_lens);
        if layer == 0 {
            step_k0 = Some((k, v));
        }
    }
    let (k0, v0) = step_k0.unwrap();

    let combined = combine_new_token(&q, &k0, &v0, &prev);
    let lens1: Vec<i32> = row_lens.iter().map(|&l| l + 1).collect();
    let full = paged_attn(arena, &slots, 0, &q, &lens1, seq_bucket, 2);
    assert_close(&combined, &full, &format!("{tag}: prev+combine vs full"));

    for &s in &slots {
        lens[s as usize] += 1;
    }
}

/// Chunked prefill: native in-place kernel vs reference over gathered cache.
fn check_prefill(arena: &mut PagedKvArena, lens: &mut [usize], rng: &mut Rng, tag: &str) {
    let slot = rng.usize(0, SLOTS) as u32;
    let cached = if rng.chance(0.4) { 0 } else { lens[slot as usize] };
    let t = rng.usize(1, 7);
    if cached + t > LEN_CAP {
        return;
    }
    let seq_bucket = 64;
    let q = rand_q(rng, t);
    for layer in 0..LAYERS {
        let k = rand_kv(rng, t);
        let v = rand_kv(rng, t);
        if layer == 0 {
            // compute BEFORE append, exactly like the worker does
            let native = paged_prefill(arena, slot, 0, &q, &k, &v, cached, seq_bucket, 2);
            let native_mt = paged_prefill(arena, slot, 0, &q, &k, &v, cached, seq_bucket, 1);
            assert_eq!(native.as_f32(), native_mt.as_f32(), "{tag}: prefill thread bits");
            let (kc_b, vc_b) = arena.gather(&[slot], 0, 1, seq_bucket);
            let kc = kc_b.reshape(vec![KHS, seq_bucket, HD]);
            let vc = vc_b.reshape(vec![KHS, seq_bucket, HD]);
            let n = if cached == 0 { 0 } else { cached.min(arena.len_tokens(slot)) };
            let want = reference::chunked_prefill_ref(&q, &kc, &vc, n, &k, &v);
            assert_close(&native, &want, &format!("{tag}: prefill"));
        }
        arena.append_chunk(slot, layer, &k, &v, cached, t);
    }
    lens[slot as usize] = cached + t;
}

fn run_case(seed: u64, block_size: usize, ops: usize) {
    let mut rng = Rng::new(seed);
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: LAYERS,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size,
        initial_blocks: 2, // force on-demand growth
    });
    let mut lens = vec![0usize; SLOTS];

    for op in 0..ops {
        let tag = format!("bs={block_size} seed={seed:#x} op={op}");
        match rng.usize(0, 100) {
            // plain decode step: append on all layers, then compare full
            // attention on a random layer
            0..=44 => {
                let bucket = rng.usize(1, SLOTS + 1);
                let mut slots: Vec<u32> = (0..SLOTS as u32).collect();
                rng.shuffle(&mut slots);
                slots.truncate(bucket);
                let mut step_lens = vec![0i32; bucket];
                for (b, s) in slots.iter_mut().enumerate() {
                    if rng.chance(0.2) || lens[*s as usize] + 1 > LEN_CAP {
                        *s = PAD_SLOT;
                    } else {
                        step_lens[b] = lens[*s as usize] as i32;
                    }
                }
                for layer in 0..LAYERS {
                    let k = rand_kv(&mut rng, bucket);
                    let v = rand_kv(&mut rng, bucket);
                    arena.append_step(&slots, layer, &k, &v, &step_lens);
                }
                for &s in &slots {
                    if s != PAD_SLOT {
                        lens[s as usize] += 1;
                    }
                }
                check_attention(&mut arena, &lens, &mut rng, &tag);
            }
            // overlap path (prev + combine) incl. its own appends
            45..=64 => check_overlap(&mut arena, &mut lens, &mut rng, &tag),
            // chunked prefill
            65..=84 => check_prefill(&mut arena, &mut lens, &mut rng, &tag),
            // retirement
            85..=92 => {
                let slot = rng.usize(0, SLOTS) as u32;
                arena.retire(slot);
                lens[slot as usize] = 0;
            }
            // slot reuse without retire (leader restarts at position 0)
            _ => {
                let slot = rng.usize(0, SLOTS);
                lens[slot] = 0;
            }
        }
    }
}

#[test]
fn prop_native_kernel_matches_gather_plus_reference() {
    for &bs in &[1usize, 4, 16] {
        for rep in 0..4 {
            run_case(0x7e57 + rep * 6151 + bs as u64, bs, 50);
        }
    }
}

#[test]
fn native_attention_is_copy_free() {
    use lamina::runtime::host::copies;
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: 1,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: 2,
        block_size: 4,
        initial_blocks: 2,
    });
    let mut rng = Rng::new(0xc0ffee);
    for t in 0..10 {
        let k = rand_kv(&mut rng, 2);
        arena.append_step(&[0, 1], 0, &k, &k, &[t, t]);
    }
    let q = rand_q(&mut rng, 2);
    // `copies` is process-global and other tests run in parallel, so probe
    // with a retry: a run of the native kernel during which the counter
    // did not move proves the kernel itself charges nothing.
    let mut clean = false;
    for _ in 0..50 {
        let before = copies::total();
        let out = paged_attn(&arena, &[0, 1], 0, &q, &[10, 10], 16, 2);
        assert_eq!(out.shape(), &[2, HS, HD]);
        if copies::total() == before {
            clean = true;
            break;
        }
    }
    assert!(clean, "native kernel must not charge host copies");
}
