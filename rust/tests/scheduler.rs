//! Property tests for the request-lifecycle scheduler — the control plane
//! of the continuous-batching engine. Everything here runs without PJRT
//! artifacts: the scheduler is pure bookkeeping, so a mock "model" (a
//! deterministic per-request token function, batch-invariant exactly like
//! the real pipeline, which `tests/e2e_pipeline.rs` asserts) is enough to
//! drive full lifecycles.
//!
//! Covered properties (ISSUE 5 satellite):
//! * (a) scheduling-order invariance: the same submissions produce
//!   bit-identical per-request outputs under continuous (Packed) and
//!   legacy wave (ByWave) grouping, and under FIFO vs SJF admission —
//!   the per-request token streams do not depend on batch composition.
//! * (b) no request starves under SJF with a continuous arrival stream
//!   (the aging escape into FIFO order).
//! * (c) slot/reservation conservation across submit/cancel/retire churn:
//!   after a drain, every slot and every reserved block/byte is back in
//!   the pools (the leader-side KvStats half lives in e2e_pipeline).
//! * (d) preemption (ISSUE 6): a preempted-and-resumed request produces
//!   the exact token stream of an unpreempted run (replay re-prefill +
//!   re-predict of the dropped token), and random preemption churn —
//!   stacked on cancel churn, with block-granular overcommit on —
//!   conserves slots and reservations.

use lamina::scheduler::{
    AdmissionKind, FinishReason, GroupMode, KvBudget, KvOccupancy, RequestId, RequestState,
    SchedCfg, Scheduler, SubmitError,
};
use lamina::util::prng::Rng;

fn cfg(slots: usize, group: usize, grouping: GroupMode, budget: KvBudget) -> SchedCfg {
    SchedCfg {
        max_context: 256,
        total_slots: slots,
        group_slots: group,
        grouping,
        use_prefill: true,
        kv_block_size: 4,
        block_bytes: 64,
        budget,
        overcommit: false,
    }
}

/// Deterministic mock model: the token a request gets at context length
/// `len` depends only on (request, len) — batch-invariant, like the real
/// pipeline.
fn mock_tok(id: RequestId, len: i32) -> i32 {
    (id as i32) * 1000 + len
}

/// One engine iteration against the mock model, mirroring
/// `DisaggPipeline::step`: admit, then one prefill chunk or a full decode
/// pass, then collect retirements. Occupancy is fed back from the
/// scheduler's own reservations (a worker pool that always grows to the
/// reservation — the conservative admission view).
fn mock_step(s: &mut Scheduler, chunk: usize) -> Vec<(RequestId, u32)> {
    let occ = KvOccupancy {
        blocks_in_use: s.reserved_blocks(),
        bytes_in_use: s.reserved_bytes(),
    };
    s.admit(occ);
    if let Some(p) = s.next_prefill() {
        let c = s.prompt_chunk(p.id, chunk);
        s.note_prefill_chunk(p.id, c.len(), mock_tok(p.id, (p.cached + c.len()) as i32));
    } else {
        for rows in s.decode_plan() {
            for r in &rows {
                s.note_decode(r.id, mock_tok(r.id, r.len + 1));
            }
        }
    }
    s.take_retirements()
}

fn drain(s: &mut Scheduler, chunk: usize) -> Vec<(RequestId, u32)> {
    let mut retired = Vec::new();
    let mut guard = 0;
    while !s.is_idle() {
        retired.extend(mock_step(s, chunk));
        guard += 1;
        assert!(guard < 100_000, "scheduler failed to drain (livelock)");
    }
    retired
}

// ---------------------------------------------------------------------------
// (a) scheduling-order invariance
// ---------------------------------------------------------------------------

/// A mixed-arrival scripted workload: some requests up front, the rest
/// joining mid-flight. Returns every request's final token stream.
fn run_session(grouping: GroupMode, admission: AdmissionKind) -> Vec<(RequestState, Vec<i32>)> {
    let mut s = Scheduler::new(cfg(4, 2, grouping, KvBudget::Blocks(16)), admission.build());
    let spec: Vec<(usize, usize)> = vec![(5, 3), (2, 6), (12, 2), (7, 4), (3, 5), (9, 1), (1, 4)];
    let mut ids = Vec::new();
    for (i, &(plen, gen)) in spec.iter().enumerate() {
        // prompt content is a function of submission order, not admission
        let prompt: Vec<i32> = (0..plen).map(|t| (i * 100 + t) as i32).collect();
        ids.push(s.submit(prompt, gen).unwrap());
        // interleave a couple of iterations between arrivals
        mock_step(&mut s, 4);
        mock_step(&mut s, 4);
    }
    drain(&mut s, 4);
    ids.iter()
        .map(|&id| {
            let st = s.poll(id).unwrap();
            (st.state, st.tokens)
        })
        .collect()
}

#[test]
fn outputs_invariant_under_grouping_and_policy() {
    let base = run_session(GroupMode::Packed, AdmissionKind::Fifo);
    for (state, tokens) in &base {
        assert_eq!(*state, RequestState::Finished(FinishReason::Completed));
        assert!(!tokens.is_empty());
    }
    // wave-partitioned grouping: different batch composition, same tokens
    assert_eq!(run_session(GroupMode::ByWave, AdmissionKind::Fifo), base);
    // SJF admission: different admission ORDER, same per-request tokens
    assert_eq!(run_session(GroupMode::Packed, AdmissionKind::Sjf), base);
    assert_eq!(run_session(GroupMode::ByWave, AdmissionKind::Sjf), base);
}

#[test]
fn token_counts_match_targets() {
    let spec = [(5usize, 3usize), (2, 6), (12, 2), (7, 4), (3, 5), (9, 1), (1, 4)];
    let results = run_session(GroupMode::Packed, AdmissionKind::Fifo);
    assert_eq!(results.len(), spec.len());
    for ((state, tokens), (_plen, gen)) in results.iter().zip(spec) {
        assert_eq!(*state, RequestState::Finished(FinishReason::Completed));
        assert_eq!(tokens.len(), gen, "output length must equal the generation target");
    }
}

// ---------------------------------------------------------------------------
// (b) SJF does not starve under a continuous arrival stream
// ---------------------------------------------------------------------------

#[test]
fn sjf_does_not_starve_long_requests() {
    let mut s = Scheduler::new(cfg(2, 2, GroupMode::Packed, KvBudget::Blocks(8)), AdmissionKind::Sjf.build());
    // the "elephant": needs the whole 8-block budget (ctx 32, bs 4), so it
    // can only be admitted when nothing else is live
    let long = s.submit(vec![1; 26], 6).unwrap();
    let mut admitted_at = None;
    for step in 0..10_000 {
        // continuous stream of mice (1 block each) that SJF always prefers
        if step % 2 == 0 {
            let _ = s.submit(vec![7, 8], 2).unwrap();
        }
        mock_step(&mut s, 4);
        if s.poll(long).unwrap().state != RequestState::Queued {
            admitted_at = Some(step);
            break;
        }
    }
    let at = admitted_at.expect("long request starved under SJF");
    // aging bound (32 rounds) + drain of the live mice — generously < 200
    assert!(at < 200, "admission took {at} iterations");
    // and the elephant actually completes
    drain(&mut s, 4);
    let st = s.poll(long).unwrap();
    assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
    assert_eq!(st.tokens.len(), 6);
    assert!(s.deferred_total() > 0, "the elephant must have been deferred first");
}

#[test]
fn sjf_reorders_around_a_blocked_head_fifo_does_not() {
    let mk = |kind: AdmissionKind| {
        let mut s = Scheduler::new(cfg(4, 4, GroupMode::Packed, KvBudget::Blocks(8)), kind.build());
        let tiny = s.submit(vec![1, 2], 2).unwrap(); // 1 block
        let big = s.submit(vec![1; 26], 6).unwrap(); // 8 blocks (the full budget)
        let small = s.submit(vec![3, 4], 2).unwrap(); // 1 block
        mock_step(&mut s, 4);
        (s, tiny, big, small)
    };
    // FIFO: tiny admits, then the big head blocks the small one behind it
    let (s, tiny, big, small) = mk(AdmissionKind::Fifo);
    assert!(s.poll(tiny).unwrap().state.is_live());
    assert_eq!(s.poll(big).unwrap().state, RequestState::Queued);
    assert_eq!(s.poll(small).unwrap().state, RequestState::Queued);
    assert!(s.deferred_total() > 0);
    // SJF: both shorts flow around the deferred big request
    let (s, tiny, big, small) = mk(AdmissionKind::Sjf);
    assert!(s.poll(tiny).unwrap().state.is_live());
    assert!(s.poll(small).unwrap().state.is_live());
    assert_eq!(s.poll(big).unwrap().state, RequestState::Queued);
    assert!(s.deferred_total() > 0);
}

#[test]
fn slot_bound_waits_do_not_age_sjf_waiters() {
    // Regression: aging must count rounds the policy PASSED a request over
    // (someone else admitted, or a budget deferral), not rounds where the
    // slots were simply full — otherwise sustained load ages the whole
    // queue past the bound and SJF degenerates into FIFO.
    let mut s = Scheduler::new(
        cfg(2, 2, GroupMode::Packed, KvBudget::Unlimited),
        AdmissionKind::Sjf.build(),
    );
    // staggered long occupants: slots stay pinned full, freeing one at a time
    s.submit(vec![1; 9], 180).unwrap();
    s.submit(vec![1; 9], 230).unwrap();
    mock_step(&mut s, 4);
    let big = s.submit(vec![1; 20], 8).unwrap(); // expensive waiter, arrives FIRST
    for _ in 0..150 {
        // 150 slot-bound rounds, far past the 32-round aging bound
        mock_step(&mut s, 4);
    }
    assert_eq!(s.poll(big).unwrap().state, RequestState::Queued);
    let cheap = s.submit(vec![9, 9], 2).unwrap(); // cheap job arrives much later
    let mut guard = 0;
    while s.poll(cheap).unwrap().state == RequestState::Queued
        && s.poll(big).unwrap().state == RequestState::Queued
    {
        mock_step(&mut s, 4);
        guard += 1;
        assert!(guard < 10_000, "nothing ever admitted");
    }
    // when the first slot frees, SJF must still pick the cheap job: the
    // big one did not age into forced-FIFO priority while slot-bound
    assert_ne!(s.poll(cheap).unwrap().state, RequestState::Queued);
    assert_eq!(s.poll(big).unwrap().state, RequestState::Queued);
}

// ---------------------------------------------------------------------------
// (c) slot/reservation conservation across churn
// ---------------------------------------------------------------------------

#[test]
fn conservation_across_submit_cancel_retire_churn() {
    for (grouping, admission, seed) in [
        (GroupMode::Packed, AdmissionKind::Fifo, 1u64),
        (GroupMode::Packed, AdmissionKind::Sjf, 2),
        (GroupMode::ByWave, AdmissionKind::Fifo, 3),
        (GroupMode::ByWave, AdmissionKind::Sjf, 4),
    ] {
        let total_slots = 4;
        let mut s =
            Scheduler::new(cfg(total_slots, 2, grouping, KvBudget::Blocks(32)), admission.build());
        let mut rng = Rng::new(seed);
        let mut submitted: Vec<RequestId> = Vec::new();
        let mut retired: Vec<(RequestId, u32)> = Vec::new();
        for _ in 0..600 {
            if rng.chance(0.5) {
                let plen = rng.usize(1, 10);
                let gen = rng.usize(1, 6);
                submitted.push(s.submit(vec![1; plen], gen).unwrap());
            }
            if rng.chance(0.15) && !submitted.is_empty() {
                let victim = submitted[rng.usize(0, submitted.len())];
                s.cancel(victim); // may hit any state; must stay consistent
            }
            // mid-flight invariants, every iteration
            assert!(s.live() + s.free_slot_count() == total_slots);
            retired.extend(mock_step(&mut s, 4));
        }
        retired.extend(drain(&mut s, 4));

        // no leaks: every slot and reservation is back
        assert_eq!(s.free_slot_count(), total_slots, "leaked slots ({grouping:?})");
        assert_eq!(s.reserved_blocks(), 0, "leaked block reservations");
        assert_eq!(s.reserved_bytes(), 0, "leaked byte reservations");
        assert_eq!(s.live(), 0);
        assert_eq!(s.waiting_len(), 0);
        // every submitted request reached a terminal state
        for id in &submitted {
            assert!(s.poll(*id).unwrap().state.is_finished(), "request {id} not finished");
        }
        // Retire accounting: at most one retirement per request, only for
        // admitted requests, slots in range — and every COMPLETED request
        // (which necessarily wrote KV; gen ≥ 1 here) retired exactly once.
        // Cancelled-before-first-write requests must NOT retire (a stale
        // Retire could wipe the slot's next occupant).
        let mut seen = std::collections::BTreeSet::new();
        for (id, slot) in &retired {
            assert!((*slot as usize) < total_slots, "retired an out-of-range slot");
            assert!(seen.insert(*id), "request {id} retired twice");
            assert!(
                s.poll(*id).unwrap().queue_s.is_some(),
                "request {id} retired without ever being admitted"
            );
        }
        let completed: Vec<RequestId> = submitted
            .iter()
            .copied()
            .filter(|&id| {
                s.poll(id).unwrap().state == RequestState::Finished(FinishReason::Completed)
            })
            .collect();
        assert!(!completed.is_empty(), "churn must complete some requests");
        for id in &completed {
            assert!(seen.contains(id), "completed request {id} never retired its KV");
        }
    }
}

// ---------------------------------------------------------------------------
// (d) preemption: output identity + conservation under churn
// ---------------------------------------------------------------------------

#[test]
fn preempted_outputs_match_unpreempted_run() {
    // `(at, victim)`: before iteration `at`, preempt request `victim`.
    // The mock model's token depends only on (id, context length), exactly
    // like the deterministic pipeline, so replay must reconstruct the same
    // stream — including the emitted-but-unfed token dropped at preemption.
    let run = |script: &[(usize, usize)]| {
        let mut s = Scheduler::new(
            SchedCfg { overcommit: true, ..cfg(4, 2, GroupMode::Packed, KvBudget::Blocks(16)) },
            AdmissionKind::Fifo.build(),
        );
        let spec = [(5usize, 3usize), (2, 6), (12, 2), (7, 4)];
        let mut ids = Vec::new();
        for (i, &(plen, gen)) in spec.iter().enumerate() {
            let prompt: Vec<i32> = (0..plen).map(|t| (i * 100 + t) as i32).collect();
            ids.push(s.submit(prompt, gen).unwrap());
        }
        let mut iter = 0;
        let mut preempted = 0u32;
        while !s.is_idle() {
            for &(at, victim) in script {
                if iter == at && s.preempt(ids[victim]) {
                    preempted += 1;
                }
            }
            mock_step(&mut s, 4);
            iter += 1;
            assert!(iter < 100_000, "scheduler failed to drain (livelock)");
        }
        if !script.is_empty() {
            assert!(preempted > 0, "the script must actually preempt something");
        }
        ids.iter()
            .map(|&id| {
                let st = s.poll(id).unwrap();
                assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
                st.tokens
            })
            .collect::<Vec<_>>()
    };
    let base = run(&[]);
    // mid-prefill victim, mid-decode victim, two victims, double-preempt
    assert_eq!(run(&[(1, 0)]), base);
    assert_eq!(run(&[(2, 1), (5, 0)]), base);
    assert_eq!(run(&[(3, 2), (6, 2)]), base);
}

#[test]
fn conservation_with_preemption_and_overcommit_churn() {
    // The cancel-churn conservation property, hardened two ways: random
    // preempts land in any state, and overcommit reserves prompt-only then
    // grows per block — reservations must still drain to exactly zero.
    for seed in [11u64, 12, 13] {
        let total_slots = 4;
        let mut s = Scheduler::new(
            SchedCfg {
                overcommit: true,
                ..cfg(total_slots, 2, GroupMode::Packed, KvBudget::Blocks(32))
            },
            AdmissionKind::Fifo.build(),
        );
        let mut rng = Rng::new(seed);
        let mut submitted: Vec<RequestId> = Vec::new();
        let mut retired: Vec<(RequestId, u32)> = Vec::new();
        for _ in 0..600 {
            if rng.chance(0.5) {
                let plen = rng.usize(1, 10);
                let gen = rng.usize(1, 6);
                submitted.push(s.submit(vec![1; plen], gen).unwrap());
            }
            if rng.chance(0.15) && !submitted.is_empty() {
                let victim = submitted[rng.usize(0, submitted.len())];
                s.cancel(victim);
            }
            if rng.chance(0.2) && !submitted.is_empty() {
                let victim = submitted[rng.usize(0, submitted.len())];
                s.preempt(victim); // false on non-live victims; must be inert
            }
            assert!(s.live() + s.free_slot_count() == total_slots);
            retired.extend(mock_step(&mut s, 4));
        }
        retired.extend(drain(&mut s, 4));

        assert_eq!(s.free_slot_count(), total_slots, "leaked slots (seed {seed})");
        assert_eq!(s.reserved_blocks(), 0, "leaked block reservations");
        assert_eq!(s.reserved_bytes(), 0, "leaked byte reservations");
        assert_eq!(s.live(), 0);
        assert_eq!(s.waiting_len(), 0);
        for id in &submitted {
            assert!(s.poll(*id).unwrap().state.is_finished(), "request {id} not finished");
        }
        // With preemption a request may retire several times (each eviction
        // releases its blocks); every retire must still name an admitted
        // request and an in-range slot, and every completed request must
        // have released its final KV.
        let mut seen = std::collections::BTreeSet::new();
        for (id, slot) in &retired {
            assert!((*slot as usize) < total_slots, "retired an out-of-range slot");
            assert!(
                s.poll(*id).unwrap().queue_s.is_some(),
                "request {id} retired without ever being admitted"
            );
            seen.insert(*id);
        }
        for id in submitted.iter().filter(|&&id| {
            s.poll(id).unwrap().state == RequestState::Finished(FinishReason::Completed)
        }) {
            assert!(seen.contains(id), "completed request {id} never retired its KV");
        }
        assert!(s.preempted_total() > 0, "churn must land some preemptions (seed {seed})");
    }
}

// ---------------------------------------------------------------------------
// budget semantics
// ---------------------------------------------------------------------------

#[test]
fn byte_budget_equivalent_to_block_budget() {
    // 4 blocks ≡ 4 × block_bytes bytes: identical admission decisions
    let run = |budget: KvBudget| {
        let mut s = Scheduler::new(cfg(8, 8, GroupMode::Packed, budget), AdmissionKind::Fifo.build());
        for i in 0..6 {
            s.submit(vec![1; 4 + i], 4).unwrap(); // ctx 8..13 → 2..4 blocks
        }
        let mut live_trace = Vec::new();
        for _ in 0..200 {
            mock_step(&mut s, 4);
            live_trace.push((s.live(), s.waiting_len(), s.reserved_blocks()));
            if s.is_idle() {
                break;
            }
        }
        assert!(s.is_idle());
        (live_trace, s.deferred_total())
    };
    let (blocks_trace, blocks_deferred) = run(KvBudget::Blocks(4));
    let (bytes_trace, bytes_deferred) = run(KvBudget::Bytes(4 * 64));
    assert_eq!(blocks_trace, bytes_trace);
    assert_eq!(blocks_deferred, bytes_deferred);
    assert!(blocks_deferred > 0, "the tight budget must defer something");
}

#[test]
fn oversized_request_escape_hatch_when_alone() {
    // needs 13 blocks against a 4-block budget: would deadlock forever
    // without the no-live-requests escape hatch
    let mut s = Scheduler::new(cfg(2, 2, GroupMode::Packed, KvBudget::Blocks(4)), AdmissionKind::Fifo.build());
    let id = s.submit(vec![1; 48], 4).unwrap();
    drain(&mut s, 4);
    let st = s.poll(id).unwrap();
    assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
    assert_eq!(st.tokens.len(), 4);
    assert_eq!(s.deferred_total(), 0, "solo admission is not a deferral");
}

// ---------------------------------------------------------------------------
// submit validation (typed, per request)
// ---------------------------------------------------------------------------

#[test]
fn submit_errors_are_typed_and_isolated() {
    let mut s = Scheduler::new(cfg(2, 2, GroupMode::Packed, KvBudget::Unlimited), AdmissionKind::Fifo.build());
    assert_eq!(s.submit(vec![], 4), Err(SubmitError::EmptyPrompt));
    assert_eq!(
        s.submit(vec![1; 200], 100),
        Err(SubmitError::ContextTooLong { requested: 300, max: 256 })
    );
    // the error is per request: the session still serves valid ones
    let ok = s.submit(vec![1, 2, 3], 2).unwrap();
    drain(&mut s, 4);
    assert_eq!(s.poll(ok).unwrap().state, RequestState::Finished(FinishReason::Completed));
    // boundary: exactly max_context is admissible
    let edge = s.submit(vec![1; 200], 56).unwrap();
    drain(&mut s, 4);
    assert!(s.poll(edge).unwrap().state.is_finished());
}

#[test]
fn queue_and_ttft_are_observable() {
    let mut s = Scheduler::new(cfg(1, 1, GroupMode::Packed, KvBudget::Unlimited), AdmissionKind::Fifo.build());
    let a = s.submit(vec![1, 2, 3, 4], 2).unwrap();
    let b = s.submit(vec![5, 6], 2).unwrap(); // waits for the only slot
    assert_eq!(s.poll(a).unwrap().queue_s, None);
    mock_step(&mut s, 4);
    assert!(s.poll(a).unwrap().queue_s.is_some());
    assert_eq!(s.poll(b).unwrap().queue_s, None, "one slot: b still queued");
    drain(&mut s, 4);
    for id in [a, b] {
        let st = s.poll(id).unwrap();
        assert!(st.queue_s.is_some());
        assert!(st.ttft_s.is_some());
        assert!(st.ttft_s >= st.queue_s, "first token cannot precede admission");
    }
}
