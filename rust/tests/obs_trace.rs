//! Golden tests for the tracing pipeline: the artifact-free smoke session
//! (`run_trace_smoke` — a real attention worker + native kernel over the
//! in-process transport) must emit a well-formed, Perfetto-parseable trace
//! with monotone timestamps and properly nested spans, on the happy path
//! AND when the worker dies mid-session.
//!
//! The trace sink is process-global, so every test here serializes through
//! one mutex and fully owns start()/stop() while holding it.

use std::sync::Mutex;

use lamina::obs::{self, trace, ArgVal, TraceEvent};
use lamina::util::json::Json;
use lamina::workers::run_trace_smoke;

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Check stack discipline per track: sorted by start time, every span must
/// either nest inside the enclosing open span or start at/after its end —
/// partial overlap (`a.ts < b.ts < a.end < b.end`) is malformed.
fn assert_nested(events: &[TraceEvent]) {
    let mut tracks: Vec<u64> = events.iter().map(|e| e.track).collect();
    tracks.sort_unstable();
    tracks.dedup();
    // float tolerance: span end timestamps are measured out-of-order with
    // sibling starts, so allow a microsecond of clock slop
    const TOL: f64 = 1.0;
    for t in tracks {
        let mut spans: Vec<&TraceEvent> =
            events.iter().filter(|e| e.track == t && e.ph == 'X').collect();
        spans.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
        let mut stack: Vec<f64> = Vec::new(); // open span end times
        for s in spans {
            assert!(s.dur_us >= 0.0, "negative duration on {}", s.name);
            let end = s.ts_us + s.dur_us;
            while let Some(&top) = stack.last() {
                if s.ts_us >= top - TOL {
                    stack.pop(); // enclosing span already closed
                } else {
                    assert!(
                        end <= top + TOL,
                        "span {} [{}, {end}] straddles enclosing span end {top} on track {t}",
                        s.name,
                        s.ts_us
                    );
                    break;
                }
            }
            stack.push(end);
        }
    }
}

fn cats_of(events: &[TraceEvent]) -> Vec<&'static str> {
    let mut cats: Vec<&'static str> = events.iter().map(|e| e.cat).collect();
    cats.sort_unstable();
    cats.dedup();
    cats
}

#[test]
fn smoke_session_emits_well_formed_trace() {
    let _g = guard();
    trace::start();
    let report = run_trace_smoke(8, false).expect("smoke session");
    let events = trace::stop();

    assert_eq!(report.decode_steps, 8);
    assert!(!report.worker_died);
    assert_eq!(trace::dropped(), 0);
    assert!(!events.is_empty());

    // spans are recorded at Drop, so per-track capture order is end-time
    // order (an outer span lands AFTER its children); the monotone clock
    // makes those end stamps nondecreasing within a track
    let mut last_end = std::collections::BTreeMap::new();
    for e in &events {
        let end = e.ts_us + e.dur_us;
        let prev = last_end.entry(e.track).or_insert(f64::NEG_INFINITY);
        assert!(
            end >= *prev,
            "event {} closes out of order on track {}",
            e.name,
            e.track
        );
        *prev = end;
    }

    assert_nested(&events);

    // the full vocabulary shows up: leader phases, wire sends/recvs, the
    // worker's message handling, and the native kernel underneath
    let cats = cats_of(&events);
    for want in ["leader", "wire", "worker", "kernel"] {
        assert!(cats.contains(&want), "missing category {want} in {cats:?}");
    }
    // worker spans land on the worker's own track (shard 0 -> track 1)
    assert!(
        events.iter().any(|e| e.cat == "worker" && e.track == 1),
        "worker spans must use track 1"
    );
    assert!(
        events.iter().any(|e| e.cat == "kernel" && e.track == 1),
        "kernel spans run on the worker thread"
    );
    // step-trace instants carry the structured scheduler view
    let steps: Vec<&TraceEvent> =
        events.iter().filter(|e| e.name == "step-trace").collect();
    assert_eq!(steps.len(), 8, "one instant per decode iteration");
    for s in &steps {
        assert_eq!(s.ph, 'i');
        assert!(s.args.iter().any(|(k, _)| *k == "slots"));
        assert!(s
            .args
            .iter()
            .any(|(k, v)| *k == "seq_bucket" && *v == ArgVal::I(64)));
    }
}

#[test]
fn chrome_trace_export_parses_and_names_tracks() {
    let _g = guard();
    trace::start();
    run_trace_smoke(4, false).expect("smoke session");
    let events = trace::stop();

    let doc = Json::parse(&obs::export::chrome_trace(&events)).expect("valid JSON");
    let evs = doc.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(evs.len() > events.len(), "events + thread_name metadata");

    let mut names = Vec::new();
    for e in evs {
        match e.get("ph").as_str().unwrap() {
            "M" => {
                assert_eq!(e.get("name").as_str(), Some("thread_name"));
                names.push(e.get("args").get("name").as_str().unwrap().to_string());
            }
            "X" => {
                assert!(e.get("dur").as_f64().unwrap() >= 0.0);
                assert!(e.get("ts").as_f64().is_some());
                assert_eq!(e.get("pid").as_i64(), Some(1));
            }
            "i" => {
                assert_eq!(e.get("s").as_str(), Some("t"), "thread-scoped instant");
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    assert!(names.contains(&"leader".to_string()));
    assert!(names.contains(&"attn-worker-0".to_string()));

    // the JSONL stream parses line-by-line too
    let jsonl = obs::export::jsonl(&events);
    let mut lines = 0;
    for line in jsonl.lines() {
        let e = Json::parse(line).expect("valid JSONL line");
        assert!(e.get("name").as_str().is_some());
        lines += 1;
    }
    assert_eq!(lines, events.len());
}

#[test]
fn worker_death_truncates_cleanly() {
    let _g = guard();
    trace::start();
    let report = run_trace_smoke(8, true).expect("kill session still returns Ok");
    let events = trace::stop();

    assert!(report.worker_died, "poisoned protocol must kill the worker");
    assert!(report.decode_steps < 8, "session was cut short");
    assert!(!events.is_empty());
    // the truncated trace is still structurally sound: parseable export,
    // nested spans, worker/kernel activity present up to the death point
    assert_nested(&events);
    let cats = cats_of(&events);
    for want in ["leader", "wire", "worker", "kernel"] {
        assert!(cats.contains(&want), "missing category {want} after death");
    }
    Json::parse(&obs::export::chrome_trace(&events)).expect("truncated trace parses");
}

#[test]
fn panicking_scope_still_records_its_span() {
    let _g = guard();
    trace::start();
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // keep the test log quiet
    let r = std::panic::catch_unwind(|| {
        let _sp = obs::span("leader", "doomed").arg("step", 1);
        panic!("mid-span failure");
    });
    std::panic::set_hook(prev);
    assert!(r.is_err());
    let events = trace::stop();
    let doomed = events
        .iter()
        .find(|e| e.name == "doomed")
        .expect("span closed during unwinding");
    assert_eq!(doomed.ph, 'X');
    assert!(doomed.dur_us >= 0.0);
    assert!(doomed.args.iter().any(|(k, v)| *k == "step" && *v == ArgVal::I(1)));
}

#[test]
fn disabled_tracing_records_nothing() {
    let _g = guard();
    // make sure we're stopped, then emit under disabled tracing
    let _ = trace::stop();
    {
        let _sp = obs::span("leader", "invisible").arg("x", 1);
        obs::instant("leader", "also-invisible", vec![]);
    }
    assert!(!trace::enabled());
    // a later session must not see the disabled-time events
    trace::start();
    {
        let _sp = obs::span("leader", "visible");
    }
    let events = trace::stop();
    assert!(events.iter().all(|e| e.name != "invisible"));
    assert!(events.iter().all(|e| e.name != "also-invisible"));
    assert_eq!(events.iter().filter(|e| e.name == "visible").count(), 1);
}

#[test]
fn spans_dropped_after_stop_are_discarded() {
    let _g = guard();
    trace::start();
    let sp = obs::span("leader", "straggler");
    let events = trace::stop();
    drop(sp); // worker draining after shutdown: silently discarded
    assert!(events.iter().all(|e| e.name != "straggler"));
    // and the next session stays clean
    trace::start();
    let next = trace::stop();
    assert!(next.iter().all(|e| e.name != "straggler"));
}
