//! Chaos suite: seeded fault schedules across full submit/step/poll
//! sessions, over both transports.
//!
//! The invariant under test is the PR's acceptance bar: **no fault the
//! injector can produce may panic the leader-side loop**, and every
//! faulted session must either
//!
//! * complete with output **bit-identical** to the fault-free golden run
//!   (auto-recovery: detection → preempt-replay-rebuild), or
//! * fail **typed** ([`ChaosFailure`] carrying the [`WorkerDeath`]) with
//!   every KV block freed — zero leaked reservations, verified through
//!   the workers' own `KvStats` accounting.
//!
//! The harness ([`lamina::workers::chaos`]) runs the real scheduler and
//! real native-backend attention workers; only the model math is a
//! deterministic pseudo-model engineered so recovered output is
//! bit-comparable (constant-K attention — see the module docs). Faults
//! are seed-driven [`FaultPlan`]s: link kills at scheduled send/recv
//! counts, probabilistic drops (which kill the link with the in-flight
//! loss), frame corruption, and added delay.

use lamina::coordinator::failover::DeathCause;
use lamina::net::{FaultPlan, TransportKind};
use lamina::workers::chaos::{prompt_for, run_chaos, ChaosCfg, ChaosReport};

fn cfg(transport: TransportKind) -> ChaosCfg {
    ChaosCfg { transport, ..ChaosCfg::default() }
}

fn golden(transport: TransportKind) -> ChaosReport {
    let r = run_chaos(&cfg(transport)).expect("fault-free run must complete");
    assert_eq!(r.worker_deaths, 0);
    assert_eq!(r.leaked_blocks, 0);
    r
}

/// Every faulted outcome must satisfy the chaos invariant against its
/// golden run: recovered-and-identical, or typed failure with zero leaks.
fn assert_invariant(
    plan: &str,
    transport: TransportKind,
    golden: &ChaosReport,
) -> Result<ChaosReport, String> {
    let mut c = cfg(transport);
    c.fault_plan = Some(FaultPlan::parse(plan).expect("plan parses"));
    match run_chaos(&c) {
        Ok(r) => {
            assert_eq!(
                r.outputs, golden.outputs,
                "fault plan `{plan}` over {}: recovered output diverged",
                transport.name()
            );
            assert_eq!(
                r.leaked_blocks, 0,
                "fault plan `{plan}` over {}: leaked KV blocks",
                transport.name()
            );
            Ok(r)
        }
        Err(f) => {
            assert_eq!(
                f.leaked_blocks, 0,
                "fault plan `{plan}` over {}: typed failure leaked KV blocks",
                transport.name()
            );
            Err(f.death.to_string())
        }
    }
}

// ---------------------------------------------------------------------------
// golden sanity
// ---------------------------------------------------------------------------

#[test]
fn golden_runs_match_across_transports() {
    let a = golden(TransportKind::Inproc);
    let b = golden(TransportKind::Tcp);
    assert_eq!(a.outputs, b.outputs, "transports must be bit-identical");
    assert!(a.outputs.iter().all(|o| o.len() == ChaosCfg::default().gen_tokens));
    // distinct prompts → the pseudo-model must not collapse to one stream
    assert!(prompt_for(0) != prompt_for(1));
    assert!(a.outputs[0] != a.outputs[1] || a.outputs[0] != a.outputs[2]);
}

// ---------------------------------------------------------------------------
// scheduled kills at random points of the session, both transports
// ---------------------------------------------------------------------------

#[test]
fn kill_schedules_never_panic_and_recover_bit_identical() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        let mut recovered = 0usize;
        // per link, a fault-free session sees ~38 sends (6 prefill, 4 per
        // decode iteration, retires, barrier) and ~21 recvs (2 per prefill
        // step and decode iteration, barrier) — these schedules land kills
        // in prefill, mid-decode, and the retire/drain tail
        for (worker, k) in [(0, 1), (1, 3), (0, 7), (1, 14), (0, 23), (1, 31)] {
            let plan = format!("worker={worker},kill-send={k}");
            if let Ok(r) = assert_invariant(&plan, transport, &gold) {
                assert!(r.worker_deaths >= 1, "plan `{plan}` never fired");
                assert!(r.recoveries >= 1);
                recovered += 1;
            }
        }
        for (worker, k) in [(0, 1), (1, 2), (0, 5), (1, 9), (0, 13), (1, 17)] {
            let plan = format!("worker={worker},kill-recv={k}");
            if let Ok(r) = assert_invariant(&plan, transport, &gold) {
                assert!(r.worker_deaths >= 1, "plan `{plan}` never fired");
                recovered += 1;
            }
        }
        // auto-recovery is on: every one of these must have healed
        assert_eq!(recovered, 12, "a kill schedule failed to recover on {}", transport.name());
    }
}

#[test]
fn kill_during_replay_recovers_or_fails_clean() {
    // worker=<none>: EVERY link is armed — the second worker's kill can
    // land inside the first recovery's re-prefill, exercising the cascade
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        for k in [5, 9, 16] {
            let _ = assert_invariant(&format!("kill-send={k}"), transport, &gold);
            let _ = assert_invariant(&format!("kill-recv={k}"), transport, &gold);
        }
    }
}

// ---------------------------------------------------------------------------
// probabilistic schedules (seeded): drop and corrupt
// ---------------------------------------------------------------------------

#[test]
fn seeded_drop_and_corrupt_schedules_hold_the_invariant() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        for seed in 1..=6u64 {
            let _ = assert_invariant(&format!("seed={seed},drop=0.05"), transport, &gold);
            let _ = assert_invariant(&format!("seed={seed},corrupt=0.05"), transport, &gold);
            let _ =
                assert_invariant(&format!("seed={seed},drop=0.02,corrupt=0.02"), transport, &gold);
        }
    }
}

#[test]
fn corrupt_frame_is_declared_corrupt_not_hang() {
    let mut c = cfg(TransportKind::Inproc);
    c.fault_plan = Some(FaultPlan::parse("worker=0,corrupt=1.0").expect("plan"));
    c.auto_recover = false;
    let f = run_chaos(&c).expect_err("certain corruption must fail the session");
    assert!(
        matches!(f.death.cause, DeathCause::Corrupt | DeathCause::Disconnected),
        "unexpected cause: {:?}",
        f.death.cause
    );
    assert_eq!(f.leaked_blocks, 0);
}

// ---------------------------------------------------------------------------
// delay: slower, but no deaths and still bit-identical
// ---------------------------------------------------------------------------

#[test]
fn delay_within_deadline_is_transparent() {
    let gold = golden(TransportKind::Inproc);
    let r = assert_invariant("delay-us=200", TransportKind::Inproc, &gold)
        .expect("delay below the recv deadline must not kill anything");
    assert_eq!(r.worker_deaths, 0);
}

// A true hang (silence without disconnect — repeated `Ok(None)` expiries
// walking the retry/backoff ladder to `Verdict::Dead`) cannot be produced
// by `FaultPlan` (its delay is a sleep that still delivers); the ladder
// itself is unit-tested in `coordinator::failover`.

// ---------------------------------------------------------------------------
// no-recovery mode: typed failure surfaces, KV accounting stays clean
// ---------------------------------------------------------------------------

#[test]
fn without_auto_recover_every_kill_fails_typed_with_zero_leaks() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        for (worker, k) in [(0, 2), (1, 11)] {
            let mut c = cfg(transport);
            c.fault_plan =
                Some(FaultPlan::parse(&format!("worker={worker},kill-send={k}")).expect("plan"));
            c.auto_recover = false;
            let f = run_chaos(&c).expect_err("kill without recovery must abort");
            assert_eq!(f.death.worker, worker);
            assert_eq!(
                f.leaked_blocks, 0,
                "aborted session leaked KV on {}",
                transport.name()
            );
        }
    }
}
