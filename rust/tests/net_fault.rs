//! Chaos suite: seeded fault schedules across full submit/step/poll
//! sessions, over both transports.
//!
//! The invariant under test is the PR's acceptance bar: **no fault the
//! injector can produce may panic the leader-side loop**, and every
//! faulted session must either
//!
//! * complete with output **bit-identical** to the fault-free golden run
//!   (auto-recovery: detection → preempt-replay-rebuild), or
//! * fail **typed** ([`ChaosFailure`] carrying the [`WorkerDeath`]) with
//!   every KV block freed — zero leaked reservations, verified through
//!   the workers' own `KvStats` accounting.
//!
//! The harness ([`lamina::workers::chaos`]) runs the real scheduler and
//! real native-backend attention workers; only the model math is a
//! deterministic pseudo-model engineered so recovered output is
//! bit-comparable (constant-K attention — see the module docs). Faults
//! are seed-driven [`FaultPlan`]s: link kills at scheduled send/recv
//! counts, probabilistic drops (which kill the link with the in-flight
//! loss), frame corruption, and added delay.

use lamina::coordinator::failover::DeathCause;
use lamina::net::{FaultPlan, TransportKind};
use lamina::workers::chaos::{prompt_for, run_chaos, ChaosCfg, ChaosReport};

fn cfg(transport: TransportKind) -> ChaosCfg {
    ChaosCfg { transport, ..ChaosCfg::default() }
}

fn golden(transport: TransportKind) -> ChaosReport {
    let r = run_chaos(&cfg(transport)).expect("fault-free run must complete");
    assert_eq!(r.worker_deaths, 0);
    assert_eq!(r.leaked_blocks, 0);
    r
}

/// Every faulted outcome must satisfy the chaos invariant against its
/// golden run: recovered-and-identical, or typed failure with zero leaks.
fn assert_invariant(
    plan: &str,
    transport: TransportKind,
    golden: &ChaosReport,
) -> Result<ChaosReport, String> {
    let mut c = cfg(transport);
    c.fault_plan = Some(FaultPlan::parse(plan).expect("plan parses"));
    match run_chaos(&c) {
        Ok(r) => {
            assert_eq!(
                r.outputs, golden.outputs,
                "fault plan `{plan}` over {}: recovered output diverged",
                transport.name()
            );
            assert_eq!(
                r.leaked_blocks, 0,
                "fault plan `{plan}` over {}: leaked KV blocks",
                transport.name()
            );
            Ok(r)
        }
        Err(f) => {
            assert_eq!(
                f.leaked_blocks, 0,
                "fault plan `{plan}` over {}: typed failure leaked KV blocks",
                transport.name()
            );
            Err(f.death.to_string())
        }
    }
}

// ---------------------------------------------------------------------------
// golden sanity
// ---------------------------------------------------------------------------

#[test]
fn golden_runs_match_across_transports() {
    let a = golden(TransportKind::Inproc);
    let b = golden(TransportKind::Tcp);
    assert_eq!(a.outputs, b.outputs, "transports must be bit-identical");
    assert!(a.outputs.iter().all(|o| o.len() == ChaosCfg::default().gen_tokens));
    // distinct prompts → the pseudo-model must not collapse to one stream
    assert!(prompt_for(0) != prompt_for(1));
    assert!(a.outputs[0] != a.outputs[1] || a.outputs[0] != a.outputs[2]);
}

// ---------------------------------------------------------------------------
// scheduled kills at random points of the session, both transports
// ---------------------------------------------------------------------------

#[test]
fn kill_schedules_never_panic_and_recover_bit_identical() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        let mut recovered = 0usize;
        // per link, a fault-free session sees ~39 sends (1 Welcome, 6
        // prefill, 4 per decode iteration, retires, barrier) and ~22 recvs
        // (the Hello, 2 per prefill step and decode iteration, barrier) —
        // these schedules land kills in prefill, mid-decode, and the
        // retire/drain tail (send/recv #1 is the handshake, covered by its
        // own test below)
        for (worker, k) in [(0, 2), (1, 4), (0, 8), (1, 15), (0, 24), (1, 32)] {
            let plan = format!("worker={worker},kill-send={k}");
            if let Ok(r) = assert_invariant(&plan, transport, &gold) {
                assert!(r.worker_deaths >= 1, "plan `{plan}` never fired");
                assert!(r.recoveries >= 1);
                recovered += 1;
            }
        }
        for (worker, k) in [(0, 2), (1, 3), (0, 6), (1, 10), (0, 14), (1, 18)] {
            let plan = format!("worker={worker},kill-recv={k}");
            if let Ok(r) = assert_invariant(&plan, transport, &gold) {
                assert!(r.worker_deaths >= 1, "plan `{plan}` never fired");
                recovered += 1;
            }
        }
        // auto-recovery is on: every one of these must have healed
        assert_eq!(recovered, 12, "a kill schedule failed to recover on {}", transport.name());
    }
}

#[test]
fn kill_during_replay_recovers_or_fails_clean() {
    // worker=<none>: EVERY link is armed — the second worker's kill can
    // land inside the first recovery's re-prefill, exercising the cascade
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        for k in [5, 9, 16] {
            let _ = assert_invariant(&format!("kill-send={k}"), transport, &gold);
            let _ = assert_invariant(&format!("kill-recv={k}"), transport, &gold);
        }
    }
}

// ---------------------------------------------------------------------------
// probabilistic schedules (seeded): drop and corrupt
// ---------------------------------------------------------------------------

#[test]
fn seeded_drop_and_corrupt_schedules_hold_the_invariant() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        for seed in 1..=6u64 {
            let _ = assert_invariant(&format!("seed={seed},drop=0.05"), transport, &gold);
            let _ = assert_invariant(&format!("seed={seed},corrupt=0.05"), transport, &gold);
            let _ =
                assert_invariant(&format!("seed={seed},drop=0.02,corrupt=0.02"), transport, &gold);
        }
    }
}

#[test]
fn corrupt_frame_is_declared_corrupt_not_hang() {
    let mut c = cfg(TransportKind::Inproc);
    c.fault_plan = Some(FaultPlan::parse("worker=0,corrupt=1.0").expect("plan"));
    c.auto_recover = false;
    let f = run_chaos(&c).expect_err("certain corruption must fail the session");
    assert!(
        matches!(f.death.cause, DeathCause::Corrupt | DeathCause::Disconnected),
        "unexpected cause: {:?}",
        f.death.cause
    );
    assert_eq!(f.leaked_blocks, 0);
}

// ---------------------------------------------------------------------------
// delay: slower, but no deaths and still bit-identical
// ---------------------------------------------------------------------------

#[test]
fn delay_within_deadline_is_transparent() {
    let gold = golden(TransportKind::Inproc);
    let r = assert_invariant("delay-us=200", TransportKind::Inproc, &gold)
        .expect("delay below the recv deadline must not kill anything");
    assert_eq!(r.worker_deaths, 0);
}

// A true hang (silence without disconnect — repeated `Ok(None)` expiries
// walking the retry/backoff ladder to `Verdict::Dead`) cannot be produced
// by `FaultPlan` (its delay is a sleep that still delivers); the ladder
// itself is unit-tested in `coordinator::failover`.

// ---------------------------------------------------------------------------
// no-recovery mode: typed failure surfaces, KV accounting stays clean
// ---------------------------------------------------------------------------

#[test]
fn without_auto_recover_every_kill_fails_typed_with_zero_leaks() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        for (worker, k) in [(0, 3), (1, 12)] {
            let mut c = cfg(transport);
            c.fault_plan =
                Some(FaultPlan::parse(&format!("worker={worker},kill-send={k}")).expect("plan"));
            c.auto_recover = false;
            let f = run_chaos(&c).expect_err("kill without recovery must abort");
            assert_eq!(f.death.worker, worker);
            assert_eq!(
                f.leaked_blocks, 0,
                "aborted session leaked KV on {}",
                transport.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// elastic membership: handshake kills, graceful degradation, adoption
// ---------------------------------------------------------------------------

#[test]
fn kill_inside_handshake_fails_typed_with_zero_leaks() {
    // send #1 on a link is the leader's Welcome and recv #1 the worker's
    // Hello: both kills land inside the membership handshake, before the
    // data plane opens — the session must refuse to start, typed, without
    // stranding anything (no KV was ever reserved)
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        for plan in ["worker=1,kill-send=1", "worker=1,kill-recv=1"] {
            let mut c = cfg(transport);
            c.fault_plan = Some(FaultPlan::parse(plan).expect("plan"));
            let f = run_chaos(&c).expect_err("handshake kill must abort typed");
            assert_eq!(
                f.leaked_blocks, 0,
                "plan `{plan}` on {} leaked KV",
                transport.name()
            );
        }
    }
}

/// Property: ANY two-kill script over a W=4 pool with respawn disabled
/// degrades W=4→3→2 with output bit-identical to the fault-free run, on
/// both transports. Includes a same-boundary double kill, which forces
/// the second death to surface *inside* the first degrade's reshard
/// window (the cascade path with shifted worker indices).
#[test]
fn degrade_ladder_w4_w3_w2_bit_identical_both_transports() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let mut g = cfg(transport);
        g.workers = 4;
        let gold = run_chaos(&g).expect("golden W=4");
        assert_eq!(gold.leaked_blocks, 0);
        for script in [
            vec![(2usize, 3usize), (5, 1)], // sequential, tail worker first
            vec![(1, 0), (4, 2)],           // head worker first, then a survivor
            vec![(2, 2), (2, 1)],           // simultaneous: cascade inside reshard
        ] {
            let mut c = g.clone();
            c.allow_respawn = false;
            c.min_workers = 2;
            c.kill_at = script.clone();
            let r = run_chaos(&c)
                .unwrap_or_else(|f| panic!("script {script:?} on {}: {f}", transport.name()));
            assert_eq!(
                r.outputs, gold.outputs,
                "script {script:?} on {}: degraded output diverged",
                transport.name()
            );
            assert_eq!(r.degrades, 2, "script {script:?}");
            assert_eq!(r.final_workers, 2, "script {script:?}");
            assert_eq!(r.leaked_blocks, 0, "script {script:?}");
            assert!(r.tokens_replayed > 0, "script {script:?}");
        }
    }
}

#[test]
fn degrade_below_floor_refuses_typed_and_leak_free() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let mut c = cfg(transport);
        c.workers = 2;
        c.allow_respawn = false;
        c.min_workers = 2;
        c.kill_at = vec![(3, 1)];
        let f = run_chaos(&c).expect_err("below-floor degrade must refuse");
        assert_eq!(f.death.worker, 1);
        assert_eq!(
            f.leaked_blocks, 0,
            "refusal must quiesce leak-free on {}",
            transport.name()
        );
    }
}

/// The PR's acceptance scenario: kill one of W=4 with respawn disabled —
/// the pool degrades live to W=3, bit-identical — then adopt a joiner at
/// a later step boundary and finish back at W=4.
#[test]
fn degrade_then_adopt_restores_full_width_bit_identical() {
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let mut g = cfg(transport);
        g.workers = 4;
        let gold = run_chaos(&g).expect("golden W=4");
        let mut c = g.clone();
        c.allow_respawn = false;
        c.min_workers = 2;
        c.kill_at = vec![(2, 1)];
        c.adopt_at_step = Some(6);
        let r = run_chaos(&c)
            .unwrap_or_else(|f| panic!("degrade+adopt on {}: {f}", transport.name()));
        assert_eq!(r.outputs, gold.outputs, "output diverged on {}", transport.name());
        assert_eq!(r.degrades, 1);
        assert_eq!(r.adoptions, 1);
        assert_eq!(r.final_workers, 4);
        assert_eq!(r.worker_deaths, 1);
        assert_eq!(r.leaked_blocks, 0);
    }
}

#[test]
fn kill_inside_adoption_window_rolls_back_clean() {
    // the joiner spawns fault-wrapped (`worker=2` targets it alone in a
    // W=2 pool); its link dies inside the adoption handshake (`kill-recv`
    // hits its Hello) or inside the reshard window (`kill-send` hits its
    // Welcome, AFTER the survivors already took the widened epoch). The
    // leader must evict it, re-fence the original membership at a fresh
    // epoch, and still finish bit-identical.
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        for plan in ["worker=2,kill-recv=1", "worker=2,kill-send=1"] {
            let mut c = cfg(transport);
            c.adopt_at_step = Some(3);
            c.fault_plan = Some(FaultPlan::parse(plan).expect("plan"));
            let r = run_chaos(&c)
                .unwrap_or_else(|f| panic!("plan `{plan}` on {}: {f}", transport.name()));
            assert_eq!(
                r.outputs, gold.outputs,
                "plan `{plan}` on {}: rollback diverged",
                transport.name()
            );
            assert_eq!(r.adoptions, 0, "plan `{plan}`: failed adoption must not count");
            assert_eq!(r.final_workers, 2, "plan `{plan}`");
            assert_eq!(r.worker_deaths, 1, "plan `{plan}`");
            assert_eq!(r.leaked_blocks, 0, "plan `{plan}`");
        }
    }
}

#[test]
fn adoption_on_healthy_pool_is_transparent() {
    // pure scale-up, no faults: W=2 → W=3 mid-session must not change a
    // single output token
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let gold = golden(transport);
        let mut c = cfg(transport);
        c.adopt_at_step = Some(4);
        let r = run_chaos(&c).expect("adoption must not fail a healthy pool");
        assert_eq!(r.outputs, gold.outputs, "adoption changed output on {}", transport.name());
        assert_eq!(r.adoptions, 1);
        assert_eq!(r.final_workers, 3);
        assert_eq!(r.worker_deaths, 0);
        assert_eq!(r.leaked_blocks, 0);
    }
}
