//! KV block quantization round-trips (ISSUE 4 satellite): property tests
//! for the f32↔f16 and f32↔int8-with-scale encode/decode pairs, plus a
//! full arena append/gather/retire/reuse lifecycle at each storage dtype.
//!
//! Bounds asserted here:
//! * f16 round-trip: `|x − rt(x)| ≤ 2⁻¹¹·|x|` for normals in the f16
//!   range; exact for f16-representable values; NaN/±0/±inf semantics;
//!   correct subnormal rounding and overflow/underflow behaviour.
//! * int8 round-trip at a region scale `s = maxabs/127`: fresh writes
//!   within `s/2`; each in-block requantization adds ≤ `s_new/2`, so the
//!   worst case over a full chain of raises is `(block_size/2)·maxabs/127`
//!   (see `kvcache::quant`) — the lifecycle test runs at block_size 4 and
//!   asserts the end-to-end gather stays within `2·maxabs/127` (+25%
//!   headroom for f32 rounding).

use lamina::kvcache::quant::{
    f16_bits_to_f32, f32_to_f16_bits, i8_decode, i8_encode, i8_scale_for,
};
use lamina::kvcache::{ArenaCfg, KvDtype, PagedKvArena};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;

const KHS: usize = 2;
const HD: usize = 8;
const MAX_SEQ: usize = 64;
const SLOTS: usize = 4;
const LEN_CAP: usize = 48;

fn rt16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[test]
fn prop_f16_roundtrip_error_bound_across_magnitudes() {
    let mut rng = Rng::new(0xf16f16);
    for _ in 0..20_000 {
        // log-uniform magnitudes across the f16 normal range, both signs
        let exp = rng.f64() * 28.0 - 13.0; // 2^-13 .. 2^15
        let x = ((rng.f64() * 2.0 - 1.0) as f32) * (2.0f64.powf(exp) as f32);
        let y = rt16(x);
        let ax = x.abs();
        if ax >= 6.104e-5 && ax <= 65504.0 {
            assert!(
                (y - x).abs() <= ax * 4.8829e-4,
                "normal-range x={x} rt={y}"
            );
        } else if ax < 6.104e-5 {
            // subnormal range: absolute error ≤ half the subnormal step
            assert!((y - x).abs() <= 2.981e-8, "subnormal x={x} rt={y}");
        }
        // round-trip is idempotent: rt(rt(x)) == rt(x) bitwise
        assert_eq!(rt16(y).to_bits(), y.to_bits(), "x={x}");
    }
}

#[test]
fn prop_f16_specials_and_monotonicity() {
    // specials
    assert!(rt16(f32::NAN).is_nan());
    assert_eq!(rt16(f32::INFINITY), f32::INFINITY);
    assert_eq!(rt16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    assert_eq!(rt16(0.0).to_bits(), 0.0f32.to_bits());
    assert_eq!(rt16(-0.0).to_bits(), (-0.0f32).to_bits());
    // conversion is monotone over a dense sweep (rounding must never
    // reorder values — a requirement for score ordering under f16 KV)
    let mut rng = Rng::new(0x5160);
    let mut vals: Vec<f32> = (0..4096).map(|_| ((rng.f64() * 2.0 - 1.0) * 100.0) as f32).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut prev = f32::NEG_INFINITY;
    for &x in &vals {
        let y = rt16(x);
        assert!(y >= prev, "monotonicity broken at {x}: {y} < {prev}");
        prev = y;
    }
}

#[test]
fn prop_int8_roundtrip_full_range_scales() {
    let mut rng = Rng::new(0x18a7e);
    for _ in 0..5_000 {
        // magnitudes from 1e-30 to 1e30: scales must keep working
        let exp = rng.f64() * 200.0 - 100.0;
        let maxabs = (10.0f64.powf(exp * 0.3) as f32).max(1e-30);
        let scale = i8_scale_for(maxabs);
        assert!(scale > 0.0 && scale.is_finite(), "scale for {maxabs}");
        for _ in 0..8 {
            let x = ((rng.f64() * 2.0 - 1.0) as f32) * maxabs;
            let c = i8_encode(x, scale);
            let y = i8_decode(c, scale);
            assert!(
                (y - x).abs() <= scale * 0.5 + maxabs * 1e-6,
                "maxabs={maxabs} x={x} y={y}"
            );
        }
        // the extremes use the full code range
        assert_eq!(i8_encode(maxabs, scale), 127);
        assert_eq!(i8_encode(-maxabs, scale), -127);
    }
}

fn mk(dtype: KvDtype, block_size: usize) -> PagedKvArena {
    PagedKvArena::new(ArenaCfg {
        layers: 2,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size,
        initial_blocks: 2,
        dtype,
    })
}

fn rand_kv(rng: &mut Rng, rows: usize, mag: f32) -> HostTensor {
    let data: Vec<f32> = (0..rows * KHS * HD)
        .map(|_| ((rng.f64() * 2.0 - 1.0) as f32) * mag)
        .collect();
    HostTensor::f32(vec![rows, KHS, HD], data)
}

/// Arena lifecycle at one dtype: random appends (decode + chunks),
/// retires, and slot reuse; after every mutation a gather must match the
/// f32 ground-truth arena within the dtype's per-element bound.
fn run_lifecycle(seed: u64, dtype: KvDtype, block_size: usize, per_elem_bound: impl Fn(f32) -> f32) {
    let mut rng = Rng::new(seed);
    let mut gold = mk(KvDtype::F32, block_size);
    let mut quant = mk(dtype, block_size);
    let mut lens = vec![0usize; SLOTS];
    // per-slot magnitude so int8 bounds can reference the stream's maxabs
    let mag = 2.5f32;

    for op in 0..80 {
        match rng.usize(0, 10) {
            0..=4 => {
                // decode step on a random subset
                let slots: Vec<u32> = (0..SLOTS as u32)
                    .filter(|_| rng.chance(0.7))
                    .collect();
                if slots.is_empty() || slots.iter().any(|&s| lens[s as usize] + 1 > LEN_CAP) {
                    continue;
                }
                let step_lens: Vec<i32> =
                    slots.iter().map(|&s| lens[s as usize] as i32).collect();
                for layer in 0..2 {
                    let k = rand_kv(&mut rng, slots.len(), mag);
                    let v = rand_kv(&mut rng, slots.len(), mag);
                    gold.append_step(&slots, layer, &k, &v, &step_lens);
                    quant.append_step(&slots, layer, &k, &v, &step_lens);
                }
                for &s in &slots {
                    lens[s as usize] += 1;
                }
            }
            5..=6 => {
                // prefill chunk
                let slot = rng.usize(0, SLOTS) as u32;
                let cached = if rng.chance(0.5) { 0 } else { lens[slot as usize] };
                let t = rng.usize(1, 6);
                if cached + t > LEN_CAP {
                    continue;
                }
                for layer in 0..2 {
                    let k = rand_kv(&mut rng, t, mag);
                    let v = rand_kv(&mut rng, t, mag);
                    gold.append_chunk(slot, layer, &k, &v, cached, t);
                    quant.append_chunk(slot, layer, &k, &v, cached, t);
                }
                lens[slot as usize] = cached + t;
            }
            7 => {
                let slot = rng.usize(0, SLOTS) as u32;
                gold.retire(slot);
                quant.retire(slot);
                lens[slot as usize] = 0;
            }
            _ => {
                // slot reuse without retire
                let slot = rng.usize(0, SLOTS);
                lens[slot] = 0;
            }
        }

        // gather both and compare element-wise within the storage bound
        let slots: Vec<u32> = (0..SLOTS as u32).collect();
        let layer = rng.usize(0, 2);
        let (gk, gv) = gold.gather(&slots, layer, SLOTS, MAX_SEQ);
        let (qk, qv) = quant.gather(&slots, layer, SLOTS, MAX_SEQ);
        for (which, g, q) in [("K", &gk, &qk), ("V", &gv, &qv)] {
            for (i, (a, b)) in g.as_f32().iter().zip(q.as_f32()).enumerate() {
                let bound = per_elem_bound(*a);
                assert!(
                    (a - b).abs() <= bound,
                    "dtype={} op={op} {which}[{i}]: gold {a} vs quant {b} (> {bound})",
                    dtype.name()
                );
                // zeros (pads, retired, beyond-len) must be exactly zero in
                // both arenas — quantization must never leak stale bytes
                if *a == 0.0 {
                    assert_eq!(*b, 0.0, "dtype={} op={op} {which}[{i}] stale", dtype.name());
                }
            }
        }
    }
    // full retire drains both arenas identically
    for s in 0..SLOTS as u32 {
        gold.retire(s);
        quant.retire(s);
    }
    assert_eq!(quant.stats().blocks_in_use, 0);
    assert_eq!(quant.stats().bytes_in_use, 0);
}

#[test]
fn prop_arena_lifecycle_f32_is_bit_exact() {
    for rep in 0..2 {
        run_lifecycle(0x1f32 + rep * 7919, KvDtype::F32, 4, |_| 0.0);
    }
}

#[test]
fn prop_arena_lifecycle_f16_within_relative_bound() {
    for rep in 0..2 {
        // RNE: ≤ 2⁻¹¹ relative per element
        run_lifecycle(0x1f16 + rep * 7919, KvDtype::F16, 4, |x| x.abs() * 4.8829e-4 + 1e-9);
    }
}

#[test]
fn prop_arena_lifecycle_int8_within_scale_bound() {
    for rep in 0..2 {
        // per-element worst case ≤ 2·maxabs/127 with maxabs ≤ 2.5
        // (block_size-bounded requant chain, see module docs); 25%
        // headroom over the exactly-tight bound for f32 rounding
        run_lifecycle(0x11e8 + rep * 7919, KvDtype::Int8, 4, |_| 2.5 * 2.5 / 127.0);
    }
}

#[test]
fn int8_gather_is_idempotent_once_scales_settle() {
    // two gathers without interleaved appends must be bit-identical (the
    // decode path gathers every layer step at the engine backend)
    let mut rng = Rng::new(0x1de);
    let mut a = mk(KvDtype::Int8, 4);
    for t in 0..10 {
        let k = rand_kv(&mut rng, SLOTS, 1.0);
        a.append_step(&[0, 1, 2, 3], 0, &k, &k, &[t, t, t, t]);
    }
    let (k1, v1) = a.gather(&[0, 1, 2, 3], 0, SLOTS, 32);
    let (s1k, s1v) = (k1.as_f32().to_vec(), v1.as_f32().to_vec());
    drop(k1);
    drop(v1);
    let (k2, v2) = a.gather(&[0, 1, 2, 3], 0, SLOTS, 32);
    assert_eq!(&s1k[..], k2.as_f32());
    assert_eq!(&s1v[..], v2.as_f32());
}
