//! End-to-end integration: the full three-layer stack.
//!
//! The Rust coordinator loads the AOT artifacts (L2 slices + L1 Pallas
//! attention lowered to HLO), spawns head-sharded attention workers, and
//! greedy-decodes the golden prompts. The produced tokens must equal
//! `golden.json`, which python generated with the *unsliced* reference
//! model — proving slicing + disaggregation + head sharding + (optionally)
//! overlap are all semantics-preserving.

use std::path::PathBuf;

use lamina::netsim::stack::NCCL;
use lamina::trace::Request;
use lamina::util::json::Json;
use lamina::workers::{DisaggPipeline, PipelineOpts};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("golden.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

struct Golden {
    prompts: Vec<Vec<i32>>,
    steps: usize,
    generated: Vec<Vec<i32>>,
}

fn load_golden() -> Golden {
    let text = std::fs::read_to_string(artifacts_dir().join("golden.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let ivec = |v: &Json| -> Vec<i32> {
        v.as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    Golden {
        prompts: j.get("prompts").as_arr().unwrap().iter().map(ivec).collect(),
        steps: j.get("steps").as_usize().unwrap(),
        generated: j.get("generated").as_arr().unwrap().iter().map(ivec).collect(),
    }
}

fn run_golden(overlap: bool, attn_workers: usize) {
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let opts = PipelineOpts {
        overlap,
        attn_workers,
        ..PipelineOpts::new(artifacts_dir())
    };
    let pipe = DisaggPipeline::start(opts).expect("pipeline start");
    let out = pipe.decode(&g.prompts, g.steps).expect("decode");
    pipe.shutdown();
    assert_eq!(out, g.generated, "decoded tokens diverge from golden (overlap={overlap}, workers={attn_workers})");
}

#[test]
fn golden_decode_sequential_two_workers() {
    run_golden(false, 2);
}

#[test]
fn golden_decode_overlap_two_workers() {
    run_golden(true, 2);
}

#[test]
fn golden_decode_single_worker() {
    run_golden(false, 1);
}

#[test]
fn golden_decode_overlap_single_worker() {
    run_golden(true, 1);
}

#[test]
fn decode_batch_invariance() {
    // A prompt's decode must not depend on its batch-mates (KV isolation
    // across slots on the attention workers).
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let solo = pipe.decode(&[vec![7, 8, 9]], 6).unwrap();
    let pair = pipe
        .decode(&[vec![7, 8, 9], vec![100, 3, 100, 55]], 6)
        .unwrap();
    pipe.shutdown();
    assert_eq!(solo[0], pair[0]);
}

#[test]
fn decode_deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let a = pipe.decode(&[vec![5, 6]], 5).unwrap();
    let b = pipe.decode(&[vec![5, 6]], 5).unwrap();
    pipe.shutdown();
    assert_eq!(a, b);
}

#[test]
fn serve_small_trace_with_metrics() {
    // Continuous-batching serve over mixed-length requests, with paced NCCL
    // networking; verifies completions and sane metrics.
    if !have_artifacts() {
        return;
    }
    let opts = PipelineOpts {
        stack: &NCCL,
        time_scale: 1.0, // real modelled network pacing
        ..PipelineOpts::new(artifacts_dir())
    };
    let pipe = DisaggPipeline::start(opts).unwrap();
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request {
            id: i,
            prompt_tokens: 3 + (i as usize % 5) * 7,
            gen_tokens: 2 + (i as usize % 4),
        })
        .collect();
    let metrics = pipe.serve(&reqs, 1).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 12);
    // first tokens come out of the prefill pass (not decode steps), so the
    // decode-step token count is below the total generation volume
    assert!(metrics.tokens_generated > 0);
    assert!(metrics.throughput() > 0.0);
    assert!(metrics.mean_tbt() > 0.0);
}

#[test]
fn serve_two_waves_staggered() {
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request { id: i, prompt_tokens: 4, gen_tokens: 3 })
        .collect();
    let metrics = pipe.serve(&reqs, 2).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 10);
}

#[test]
fn oversized_context_rejected() {
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let huge = [Request { id: 0, prompt_tokens: 10_000, gen_tokens: 4 }];
    assert!(pipe.serve(&huge, 1).is_err());
    pipe.shutdown();
}

#[test]
fn prefill_then_decode_matches_teacher_forced_golden() {
    // The chunked-prefill transition (paper §5) must be semantics-preserving:
    // prefill(prompt) + decode == the golden teacher-forced decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    for overlap in [false, true] {
        let pipe = DisaggPipeline::start(PipelineOpts {
            overlap,
            ..PipelineOpts::new(artifacts_dir())
        })
        .unwrap();
        for (i, (prompt, want)) in g.prompts.iter().zip(&g.generated).enumerate() {
            let out = pipe.generate(i as u32, prompt, g.steps).unwrap();
            assert_eq!(&out, want, "prompt {i} (overlap={overlap})");
        }
        pipe.shutdown();
    }
}

#[test]
fn prefill_long_prompt_multi_chunk() {
    // A prompt longer than the largest chunk bucket (8) must round-trip
    // through multiple PrefillChunk messages and still match the
    // teacher-forced decode path.
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let prompt: Vec<i32> = (0..37).map(|i| (i * 13 + 1) % 512).collect();
    let via_prefill = pipe.generate(0, &prompt, 8).unwrap();
    let via_decode = pipe.decode(&[prompt.clone()], 8).unwrap();
    pipe.shutdown();
    assert_eq!(via_prefill, via_decode[0]);
}

#[test]
fn serve_with_prefill_path() {
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts {
        use_prefill: true,
        ..PipelineOpts::new(artifacts_dir())
    })
    .unwrap();
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request {
            id: i,
            prompt_tokens: 10 + (i as usize % 4) * 9,
            gen_tokens: 2 + (i as usize % 3),
        })
        .collect();
    let metrics = pipe.serve(&reqs, 2).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 10);
}

#[test]
fn serve_slot_recycling_no_cross_contamination() {
    // More requests than slots: recycled slots must not leak stale KV.
    // After heavy slot churn a fresh decode must still match golden.
    if !have_artifacts() {
        return;
    }
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let reqs: Vec<Request> = (0..24)
        .map(|i| Request { id: i, prompt_tokens: 5, gen_tokens: 3 })
        .collect();
    let m = pipe.serve(&reqs, 2).unwrap();
    assert_eq!(m.requests_completed, 24);
    let g = load_golden();
    let out = pipe.decode(&g.prompts, g.steps).unwrap();
    pipe.shutdown();
    assert_eq!(out, g.generated);
}

#[test]
fn attention_worker_failover_preserves_decode() {
    // Paper §5: kill an attention worker mid-decode, respawn it, rebuild the
    // KV from prompt + already-generated tokens, and continue — the final
    // token stream must still equal the golden decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let prompt = &g.prompts[0];
    let want = &g.generated[0];
    let half = g.steps / 2;

    // first half of the decode
    let first_half = pipe.generate(0, prompt, half).unwrap();
    assert_eq!(&first_half, &want[..half]);

    // catastrophe: attention worker 1 dies, losing its head shard
    pipe.kill_attn_worker(1);

    // recovery: front-end replays prompt + generated tokens
    let mut known: Vec<i32> = prompt.clone();
    known.extend_from_slice(&first_half);
    pipe.recover_attn_worker(1, &[(0, known.clone())]).unwrap();

    // continue decoding the second half from the rebuilt cache
    let rest = pipe
        .generate(0, &known, g.steps - half)
        .unwrap();
    pipe.shutdown();
    assert_eq!(&rest, &want[half..], "post-failover tokens diverge");
}

#[test]
fn model_worker_failover_is_stateless() {
    // The leader (model worker) holds no request state: restarting the whole
    // pipeline and replaying from front-end history reproduces the decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let half = g.steps / 2;
    let first = pipe.generate(0, &g.prompts[0], half).unwrap();
    pipe.shutdown(); // model worker "fails"; KV is notionally lost with it

    let pipe2 = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let mut known = g.prompts[0].clone();
    known.extend_from_slice(&first);
    let rest = pipe2.generate(0, &known, g.steps - half).unwrap();
    pipe2.shutdown();
    assert_eq!(&rest, &g.generated[0][half..]);
}
