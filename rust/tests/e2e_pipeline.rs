//! End-to-end integration: the full three-layer stack.
//!
//! The Rust coordinator loads the AOT artifacts (L2 slices + L1 Pallas
//! attention lowered to HLO), spawns head-sharded attention workers, and
//! greedy-decodes the golden prompts through the request-lifecycle engine
//! (`submit`/`step`/`poll`/`drain`; `decode`/`generate`/`serve` are driver
//! loops over it). The produced tokens must equal `golden.json`, which
//! python generated with the *unsliced* reference model — proving slicing
//! + disaggregation + head sharding + (optionally) overlap + the
//! continuous-batching scheduler are all semantics-preserving.

use std::path::PathBuf;

use lamina::netsim::stack::NCCL;
use lamina::scheduler::{FinishReason, GroupMode, RequestState, SubmitError};
use lamina::trace::Request;
use lamina::util::json::Json;
use lamina::workers::{DisaggPipeline, PipelineOpts};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("golden.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

struct Golden {
    prompts: Vec<Vec<i32>>,
    steps: usize,
    generated: Vec<Vec<i32>>,
}

fn load_golden() -> Golden {
    let text = std::fs::read_to_string(artifacts_dir().join("golden.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let ivec = |v: &Json| -> Vec<i32> {
        v.as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect()
    };
    Golden {
        prompts: j.get("prompts").as_arr().unwrap().iter().map(ivec).collect(),
        steps: j.get("steps").as_usize().unwrap(),
        generated: j.get("generated").as_arr().unwrap().iter().map(ivec).collect(),
    }
}

fn run_golden(overlap: bool, attn_workers: usize) {
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let opts = PipelineOpts {
        overlap,
        attn_workers,
        ..PipelineOpts::new(artifacts_dir())
    };
    let mut pipe = DisaggPipeline::start(opts).expect("pipeline start");
    let out = pipe.decode(&g.prompts, g.steps).expect("decode");
    pipe.shutdown();
    assert_eq!(out, g.generated, "decoded tokens diverge from golden (overlap={overlap}, workers={attn_workers})");
}

#[test]
fn golden_decode_sequential_two_workers() {
    run_golden(false, 2);
}

#[test]
fn golden_decode_overlap_two_workers() {
    run_golden(true, 2);
}

#[test]
fn golden_decode_single_worker() {
    run_golden(false, 1);
}

#[test]
fn golden_decode_overlap_single_worker() {
    run_golden(true, 1);
}

#[test]
fn decode_batch_invariance() {
    // A prompt's decode must not depend on its batch-mates (KV isolation
    // across slots on the attention workers) — also the property that
    // makes continuous-batching output equal wave-mode output.
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let solo = pipe.decode(&[vec![7, 8, 9]], 6).unwrap();
    let pair = pipe
        .decode(&[vec![7, 8, 9], vec![100, 3, 100, 55]], 6)
        .unwrap();
    pipe.shutdown();
    assert_eq!(solo[0], pair[0]);
}

#[test]
fn decode_deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let a = pipe.decode(&[vec![5, 6]], 5).unwrap();
    let b = pipe.decode(&[vec![5, 6]], 5).unwrap();
    pipe.shutdown();
    assert_eq!(a, b);
}

#[test]
fn serve_small_trace_with_metrics() {
    // Continuous-batching serve over mixed-length requests, with paced NCCL
    // networking; verifies completions and sane metrics (including the new
    // per-request queue/TTFT aggregates).
    if !have_artifacts() {
        return;
    }
    let opts = PipelineOpts {
        stack: &NCCL,
        time_scale: 1.0, // real modelled network pacing
        ..PipelineOpts::new(artifacts_dir())
    };
    let mut pipe = DisaggPipeline::start(opts).unwrap();
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request {
            id: i,
            prompt_tokens: 3 + (i as usize % 5) * 7,
            gen_tokens: 2 + (i as usize % 4),
        })
        .collect();
    let metrics = pipe.serve(&reqs, 1).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 12);
    assert_eq!(metrics.rejected_submissions(), 0);
    // first tokens come out of the prefill pass (not decode steps), so the
    // decode-step token count is below the total generation volume
    assert!(metrics.tokens_generated > 0);
    assert!(metrics.throughput() > 0.0);
    assert!(metrics.mean_tbt() > 0.0);
    // per-request lifecycle metrics are populated
    assert!(metrics.mean_ttft_s() > 0.0);
    assert!(metrics.mean_request_tokens() >= 2.0);
}

#[test]
fn serve_capacity_scaled_by_waves() {
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request { id: i, prompt_tokens: 4, gen_tokens: 3 })
        .collect();
    let metrics = pipe.serve(&reqs, 2).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 10);
}

#[test]
fn wave_driver_matches_continuous_serve() {
    // The legacy wave-partitioned driver is a grouping change only: same
    // engine, same admission, same completions and token volume.
    if !have_artifacts() {
        return;
    }
    let reqs: Vec<Request> = (0..14)
        .map(|i| Request {
            id: i,
            prompt_tokens: 2 + (i as usize % 6) * 4,
            gen_tokens: 1 + (i as usize % 5),
        })
        .collect();
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let cont = pipe.serve(&reqs, 2).unwrap();
    let wave = pipe.serve_waves(&reqs, 2).unwrap();
    pipe.shutdown();
    assert_eq!(cont.requests_completed, wave.requests_completed);
    assert_eq!(cont.tokens_generated, wave.tokens_generated);
}

#[test]
fn oversized_context_rejected_per_request() {
    // Satellite: up-front whole-trace validation is gone; an invalid
    // request fails with a typed SubmitError at submit time and the rest
    // of the run proceeds.
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let max = pipe.config().max_seq - 1;
    let err = pipe.submit(vec![1; 17], 10_000).unwrap_err();
    assert!(matches!(err, SubmitError::ContextTooLong { max: m, .. } if m == max));
    assert_eq!(pipe.submit(vec![], 4), Err(SubmitError::EmptyPrompt));
    // one bad request no longer aborts the whole serve run
    let reqs = [
        Request { id: 0, prompt_tokens: 10_000, gen_tokens: 4 },
        Request { id: 1, prompt_tokens: 4, gen_tokens: 3 },
    ];
    let m = pipe.serve(&reqs, 1).unwrap();
    pipe.shutdown();
    assert_eq!(m.requests_completed, 1);
    assert_eq!(m.rejected_submissions(), 1);
}

#[test]
fn prefill_then_decode_matches_teacher_forced_golden() {
    // The chunked-prefill transition (paper §5) must be semantics-preserving:
    // generate(prompt) [prefill + decode] == the golden teacher-forced decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    for overlap in [false, true] {
        let mut pipe = DisaggPipeline::start(PipelineOpts {
            overlap,
            ..PipelineOpts::new(artifacts_dir())
        })
        .unwrap();
        for (i, (prompt, want)) in g.prompts.iter().zip(&g.generated).enumerate() {
            let out = pipe.generate(prompt, g.steps).unwrap();
            assert_eq!(&out, want, "prompt {i} (overlap={overlap})");
        }
        pipe.shutdown();
    }
}

#[test]
fn prefill_long_prompt_multi_chunk() {
    // A prompt longer than the largest chunk bucket (8) must round-trip
    // through multiple PrefillChunk messages and still match the
    // teacher-forced decode path.
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let prompt: Vec<i32> = (0..37).map(|i| (i * 13 + 1) % 512).collect();
    let via_prefill = pipe.generate(&prompt, 8).unwrap();
    let via_decode = pipe.decode(&[prompt.clone()], 8).unwrap();
    pipe.shutdown();
    assert_eq!(via_prefill, via_decode[0]);
}

#[test]
fn serve_with_prefill_path() {
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts {
        use_prefill: true,
        ..PipelineOpts::new(artifacts_dir())
    })
    .unwrap();
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request {
            id: i,
            prompt_tokens: 10 + (i as usize % 4) * 9,
            gen_tokens: 2 + (i as usize % 3),
        })
        .collect();
    let metrics = pipe.serve(&reqs, 2).unwrap();
    pipe.shutdown();
    assert_eq!(metrics.requests_completed, 10);
}

#[test]
fn serve_slot_recycling_no_cross_contamination() {
    // More requests than slots: recycled slots must not leak stale KV.
    // After heavy slot churn a fresh decode must still match golden.
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let reqs: Vec<Request> = (0..24)
        .map(|i| Request { id: i, prompt_tokens: 5, gen_tokens: 3 })
        .collect();
    let m = pipe.serve(&reqs, 2).unwrap();
    assert_eq!(m.requests_completed, 24);
    let g = load_golden();
    let out = pipe.decode(&g.prompts, g.steps).unwrap();
    pipe.shutdown();
    assert_eq!(out, g.generated);
}

// ---------------------------------------------------------------------------
// the request-lifecycle API itself (tentpole acceptance)
// ---------------------------------------------------------------------------

/// A scripted mixed-arrival session through submit/step/poll/drain must
/// produce bit-identical per-request tokens to the old wave path — here
/// asserted against (1) per-request solo generate (the strongest ground
/// truth: no batching at all) and (2) the legacy ByWave grouping, for both
/// attention backends. `tests/net_e2e.rs` covers the transport axis; the
/// engine-vs-native axis cannot share goldens (they agree to ~1e-5, not
/// bit-exact), so each backend is compared against its own solo runs.
#[test]
fn continuous_batching_bit_identical_to_wave_and_solo() {
    use lamina::kernels::AttnBackendKind;
    use lamina::net::TransportKind;
    if !have_artifacts() {
        return;
    }
    let prompts: Vec<Vec<i32>> = vec![
        vec![1, 7, 42, 99, 3],
        vec![5, 6],
        vec![11; 12],
        vec![9, 8, 7, 6],
        vec![2; 7],
        vec![3, 1, 4, 1, 5, 9],
    ];
    let gens = [6usize, 3, 5, 2, 4, 6];

    for backend in [AttnBackendKind::Engine, AttnBackendKind::Native] {
        // ground truth: each prompt alone (prefill + decode), no batching
        let mut solo = Vec::new();
        {
            let mut pipe = DisaggPipeline::start(PipelineOpts {
                attn_backend: backend,
                ..PipelineOpts::new(artifacts_dir())
            })
            .unwrap();
            for (p, &g) in prompts.iter().zip(&gens) {
                solo.push(pipe.generate(p, g).unwrap());
            }
            pipe.shutdown();
        }
        // grouping × transport: the scripted session must match the solo
        // ground truth bit-for-bit on every combination
        for (grouping, transport) in [
            (GroupMode::Packed, TransportKind::Inproc),
            (GroupMode::ByWave, TransportKind::Inproc),
            (GroupMode::Packed, TransportKind::Tcp),
        ] {
            let mut pipe = DisaggPipeline::start(PipelineOpts {
                attn_backend: backend,
                transport,
                slots: 2, // force real queueing + group churn
                ..PipelineOpts::new(artifacts_dir())
            })
            .unwrap();
            pipe.begin_session(grouping, 2).unwrap();
            // mixed arrivals: three up front, the rest joining mid-flight
            let mut ids = Vec::new();
            for i in 0..3 {
                ids.push(pipe.submit(prompts[i].clone(), gens[i]).unwrap());
            }
            for i in 3..prompts.len() {
                pipe.step().unwrap();
                pipe.step().unwrap();
                ids.push(pipe.submit(prompts[i].clone(), gens[i]).unwrap());
            }
            let metrics = pipe.drain().unwrap();
            assert_eq!(metrics.requests_completed, prompts.len() as u64);
            for (i, id) in ids.iter().enumerate() {
                let st = pipe.poll(*id).unwrap();
                assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
                assert_eq!(
                    st.tokens, solo[i],
                    "request {i} diverged ({backend:?}, {grouping:?}, {transport:?})"
                );
                assert!(st.queue_s.is_some() && st.ttft_s.is_some());
            }
            pipe.shutdown();
        }
    }
}

#[test]
fn step_outcomes_expose_the_lifecycle() {
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let id = pipe.submit(vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10], 2).unwrap();
    assert_eq!(pipe.poll(id).unwrap().state, RequestState::Queued);
    // first step admits and runs the first prefill chunk
    let o = pipe.step().unwrap();
    assert_eq!(o.admitted, 1);
    assert_eq!(o.prefilled, Some(id));
    assert!(!o.idle);
    assert_eq!(pipe.poll(id).unwrap().state, RequestState::Prefilling);
    // run to completion
    let m = pipe.drain().unwrap();
    assert_eq!(m.requests_completed, 1);
    let st = pipe.poll(id).unwrap();
    assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
    assert_eq!(st.tokens.len(), 2);
    // idle steps are no-ops
    let o = pipe.step().unwrap();
    assert!(o.idle && o.admitted == 0 && o.decoded_rows == 0);
    pipe.shutdown();
}

#[test]
fn cancel_mid_flight_frees_capacity() {
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let keep = pipe.submit(vec![1, 2, 3], 6).unwrap();
    let kill = pipe.submit(vec![4, 5, 6, 7], 40).unwrap(); // would run long
    pipe.step().unwrap();
    pipe.step().unwrap();
    assert!(pipe.cancel(kill));
    let m = pipe.drain().unwrap();
    // the cancelled request completes nothing; the other finishes normally
    assert_eq!(m.requests_completed, 1);
    assert_eq!(
        pipe.poll(kill).unwrap().state,
        RequestState::Finished(FinishReason::Cancelled)
    );
    assert_eq!(pipe.poll(keep).unwrap().tokens.len(), 6);
    // its KV really was retired on the workers
    let kv = pipe.kv_stats().unwrap();
    pipe.shutdown();
    assert_eq!(kv.blocks_in_use, 0, "cancelled request leaked KV blocks");
}

#[test]
fn drain_frees_all_kv_blocks() {
    // Satellite (c), pipeline half: after submit/retire churn and a drain,
    // no KvStats leaks — every block is back in the workers' pools.
    if !have_artifacts() {
        return;
    }
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let reqs: Vec<Request> = (0..16)
        .map(|i| Request {
            id: i,
            prompt_tokens: 4 + (i as usize % 6) * 5,
            gen_tokens: 1 + (i as usize % 4),
        })
        .collect();
    let m = pipe.serve(&reqs, 2).unwrap();
    assert_eq!(m.requests_completed, 16);
    let kv = pipe.kv_stats().unwrap();
    pipe.shutdown();
    assert_eq!(kv.blocks_in_use, 0, "leaked KV blocks after drain");
    assert_eq!(kv.bytes_in_use, 0, "leaked KV bytes after drain");
}

// ---------------------------------------------------------------------------
// fault tolerance (paper §5)
// ---------------------------------------------------------------------------

#[test]
fn attention_worker_failover_preserves_decode() {
    // Paper §5: kill an attention worker mid-decode, respawn it, rebuild the
    // KV from prompt + already-generated tokens, and continue — the final
    // token stream must still equal the golden decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let prompt = &g.prompts[0];
    let want = &g.generated[0];
    let half = g.steps / 2;

    // first half of the decode
    let first_half = pipe.generate(prompt, half).unwrap();
    assert_eq!(&first_half, &want[..half]);

    // catastrophe: attention worker 1 dies, losing its head shard
    pipe.kill_attn_worker(1);

    // recovery: front-end replays prompt + generated tokens into slot 0
    // (the rebuild path keeps the explicit-slot prefill)
    let mut known: Vec<i32> = prompt.clone();
    known.extend_from_slice(&first_half);
    pipe.recover_attn_worker(1, &[(0, known.clone())]).unwrap();

    // continue decoding the second half from the rebuilt cache
    let rest = pipe.generate(&known, g.steps - half).unwrap();
    pipe.shutdown();
    assert_eq!(&rest, &want[half..], "post-failover tokens diverge");
}

#[test]
fn model_worker_failover_is_stateless() {
    // The leader (model worker) holds no request state: restarting the whole
    // pipeline and replaying from front-end history reproduces the decode.
    if !have_artifacts() {
        return;
    }
    let g = load_golden();
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let half = g.steps / 2;
    let first = pipe.generate(&g.prompts[0], half).unwrap();
    pipe.shutdown(); // model worker "fails"; KV is notionally lost with it

    let mut pipe2 = DisaggPipeline::start(PipelineOpts::new(artifacts_dir())).unwrap();
    let mut known = g.prompts[0].clone();
    known.extend_from_slice(&first);
    let rest = pipe2.generate(&known, g.steps - half).unwrap();
    pipe2.shutdown();
    assert_eq!(&rest, &g.generated[0][half..]);
}
