//! Transport equivalence, end to end.
//!
//! Part 1 (always runs): a scripted decode+prefill-shaped `WireMsg` session
//! driven over BOTH transports — the paced in-process channel and real TCP
//! loopback sockets — must produce bit-identical replies, and the TCP
//! side's measured serialized bytes must dominate the logical
//! `wire_bytes()` model with a tightly bounded overhead ratio.
//!
//! Part 2 (needs `make artifacts`): the full tiny-model pipeline — greedy
//! decode, chunked prefill + decode, and a continuous-batching serve — run
//! under `--transport tcp` must match the in-process transport
//! token-for-token, with the measured-vs-logical report populated in
//! `ServeMetrics`, plus a KV-budget serve that exercises leader-side
//! admission deferral.

use std::path::PathBuf;

use lamina::kernels::AttnBackendKind;
use lamina::metrics::KvCacheStats;
use lamina::net::{inproc, tcp, MsgClass, Transport, TransportKind, WireStats};
use lamina::netsim::stack::{FHBN, LINE_RATE_400G};
use lamina::runtime::host::HostTensor;
use lamina::trace::Request;
use lamina::kvcache::KvDtype;
use lamina::workers::{
    run_attn_worker, AttnWorkerCfg, DisaggPipeline, ModelGeom, PipelineOpts, WireMsg, PAD_SLOT,
};

// ---------------------------------------------------------------------------
// Part 1: protocol-level session over both transports (no artifacts needed)
// ---------------------------------------------------------------------------

fn tensor(shape: &[usize], salt: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::f32(
        shape.to_vec(),
        (0..n).map(|i| salt + (i as f32) * 0.125 - (i % 7) as f32).collect(),
    )
}

/// Deterministic stand-in for an attention worker: combines StepQ+StepKv
/// (or a PrefillChunk) into an output tensor by pure arithmetic, so replies
/// depend only on the received bytes — any transport-level corruption or
/// reordering would change them.
fn scripted_worker<T: Transport>(link: T) {
    let mut pending_q: Option<HostTensor> = None;
    loop {
        match link.recv().expect("worker recv") {
            WireMsg::Shutdown => return,
            WireMsg::Retire { .. } => {}
            WireMsg::KvStatsReq => {
                let stats = KvCacheStats {
                    blocks_in_use: 3,
                    total_blocks: 8,
                    block_size: 16,
                    internal_waste_tokens: 1,
                    bytes_in_use: 3 * 4096,
                    total_bytes: 8 * 4096,
                    physical_blocks_in_use: 3,
                    physical_bytes_in_use: 3 * 4096,
                };
                link.send(WireMsg::KvStats { stats, epoch: 0 }).expect("worker send");
            }
            WireMsg::StepQ { q, .. } => pending_q = Some(q),
            WireMsg::StepKv { layer, k, v } => {
                let q = pending_q.take().expect("StepKv without StepQ");
                let out: Vec<f32> = q
                    .as_f32()
                    .iter()
                    .zip(k.as_f32().iter().cycle())
                    .zip(v.as_f32().iter().cycle())
                    .map(|((&a, &b), &c)| a + 2.0 * b - 0.5 * c)
                    .collect();
                let out = HostTensor::f32(q.shape().to_vec(), out);
                link.send(WireMsg::AttnOut { layer, out }).expect("worker send");
            }
            WireMsg::PrefillChunk { layer, q, k, v, cached, valid, .. } => {
                let bias = cached as f32 + valid as f32 * 0.25;
                let out: Vec<f32> = q
                    .as_f32()
                    .iter()
                    .zip(k.as_f32().iter().cycle())
                    .zip(v.as_f32().iter().cycle())
                    .map(|((&a, &b), &c)| a * 0.5 + b - c + bias)
                    .collect();
                let out = HostTensor::f32(q.shape().to_vec(), out);
                link.send(WireMsg::AttnOut { layer, out }).expect("worker send");
            }
            other => panic!("unexpected message {other:?}"),
        }
    }
}

/// Drive a fixed decode + chunked-prefill-shaped session over `leader`,
/// returning every reply (in order) plus the leader endpoint's wire stats.
fn run_session<T: Transport + 'static>(leader: T, worker: T) -> (Vec<WireMsg>, WireStats) {
    let h = std::thread::spawn(move || scripted_worker(worker));
    let mut replies = Vec::new();

    // decode steps: 3 layers × 2 steps
    for step in 0..2i32 {
        for layer in 0..3usize {
            let salt = (step * 10) as f32 + layer as f32;
            leader
                .send(WireMsg::StepQ {
                    layer,
                    slots: vec![0, 1, u32::MAX, 3],
                    q: tensor(&[4, 8, 16], salt),
                    lens: vec![step, step, 0, step + 2],
                    seq_bucket: 64,
                    overlap: false,
                })
                .unwrap();
            leader
                .send(WireMsg::StepKv {
                    layer,
                    k: tensor(&[4, 4, 16], salt + 0.5),
                    v: tensor(&[4, 4, 16], salt - 0.5),
                })
                .unwrap();
            replies.push(leader.recv().unwrap());
        }
    }

    // chunked prefill: 2 chunks on one slot
    for (chunk, cached) in [(0i32, 0i32), (1, 8)] {
        leader
            .send(WireMsg::PrefillChunk {
                layer: 0,
                slot: 2,
                q: tensor(&[8, 8, 16], 100.0 + chunk as f32),
                k: tensor(&[8, 4, 16], 200.0 + chunk as f32),
                v: tensor(&[8, 4, 16], 300.0 + chunk as f32),
                cached,
                valid: 8,
                seq_bucket: 64,
            })
            .unwrap();
        replies.push(leader.recv().unwrap());
    }

    // KV control plane
    leader.send(WireMsg::KvStatsReq).unwrap();
    replies.push(leader.recv().unwrap());
    leader.send(WireMsg::Retire { slot: 2 }).unwrap();

    leader.send(WireMsg::Shutdown).unwrap();
    h.join().unwrap();
    let stats = leader.stats();
    (replies, stats)
}

#[test]
fn session_bit_identical_across_transports() {
    let (inproc_leader, inproc_worker) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
    let (tcp_leader, tcp_worker) = tcp::pair().expect("loopback pair");

    let (replies_inproc, stats_inproc) = run_session(inproc_leader, inproc_worker);
    let (replies_tcp, stats_tcp) = run_session(tcp_leader, tcp_worker);

    // bit-identical replies: serialize→socket→deserialize changed nothing
    assert_eq!(replies_inproc.len(), replies_tcp.len());
    for (i, (a, b)) in replies_inproc.iter().zip(&replies_tcp).enumerate() {
        assert_eq!(a, b, "reply {i} diverged between transports");
    }

    // both endpoints saw identical logical traffic
    assert_eq!(stats_inproc.total().msgs, stats_tcp.total().msgs);
    assert_eq!(stats_inproc.total().logical_bytes, stats_tcp.total().logical_bytes);
    // the in-process link serializes nothing
    assert_eq!(stats_inproc.total().serialized_bytes, 0);
    assert_eq!(stats_inproc.overhead_ratio(), None);

    // TCP measured ≥ logical on every class that saw traffic…
    for (class, c) in stats_tcp.iter() {
        if c.msgs == 0 {
            continue;
        }
        assert!(
            c.serialized_bytes >= c.logical_bytes,
            "{}: measured {} < logical {}",
            class.name(),
            c.serialized_bytes,
            c.logical_bytes
        );
    }
    // …and on tensor-bearing classes the framing overhead is tiny
    for class in [MsgClass::StepQ, MsgClass::StepKv, MsgClass::Prefill, MsgClass::AttnOut] {
        let c = stats_tcp.class(class);
        assert!(c.msgs > 0, "{} must have traffic", class.name());
        let ratio = c.serialized_bytes as f64 / c.logical_bytes as f64;
        assert!(
            (1.0..1.15).contains(&ratio),
            "{}: overhead ratio {ratio:.4} out of bounds",
            class.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Part 1b: a REAL attention worker on the native backend, artifact-free.
// The worker runs `run_attn_worker` with `--attn-backend native` semantics
// (pure-Rust block-table kernel; no PJRT, no artifacts, geometry handed in
// explicitly) and is driven through a full decode + overlap + chunked-
// prefill + KV-lifecycle session over BOTH transports. Replies must be
// bit-identical: the native kernel is deterministic and the TCP codec is
// bit-preserving.
// ---------------------------------------------------------------------------

fn native_worker_cfg(kv_dtype: KvDtype) -> AttnWorkerCfg {
    AttnWorkerCfg {
        // deliberately nonexistent: the native backend must not need it
        artifacts_dir: PathBuf::from("artifacts-does-not-exist"),
        shard: 0,
        n_shards: 1,
        slots: 4,
        kv_block_size: 4,
        kv_dtype,
        backend: AttnBackendKind::Native,
        geom: Some(ModelGeom { layers: 2, kv_heads: 4, head_dim: 16, max_seq: 64 }),
        trust_welcome: false,
    }
}

/// Drive a full session against a real native-backend worker: chunked
/// prefill on slot 0, decode steps (both plain and overlap mode) over a
/// padded wave, and the KV control plane. Returns every reply in order.
fn run_native_session<T: Transport + 'static>(leader: T, worker: T, dtype: KvDtype) -> Vec<WireMsg> {
    let cfg = native_worker_cfg(dtype);
    let h = std::thread::spawn(move || run_attn_worker(cfg, worker));
    let mut replies = Vec::new();

    // membership handshake: the worker opens with Hello and only joins the
    // data plane after a geometry-carrying Welcome
    match leader.recv().unwrap() {
        WireMsg::Hello { codec_version, .. } => {
            assert_eq!(codec_version, lamina::net::codec::FORMAT_VERSION as u32);
        }
        other => panic!("expected Hello, got {other:?}"),
    }
    leader
        .send(WireMsg::Welcome {
            epoch: 1,
            kv_start: 0,
            kv_count: 4,
            slots: 4,
            kv_block_size: 4,
            layers: 2,
            head_dim: 16,
            max_seq: 64,
        })
        .unwrap();

    // chunked prefill: 2 chunks × 3 tokens on slot 0, both layers each
    let mut cached = 0i32;
    for chunk in 0..2i32 {
        for layer in 0..2usize {
            let salt = 50.0 + chunk as f32 * 4.0 + layer as f32;
            leader
                .send(WireMsg::PrefillChunk {
                    layer,
                    slot: 0,
                    q: tensor(&[3, 8, 16], salt),
                    k: tensor(&[3, 4, 16], salt + 0.25),
                    v: tensor(&[3, 4, 16], salt - 0.25),
                    cached,
                    valid: 3,
                    seq_bucket: 16,
                })
                .unwrap();
            replies.push(leader.recv().unwrap());
        }
        cached += 3;
    }

    // decode steps over a padded wave; overlap toggles per step (both the
    // attention path and the attn_prev+combine path cross the wire)
    let mut lens = [6i32, 0, 0];
    for step in 0..4i32 {
        let overlap = step % 2 == 1;
        for layer in 0..2usize {
            let salt = 7.0 + step as f32 * 3.0 + layer as f32;
            leader
                .send(WireMsg::StepQ {
                    layer,
                    slots: vec![0, 1, PAD_SLOT, 3],
                    q: tensor(&[4, 8, 16], salt),
                    lens: vec![lens[0], lens[1], 0, lens[2]],
                    seq_bucket: 16,
                    overlap,
                })
                .unwrap();
            leader
                .send(WireMsg::StepKv {
                    layer,
                    k: tensor(&[4, 4, 16], salt + 0.5),
                    v: tensor(&[4, 4, 16], salt - 0.5),
                })
                .unwrap();
            replies.push(leader.recv().unwrap());
        }
        for l in lens.iter_mut() {
            *l += 1;
        }
    }

    // KV control plane: occupancy, retire, occupancy again (ordered wire)
    leader.send(WireMsg::KvStatsReq).unwrap();
    replies.push(leader.recv().unwrap());
    leader.send(WireMsg::Retire { slot: 0 }).unwrap();
    leader.send(WireMsg::KvStatsReq).unwrap();
    replies.push(leader.recv().unwrap());

    leader.send(WireMsg::Shutdown).unwrap();
    h.join().unwrap();
    replies
}

#[test]
fn native_backend_full_session_artifact_free_over_both_transports() {
    let (inproc_leader, inproc_worker) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
    let (tcp_leader, tcp_worker) = tcp::pair().expect("loopback pair");

    let replies_inproc = run_native_session(inproc_leader, inproc_worker, KvDtype::F32);
    let replies_tcp = run_native_session(tcp_leader, tcp_worker, KvDtype::F32);

    assert_eq!(replies_inproc.len(), replies_tcp.len());
    for (i, (a, b)) in replies_inproc.iter().zip(&replies_tcp).enumerate() {
        // no WorkerError slipped in as a "reply"
        assert!(
            matches!(a, WireMsg::AttnOut { .. } | WireMsg::KvStats { .. }),
            "reply {i} is {a:?}"
        );
        assert_eq!(a, b, "native reply {i} diverged between transports");
    }

    // the KV lifecycle really happened: before the retire the worker held
    // blocks for slot 0 (6 prefill + 4 decode = 10 tokens → 3 blocks of 4)
    // plus slots 1 and 3 (4 tokens → 1 block each); after retiring slot 0
    // its 3 blocks are back in the pool
    let WireMsg::KvStats { stats: before, .. } = &replies_inproc[replies_inproc.len() - 2] else {
        panic!("expected KvStats");
    };
    let WireMsg::KvStats { stats: after, .. } = &replies_inproc[replies_inproc.len() - 1] else {
        panic!("expected KvStats");
    };
    assert_eq!(before.blocks_in_use, 3 + 1 + 1);
    assert_eq!(after.blocks_in_use, 2);
    // the byte view agrees with the block view: 2 layers × (2·KH_s·region)
    // per block at f32 (4·16·4 B regions, KH_s = 4)
    let block_bytes = 2 * 2 * 4 * (4 * 16 * 4);
    assert_eq!(before.bytes_in_use, 5 * block_bytes);
    assert_eq!(after.bytes_in_use, 2 * block_bytes);
}

/// The same artifact-free session on quantized workers: the wire protocol
/// is unchanged (all tensors still f32), both transports stay
/// bit-identical to each other, outputs stay close to the f32-storage
/// session, and the KvStats byte view shrinks 2×/≈4× at the same block
/// occupancy.
#[test]
fn native_backend_quantized_session_over_both_transports() {
    let (l32, w32) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
    let base = run_native_session(l32, w32, KvDtype::F32);
    let WireMsg::KvStats { stats: base_before, .. } = &base[base.len() - 2] else {
        panic!("expected KvStats");
    };

    // int8 at this geometry: 4·16 B codes + 4 B scale per region vs 256 B
    // f32 → 3.76× (the scale overhead is proportionally larger at small
    // blocks; the big-block bench rows clear ≥3.9×)
    for (dtype, min_cut) in [(KvDtype::F16, 2.0), (KvDtype::Int8, 3.7)] {
        let (inproc_leader, inproc_worker) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
        let (tcp_leader, tcp_worker) = tcp::pair().expect("loopback pair");
        let a = run_native_session(inproc_leader, inproc_worker, dtype);
        let b = run_native_session(tcp_leader, tcp_worker, dtype);
        assert_eq!(a, b, "kv={} replies diverged between transports", dtype.name());

        // every attention reply is a real finite f32 tensor of the same
        // shape as the f32-storage session (numeric error bounds are
        // asserted with controlled inputs in tests/kernel_native.rs; this
        // session's large synthetic magnitudes only validate the protocol)
        for (i, (qa, qb)) in a.iter().zip(&base).enumerate() {
            if let (WireMsg::AttnOut { out: oa, .. }, WireMsg::AttnOut { out: ob, .. }) = (qa, qb) {
                assert_eq!(oa.shape(), ob.shape(), "kv={} reply {i} shape", dtype.name());
                assert!(
                    oa.as_f32().iter().all(|x| x.is_finite()),
                    "kv={} reply {i} must stay finite",
                    dtype.name()
                );
            }
        }

        // same blocks, fewer bytes
        let WireMsg::KvStats { stats, .. } = &a[a.len() - 2] else { panic!("expected KvStats") };
        assert_eq!(stats.blocks_in_use, base_before.blocks_in_use);
        let cut = base_before.bytes_in_use as f64 / stats.bytes_in_use as f64;
        assert!(
            cut >= min_cut,
            "kv={} bytes_in_use cut {cut:.2}× < {min_cut}×",
            dtype.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Part 2: the real tiny-model pipeline over TCP (needs artifacts)
// ---------------------------------------------------------------------------

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping net e2e pipeline test: run `make artifacts` first");
    }
    ok
}

fn opts_with(transport: TransportKind) -> PipelineOpts {
    PipelineOpts { transport, ..PipelineOpts::new(artifacts_dir()) }
}

#[test]
fn tcp_pipeline_decode_and_prefill_bit_identical_to_inproc() {
    if !have_artifacts() {
        return;
    }
    let prompts: Vec<Vec<i32>> = vec![vec![1, 7, 42, 99, 3], vec![5, 6], vec![11; 12]];
    let steps = 6;

    let mut decoded: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut generated: Vec<Vec<i32>> = Vec::new();
    for transport in [TransportKind::Inproc, TransportKind::Tcp] {
        let mut pipe = DisaggPipeline::start(opts_with(transport)).expect("pipeline start");
        decoded.push(pipe.decode(&prompts, steps).expect("decode"));
        // chunked prefill + decode (the paper's transition protocol)
        generated.push(pipe.generate(&prompts[2], steps).expect("generate"));
        // TCP must actually have serialized traffic
        let wire = pipe.wire_stats().total();
        match transport {
            TransportKind::Inproc => assert_eq!(wire.serialized_bytes, 0),
            TransportKind::Tcp => {
                assert!(wire.serialized_bytes > wire.logical_bytes);
                // bucket-1 decode steps carry small tensors, so framing
                // overhead is at its worst here; still tightly bounded
                assert!(wire.serialized_bytes as f64 / wire.logical_bytes as f64 < 1.35);
            }
        }
        pipe.shutdown();
    }
    assert_eq!(decoded[0], decoded[1], "decode tokens diverge across transports");
    assert_eq!(generated[0], generated[1], "prefill+decode diverges across transports");
}

#[test]
fn tcp_serve_session_reports_measured_vs_logical() {
    if !have_artifacts() {
        return;
    }
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request {
            id: i,
            prompt_tokens: 3 + (i as usize % 4) * 5,
            gen_tokens: 2 + (i as usize % 3),
        })
        .collect();

    let mut inproc_pipe = DisaggPipeline::start(opts_with(TransportKind::Inproc)).unwrap();
    let m_inproc = inproc_pipe.serve(&reqs, 1).unwrap();
    inproc_pipe.shutdown();

    let mut tcp_pipe = DisaggPipeline::start(opts_with(TransportKind::Tcp)).unwrap();
    let m_tcp = tcp_pipe.serve(&reqs, 1).unwrap();
    tcp_pipe.shutdown();

    // same workload semantics over either wire
    assert_eq!(m_inproc.requests_completed, m_tcp.requests_completed);
    assert_eq!(m_inproc.tokens_generated, m_tcp.tokens_generated);

    // the serve metrics carry the per-class measured-vs-logical report
    let wire = m_tcp.wire_stats();
    for (class, c) in wire.iter() {
        if c.msgs == 0 {
            continue;
        }
        assert!(c.serialized_bytes >= c.logical_bytes, "{} under-measured", class.name());
    }
    let ratio = wire.overhead_ratio().expect("tcp serve must measure bytes");
    assert!((1.0..1.35).contains(&ratio), "overhead ratio {ratio:.4}");
    assert_eq!(m_inproc.wire_stats().overhead_ratio(), None);
}

#[test]
fn kv_budget_defers_admissions_but_completes() {
    if !have_artifacts() {
        return;
    }
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request { id: i, prompt_tokens: 9 + (i as usize % 3) * 8, gen_tokens: 3 })
        .collect();
    // legacy block-denominated budget, sized so only ~2 requests fit
    // concurrently (block_size 16)
    let opts = PipelineOpts { kv_block_budget: Some(4), ..opts_with(TransportKind::Inproc) };
    let mut pipe = DisaggPipeline::start(opts).unwrap();
    let m = pipe.serve(&reqs, 1).unwrap();
    pipe.shutdown();
    assert_eq!(m.requests_completed, 12, "budget must defer, not drop");
    assert!(m.deferred_admissions() > 0, "tight budget must defer admissions");
    // the budget kept worker residency bounded: peak blocks (summed over
    // the 2 workers) within budget × workers
    assert!(m.kv_peak_blocks() <= 4 * 2, "peak {} blocks", m.kv_peak_blocks());
    // the metrics report the budget in BOTH units
    assert_eq!(m.kv_budget_blocks(), Some(4));
    assert!(m.kv_budget_bytes().unwrap() > 0);
}

#[test]
fn kv_byte_budget_equivalent_to_blocks_and_reported() {
    // Satellite: byte-denominated --kv-budget. A byte budget worth exactly
    // 4 blocks must behave like the 4-block legacy budget (defer, bound
    // residency, complete everything) and report both units.
    if !have_artifacts() {
        return;
    }
    // probe the per-worker per-block byte size from a pool snapshot
    let probe = DisaggPipeline::start(opts_with(TransportKind::Inproc)).unwrap();
    let snap = probe.kv_stats().unwrap();
    let block_bytes = snap.total_bytes / snap.total_blocks.max(1);
    probe.shutdown();
    assert!(block_bytes > 0);

    let reqs: Vec<Request> = (0..12)
        .map(|i| Request { id: i, prompt_tokens: 9 + (i as usize % 3) * 8, gen_tokens: 3 })
        .collect();
    let opts = PipelineOpts {
        kv_byte_budget: Some(4 * block_bytes),
        ..opts_with(TransportKind::Inproc)
    };
    let mut pipe = DisaggPipeline::start(opts).unwrap();
    let m = pipe.serve(&reqs, 1).unwrap();
    pipe.shutdown();
    assert_eq!(m.requests_completed, 12, "byte budget must defer, not drop");
    assert!(m.deferred_admissions() > 0, "tight byte budget must defer admissions");
    assert!(m.kv_peak_blocks() <= 4 * 2, "peak {} blocks", m.kv_peak_blocks());
    assert_eq!(m.kv_budget_bytes(), Some(4 * block_bytes));
    assert_eq!(m.kv_budget_blocks(), Some(4));
}
