//! `net::codec` property tests: every `WireMsg` variant must round-trip
//! bit-identically through the frame format under randomized shapes,
//! dtypes, empty tensors and max-size control vectors — and corrupted or
//! short-read input must yield a typed decode error (or "need more
//! bytes"), never a panic. Uses the in-repo PRNG (no proptest offline).

use lamina::metrics::KvCacheStats;
use lamina::net::codec::{self, CodecError};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;
use lamina::workers::WireMsg;

/// Random tensor with 1–4 dims (dims may be zero → empty tensors) in a
/// random dtype.
fn rand_tensor(rng: &mut Rng) -> HostTensor {
    let ndim = rng.usize(1, 5);
    let shape: Vec<usize> = (0..ndim)
        .map(|_| if rng.chance(0.1) { 0 } else { rng.usize(1, 9) })
        .collect();
    let n: usize = shape.iter().product();
    if rng.chance(0.25) {
        let data: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32).collect();
        HostTensor::i32(shape, data)
    } else {
        // finite, non-NaN values so PartialEq is exact
        let data: Vec<f32> = (0..n).map(|_| (rng.next_u64() as i32 as f32) * 0.5).collect();
        HostTensor::f32(shape, data)
    }
}

fn rand_msg(rng: &mut Rng) -> WireMsg {
    match rng.usize(0, 9) {
        0 => {
            let rows = rng.usize(0, 5);
            WireMsg::StepQ {
                layer: rng.usize(0, 1 << 16),
                slots: (0..rows).map(|_| rng.next_u64() as u32).collect(),
                q: rand_tensor(rng),
                lens: (0..rows).map(|_| rng.next_u64() as i32).collect(),
                seq_bucket: rng.usize(0, 1 << 20),
                overlap: rng.chance(0.5),
            }
        }
        1 => WireMsg::StepKv { layer: rng.usize(0, 99), k: rand_tensor(rng), v: rand_tensor(rng) },
        2 => WireMsg::PrefillChunk {
            layer: rng.usize(0, 99),
            slot: rng.next_u64() as u32,
            q: rand_tensor(rng),
            k: rand_tensor(rng),
            v: rand_tensor(rng),
            cached: rng.next_u64() as i32,
            valid: rng.usize(0, 1 << 20),
            seq_bucket: rng.usize(0, 1 << 20),
        },
        3 => WireMsg::AttnOut { layer: rng.usize(0, 99), out: rand_tensor(rng) },
        4 => WireMsg::Retire { slot: rng.next_u64() as u32 },
        5 => WireMsg::KvStatsReq,
        6 => WireMsg::KvStats {
            stats: KvCacheStats {
                blocks_in_use: rng.usize(0, 1 << 30),
                total_blocks: rng.usize(0, 1 << 30),
                block_size: rng.usize(0, 1 << 16),
                internal_waste_tokens: rng.usize(0, 1 << 30),
                bytes_in_use: rng.usize(0, 1 << 40),
                total_bytes: rng.usize(0, 1 << 40),
            },
        },
        7 => {
            let n = rng.usize(0, 200);
            let text: String = (0..n).map(|_| char::from(b'a' + (rng.usize(0, 26) as u8))).collect();
            WireMsg::WorkerError { msg: text }
        }
        _ => WireMsg::Shutdown,
    }
}

#[test]
fn prop_every_variant_roundtrips_bit_identically() {
    let mut rng = Rng::new(0xc0dec);
    for case in 0..500 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        let n = codec::encode(&msg, &mut buf);
        assert_eq!(n, buf.len(), "case {case}: frame length");
        assert_eq!(n, codec::encoded_len(&msg), "case {case}: encoded_len model");
        let (got, used) = codec::decode_frame(&buf)
            .unwrap_or_else(|e| panic!("case {case}: decode error {e}"))
            .expect("complete frame");
        assert_eq!(used, n, "case {case}: consumed bytes");
        assert_eq!(got, msg, "case {case}: payload diverged");
    }
}

#[test]
fn max_size_control_vectors_roundtrip() {
    // slots/lens at the protocol's practical maximum (one entry per batch
    // row of the largest bucket, here pushed far beyond: 4096 entries)
    let rows = 4096;
    let msg = WireMsg::StepQ {
        layer: usize::from(u16::MAX),
        slots: (0..rows as u32).rev().collect(),
        q: HostTensor::zeros_f32(vec![rows, 1, 8]),
        lens: (0..rows as i32).map(|i| i - 2048).collect(),
        seq_bucket: 1 << 20,
        overlap: true,
    };
    let mut buf = Vec::new();
    codec::encode(&msg, &mut buf);
    let (got, _) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(got, msg);
}

#[test]
fn empty_tensor_and_empty_vectors_roundtrip() {
    let msg = WireMsg::StepQ {
        layer: 0,
        slots: Vec::new(),
        q: HostTensor::f32(vec![0, 4, 8], Vec::new()),
        lens: Vec::new(),
        seq_bucket: 0,
        overlap: false,
    };
    let mut buf = Vec::new();
    codec::encode(&msg, &mut buf);
    let (got, _) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(got, msg);
}

#[test]
fn prop_short_reads_ask_for_more_never_panic() {
    let mut rng = Rng::new(0x5caff);
    for _ in 0..50 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        // every strict prefix is "incomplete", not an error
        for cut in [0, 1, 3, 4, 11, buf.len().saturating_sub(1)] {
            let cut = cut.min(buf.len().saturating_sub(1));
            assert_eq!(
                codec::decode_frame(&buf[..cut]).expect("prefix must not error"),
                None,
                "prefix len {cut} of {}",
                buf.len()
            );
        }
    }
}

#[test]
fn prop_corrupted_frames_error_not_panic() {
    let mut rng = Rng::new(0xbadf00d);
    for case in 0..200 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        let i = rng.usize(0, buf.len());
        let bit = 1u8 << rng.usize(0, 8);
        let mut bad = buf.clone();
        bad[i] ^= bit;
        // a flipped bit may make the frame corrupt (Err), or — when it hits
        // the length field — merely incomplete (Ok(None)); it must never
        // decode as a valid frame, and must never panic
        match codec::decode_frame(&bad) {
            Ok(Some((got, _))) => {
                assert_ne!(got, msg, "case {case}: corruption at byte {i} went unnoticed")
            }
            Ok(None) | Err(_) => {}
        }
    }
}

#[test]
fn specific_corruptions_have_typed_errors() {
    let mut buf = Vec::new();
    codec::encode(&WireMsg::Retire { slot: 9 }, &mut buf);

    let mut bad_magic = buf.clone();
    bad_magic[0] ^= 0xff;
    assert!(matches!(codec::decode_frame(&bad_magic), Err(CodecError::BadMagic(_))));

    let mut bad_version = buf.clone();
    bad_version[2] = 99;
    assert!(matches!(codec::decode_frame(&bad_version), Err(CodecError::BadVersion(99))));

    // the checksum covers the type tag, so a flipped tag is caught even
    // though the payload bytes are untouched
    let mut bad_tag = buf.clone();
    bad_tag[3] = 8; // Shutdown's tag
    assert!(matches!(codec::decode_frame(&bad_tag), Err(CodecError::BadChecksum { .. })));

    let mut bad_payload = buf;
    let last = bad_payload.len() - 1;
    bad_payload[last] ^= 0x01;
    assert!(matches!(
        codec::decode_frame(&bad_payload),
        Err(CodecError::BadChecksum { .. })
    ));
}

#[test]
fn giant_length_field_rejected_without_allocation() {
    let mut buf = Vec::new();
    codec::encode(&WireMsg::Shutdown, &mut buf);
    // claim a multi-GiB payload: must be rejected as malformed, not
    // buffered for ("need more bytes") or allocated
    buf[4..8].copy_from_slice(&(u32::MAX).to_le_bytes());
    assert!(matches!(codec::decode_frame(&buf), Err(CodecError::Malformed(_))));
}
