//! `net::codec` property tests: every `WireMsg` variant must round-trip
//! bit-identically through the frame format under randomized shapes,
//! dtypes, empty tensors and max-size control vectors — and corrupted,
//! truncated or short-read input must yield a typed decode error (or
//! "need more bytes"), never a panic and never a silent wrong decode.
//! Corruption coverage spans single-bit flips, multi-byte rewrites,
//! lying length fields, random truncation points, and corruption inside
//! a later frame of a batched stream. Uses the in-repo PRNG (no
//! proptest offline).

use lamina::metrics::KvCacheStats;
use lamina::net::codec::{self, CodecError};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;
use lamina::workers::WireMsg;

/// Random tensor with 1–4 dims (dims may be zero → empty tensors) in a
/// random dtype.
fn rand_tensor(rng: &mut Rng) -> HostTensor {
    let ndim = rng.usize(1, 5);
    let shape: Vec<usize> = (0..ndim)
        .map(|_| if rng.chance(0.1) { 0 } else { rng.usize(1, 9) })
        .collect();
    let n: usize = shape.iter().product();
    if rng.chance(0.25) {
        let data: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32).collect();
        HostTensor::i32(shape, data)
    } else {
        // finite, non-NaN values so PartialEq is exact
        let data: Vec<f32> = (0..n).map(|_| (rng.next_u64() as i32 as f32) * 0.5).collect();
        HostTensor::f32(shape, data)
    }
}

fn rand_msg(rng: &mut Rng) -> WireMsg {
    match rng.usize(0, 12) {
        0 => {
            let rows = rng.usize(0, 5);
            WireMsg::StepQ {
                layer: rng.usize(0, 1 << 16),
                slots: (0..rows).map(|_| rng.next_u64() as u32).collect(),
                q: rand_tensor(rng),
                lens: (0..rows).map(|_| rng.next_u64() as i32).collect(),
                seq_bucket: rng.usize(0, 1 << 20),
                overlap: rng.chance(0.5),
            }
        }
        1 => WireMsg::StepKv { layer: rng.usize(0, 99), k: rand_tensor(rng), v: rand_tensor(rng) },
        2 => WireMsg::PrefillChunk {
            layer: rng.usize(0, 99),
            slot: rng.next_u64() as u32,
            q: rand_tensor(rng),
            k: rand_tensor(rng),
            v: rand_tensor(rng),
            cached: rng.next_u64() as i32,
            valid: rng.usize(0, 1 << 20),
            seq_bucket: rng.usize(0, 1 << 20),
        },
        3 => WireMsg::AttnOut { layer: rng.usize(0, 99), out: rand_tensor(rng) },
        4 => WireMsg::Retire { slot: rng.next_u64() as u32 },
        5 => WireMsg::KvStatsReq,
        6 => WireMsg::KvStats {
            stats: KvCacheStats {
                blocks_in_use: rng.usize(0, 1 << 30),
                total_blocks: rng.usize(0, 1 << 30),
                block_size: rng.usize(0, 1 << 16),
                internal_waste_tokens: rng.usize(0, 1 << 30),
                bytes_in_use: rng.usize(0, 1 << 40),
                total_bytes: rng.usize(0, 1 << 40),
                physical_blocks_in_use: rng.usize(0, 1 << 30),
                physical_bytes_in_use: rng.usize(0, 1 << 40),
            },
            epoch: rng.next_u64(),
        },
        7 => WireMsg::MapBlocks {
            slot: rng.next_u64() as u32,
            src_slot: rng.next_u64() as u32,
            tokens: rng.usize(0, 1 << 20),
        },
        8 => {
            let n = rng.usize(0, 200);
            let text: String = (0..n).map(|_| char::from(b'a' + (rng.usize(0, 26) as u8))).collect();
            WireMsg::WorkerError { msg: text }
        }
        9 => WireMsg::Hello {
            codec_version: rng.next_u64() as u32,
            shard: rng.next_u64() as u32,
        },
        10 => WireMsg::Welcome {
            epoch: rng.next_u64(),
            kv_start: rng.next_u64() as u32,
            kv_count: rng.next_u64() as u32,
            slots: rng.next_u64() as u32,
            kv_block_size: rng.next_u64() as u32,
            layers: rng.next_u64() as u32,
            head_dim: rng.next_u64() as u32,
            max_seq: rng.next_u64() as u32,
        },
        _ => WireMsg::Shutdown,
    }
}

#[test]
fn prop_every_variant_roundtrips_bit_identically() {
    let mut rng = Rng::new(0xc0dec);
    for case in 0..500 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        let n = codec::encode(&msg, &mut buf);
        assert_eq!(n, buf.len(), "case {case}: frame length");
        assert_eq!(n, codec::encoded_len(&msg), "case {case}: encoded_len model");
        let (got, used) = codec::decode_frame(&buf)
            .unwrap_or_else(|e| panic!("case {case}: decode error {e}"))
            .expect("complete frame");
        assert_eq!(used, n, "case {case}: consumed bytes");
        assert_eq!(got, msg, "case {case}: payload diverged");
    }
}

#[test]
fn max_size_control_vectors_roundtrip() {
    // slots/lens at the protocol's practical maximum (one entry per batch
    // row of the largest bucket, here pushed far beyond: 4096 entries)
    let rows = 4096;
    let msg = WireMsg::StepQ {
        layer: usize::from(u16::MAX),
        slots: (0..rows as u32).rev().collect(),
        q: HostTensor::zeros_f32(vec![rows, 1, 8]),
        lens: (0..rows as i32).map(|i| i - 2048).collect(),
        seq_bucket: 1 << 20,
        overlap: true,
    };
    let mut buf = Vec::new();
    codec::encode(&msg, &mut buf);
    let (got, _) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(got, msg);
}

#[test]
fn empty_tensor_and_empty_vectors_roundtrip() {
    let msg = WireMsg::StepQ {
        layer: 0,
        slots: Vec::new(),
        q: HostTensor::f32(vec![0, 4, 8], Vec::new()),
        lens: Vec::new(),
        seq_bucket: 0,
        overlap: false,
    };
    let mut buf = Vec::new();
    codec::encode(&msg, &mut buf);
    let (got, _) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(got, msg);
}

#[test]
fn prop_short_reads_ask_for_more_never_panic() {
    let mut rng = Rng::new(0x5caff);
    for _ in 0..50 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        // every strict prefix is "incomplete", not an error
        for cut in [0, 1, 3, 4, 11, buf.len().saturating_sub(1)] {
            let cut = cut.min(buf.len().saturating_sub(1));
            assert_eq!(
                codec::decode_frame(&buf[..cut]).expect("prefix must not error"),
                None,
                "prefix len {cut} of {}",
                buf.len()
            );
        }
    }
}

#[test]
fn prop_corrupted_frames_error_not_panic() {
    let mut rng = Rng::new(0xbadf00d);
    for case in 0..200 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        let i = rng.usize(0, buf.len());
        let bit = 1u8 << rng.usize(0, 8);
        let mut bad = buf.clone();
        bad[i] ^= bit;
        // a flipped bit may make the frame corrupt (Err), or — when it hits
        // the length field — merely incomplete (Ok(None)); it must never
        // decode as a valid frame, and must never panic
        match codec::decode_frame(&bad) {
            Ok(Some((got, _))) => {
                assert_ne!(got, msg, "case {case}: corruption at byte {i} went unnoticed")
            }
            Ok(None) | Err(_) => {}
        }
    }
}

#[test]
fn specific_corruptions_have_typed_errors() {
    let mut buf = Vec::new();
    codec::encode(&WireMsg::Retire { slot: 9 }, &mut buf);

    let mut bad_magic = buf.clone();
    bad_magic[0] ^= 0xff;
    assert!(matches!(codec::decode_frame(&bad_magic), Err(CodecError::BadMagic(_))));

    let mut bad_version = buf.clone();
    bad_version[2] = 99;
    assert!(matches!(codec::decode_frame(&bad_version), Err(CodecError::BadVersion(99))));

    // the checksum covers the type tag, so a flipped tag is caught even
    // though the payload bytes are untouched
    let mut bad_tag = buf.clone();
    bad_tag[3] = 8; // Shutdown's tag
    assert!(matches!(codec::decode_frame(&bad_tag), Err(CodecError::BadChecksum { .. })));

    let mut bad_payload = buf;
    let last = bad_payload.len() - 1;
    bad_payload[last] ^= 0x01;
    assert!(matches!(
        codec::decode_frame(&bad_payload),
        Err(CodecError::BadChecksum { .. })
    ));
}

#[test]
fn map_blocks_roundtrips_and_any_body_corruption_is_checksummed() {
    let msg = WireMsg::MapBlocks { slot: 7, src_slot: 3, tokens: 129 };
    let mut buf = Vec::new();
    codec::encode(&msg, &mut buf);
    // fixed 12-byte payload: exactly 12 bytes larger than an empty frame
    let mut empty = Vec::new();
    codec::encode(&WireMsg::Shutdown, &mut empty);
    assert_eq!(buf.len(), empty.len() + 12);
    let (got, used) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(used, buf.len());
    assert_eq!(got, msg);
    // every byte past the length field (checksum + payload) is covered:
    // flipping any of them must surface as a checksum mismatch, never a
    // silently different slot/src_slot/tokens mapping
    for i in 8..buf.len() {
        let mut bad = buf.clone();
        bad[i] ^= 0x40;
        assert!(
            matches!(codec::decode_frame(&bad), Err(CodecError::BadChecksum { .. })),
            "flipped byte {i} was not caught"
        );
    }
}

#[test]
fn prop_multibyte_mutations_never_panic_or_misdecode() {
    // harsher than single-bit flips: rewrite 1–8 random bytes to random
    // values (may hit magic, version, tag, length, checksum, or payload)
    let mut rng = Rng::new(0xf0e2);
    for case in 0..300 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        let mut bad = buf.clone();
        let hits = rng.usize(1, 9);
        let mut changed = false;
        for _ in 0..hits {
            let i = rng.usize(0, bad.len());
            let v = rng.next_u64() as u8;
            changed |= bad[i] != v;
            bad[i] = v;
        }
        match codec::decode_frame(&bad) {
            Ok(Some((got, _))) => {
                if changed {
                    assert_ne!(got, msg, "case {case}: mutation went unnoticed")
                }
            }
            Ok(None) | Err(_) => {}
        }
    }
}

#[test]
fn prop_random_truncations_are_incomplete_never_panic() {
    // any strict prefix — header-split, length-split, or mid-payload —
    // means "read more", never an error and never a partial decode
    let mut rng = Rng::new(0x7a011c);
    for _ in 0..100 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        for _ in 0..8 {
            let cut = rng.usize(0, buf.len());
            assert_eq!(
                codec::decode_frame(&buf[..cut]).expect("prefix must not error"),
                None,
                "prefix len {cut} of {}",
                buf.len()
            );
        }
    }
}

#[test]
fn prop_lying_length_fields_are_caught() {
    let mut rng = Rng::new(0x11e5);
    for case in 0..200 {
        let msg = rand_msg(&mut rng);
        let mut buf = Vec::new();
        codec::encode(&msg, &mut buf);
        let plen = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
        // understate: the decoder checksums a short payload — mismatch
        if plen > 0 {
            let mut lie = buf.clone();
            let short = rng.usize(0, plen as usize) as u32;
            lie[4..8].copy_from_slice(&short.to_le_bytes());
            match codec::decode_frame(&lie) {
                Ok(Some((got, _))) => {
                    assert_ne!(got, msg, "case {case}: understated length decoded as original")
                }
                Ok(None) | Err(_) => {}
            }
        }
        // overstate + pad garbage: the padded tail joins the checksummed
        // payload, so the original frame must not be reconstructed
        let mut lie = buf.clone();
        let pad = rng.usize(1, 64);
        lie[4..8].copy_from_slice(&(plen + pad as u32).to_le_bytes());
        for _ in 0..pad {
            let b = rng.next_u64() as u8;
            lie.push(b);
        }
        match codec::decode_frame(&lie) {
            Ok(Some((got, _))) => {
                assert_ne!(got, msg, "case {case}: overstated length decoded as original")
            }
            Ok(None) | Err(_) => {}
        }
    }
}

#[test]
fn corruption_in_second_frame_does_not_poison_the_first() {
    // batched writes put many frames in one buffer; a corrupt later frame
    // must not prevent decoding the intact frames before it
    let first = WireMsg::Retire { slot: 4 };
    let second = WireMsg::MapBlocks { slot: 9, src_slot: 4, tokens: 64 };
    let mut buf = Vec::new();
    codec::encode(&first, &mut buf);
    let split = buf.len();
    codec::encode(&second, &mut buf);
    let last = buf.len() - 1;
    buf[last] ^= 0x10; // corrupt the second frame's tail
    let (got, used) = codec::decode_frame(&buf).unwrap().unwrap();
    assert_eq!(got, first);
    assert_eq!(used, split);
    assert!(matches!(
        codec::decode_frame(&buf[used..]),
        Err(CodecError::BadChecksum { .. })
    ));
}

#[test]
fn giant_length_field_rejected_without_allocation() {
    let mut buf = Vec::new();
    codec::encode(&WireMsg::Shutdown, &mut buf);
    // claim a multi-GiB payload: must be rejected as malformed, not
    // buffered for ("need more bytes") or allocated
    buf[4..8].copy_from_slice(&(u32::MAX).to_le_bytes());
    assert!(matches!(codec::decode_frame(&buf), Err(CodecError::Malformed(_))));
}
