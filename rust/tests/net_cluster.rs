//! Real multi-host cluster e2e: spawn standalone `lamina-attn` worker
//! PROCESSES on 127.0.0.1 ephemeral ports and drive a full chaos session
//! (prefill + decode + retire, native backend, no artifacts) against
//! them, asserting the remote pool is bit-identical to the in-process
//! golden run — including across link severs (respawn re-dials the same
//! daemon) and a SIGKILLed subprocess (graceful degradation).
//!
//! These tests exercise the whole new-subsystem stack at once: the
//! `lamina-attn` accept loop, `Addr` parsing, `dial_worker`'s bounded
//! retry, the batched-envelope wire format crossing real sockets, and
//! the typed failure taxonomy when a peer is a separate OS process.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

use lamina::net::TransportKind;
use lamina::workers::{run_chaos, ChaosCfg};

/// Spawn one `lamina-attn` daemon on an ephemeral port and return it
/// with its bound address, parsed from the single stdout line the
/// binary contractually prints before serving.
fn spawn_daemon() -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_lamina-attn"))
        .args(["--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn lamina-attn");
    let stdout = child.stdout.take().expect("stdout piped");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("read the address line");
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .unwrap_or_default()
        .to_string();
    assert!(
        line.starts_with("lamina-attn listening on ") && addr.contains(':'),
        "unexpected stdout line from lamina-attn: {line:?}"
    );
    (child, addr)
}

/// Kills the daemon on drop so a failing assertion can't leak processes.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn() -> Daemon {
        let (child, addr) = spawn_daemon();
        Daemon { child, addr }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn remote_cfg(addrs: &[&str]) -> ChaosCfg {
    let mut cfg = ChaosCfg::default();
    cfg.transport = TransportKind::Tcp;
    cfg.workers = addrs.len();
    cfg.worker_addrs = Some(addrs.iter().map(|a| a.to_string()).collect());
    cfg
}

#[test]
fn remote_cluster_is_bit_identical_to_inproc() {
    let golden = run_chaos(&ChaosCfg::default()).expect("inproc golden");

    let d0 = Daemon::spawn();
    let d1 = Daemon::spawn();
    let cfg = remote_cfg(&[&d0.addr, &d1.addr]);
    let remote = run_chaos(&cfg).expect("remote session");

    assert_eq!(remote.worker_deaths, 0, "healthy cluster: no deaths");
    assert_eq!(remote.leaked_blocks, 0);
    assert_eq!(
        remote.outputs, golden.outputs,
        "2 real lamina-attn processes must reproduce the inproc session bit-for-bit"
    );
}

#[test]
fn severed_link_respawn_redials_the_same_daemon() {
    let golden = run_chaos(&ChaosCfg::default()).expect("inproc golden");

    let d0 = Daemon::spawn();
    let d1 = Daemon::spawn();
    let mut cfg = remote_cfg(&[&d0.addr, &d1.addr]);
    // sever worker 1's link at step boundary 3: the daemon's session ends
    // on the dropped socket, its accept loop returns to listening, and
    // respawn-style recovery re-dials the SAME address for a fresh
    // session (handshake + rebuilt arena)
    cfg.kill_at = vec![(3, 1)];
    let faulted = run_chaos(&cfg).expect("recovery through re-dial");

    assert!(faulted.worker_deaths >= 1, "the sever must be detected");
    assert!(faulted.recoveries >= 1);
    assert_eq!(faulted.final_workers, 2, "respawned at the same width");
    assert_eq!(faulted.leaked_blocks, 0);
    assert_eq!(faulted.outputs, golden.outputs, "re-dialed session must be bit-identical");
}

/// The subprocess the degrade test SIGKILLs mid-session; the `on_step`
/// hook is a plain fn pointer, so the victim rides a static.
static VICTIM: Mutex<Option<Child>> = Mutex::new(None);

fn sigkill_victim_at_step_5(step: usize) {
    if step == 5 {
        if let Some(mut c) = VICTIM.lock().unwrap().take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

#[test]
fn sigkilled_subprocess_degrades_bit_identically() {
    let mut golden_cfg = ChaosCfg::default();
    golden_cfg.workers = 3;
    let golden = run_chaos(&golden_cfg).expect("inproc golden at width 3");

    let d0 = Daemon::spawn();
    let d1 = Daemon::spawn();
    let (victim, victim_addr) = spawn_daemon();
    *VICTIM.lock().unwrap() = Some(victim);

    let mut cfg = remote_cfg(&[&d0.addr, &d1.addr, &victim_addr]);
    // no process left to re-dial → degradation is the only recovery
    cfg.allow_respawn = false;
    cfg.min_workers = 1;
    cfg.on_step = Some(sigkill_victim_at_step_5);
    let faulted = run_chaos(&cfg).expect("degrade to the survivors");

    assert!(faulted.worker_deaths >= 1, "the SIGKILL must be detected");
    assert_eq!(faulted.degrades, 1);
    assert_eq!(faulted.final_workers, 2, "pool degraded 3 -> 2");
    assert_eq!(faulted.leaked_blocks, 0, "zero leaked KV blocks after losing a process");
    assert_eq!(faulted.outputs, golden.outputs, "degraded output must be bit-identical");
}

#[test]
fn dialing_an_unreachable_worker_fails_typed() {
    // port 1 on loopback: refused immediately, so the bounded retry
    // ladder (not a hang) decides how long this takes
    let cfg = remote_cfg(&["127.0.0.1:1"]);
    let err = run_chaos(&cfg).expect_err("no daemon to dial");
    let msg = err.death.to_string();
    assert!(msg.contains("dial"), "typed dial failure, got: {msg}");
    assert!(msg.contains("127.0.0.1:1"), "names the address, got: {msg}");
    assert_eq!(err.leaked_blocks, 0);
}
