//! Paged-KV correctness: the block-paged arena must be **bit-identical** to
//! a dense zero-initialised reference cache under any interleaving of
//! decode appends, prefill chunks, slot reuse, retirement — and (ISSUE 6)
//! shared-prefix mapping with copy-on-write divergence: a `map_prefix`
//! mirror copies the donor's covering blocks in the dense model, after
//! which no interleaving of appends on either slot may let one slot
//! observe the other's writes, and refcounted retirement must return
//! every physical block exactly once. Checked here without PJRT
//! artifacts. Uses the in-repo PRNG (no proptest offline).

use lamina::kvcache::{kv_blocks_needed, ArenaCfg, KvDtype, PagedKvArena, PAD_SLOT};
use lamina::runtime::host::HostTensor;
use lamina::util::prng::Rng;

const LAYERS: usize = 3;
const KHS: usize = 2;
const HD: usize = 4;
const MAX_SEQ: usize = 64;
const SLOTS: usize = 6;
/// Keep sequences clear of MAX_SEQ so both paths stay in-protocol.
const LEN_CAP: usize = 48;

/// Dense mirror of the arena's semantics: per slot `[layers, KHS, MAX_SEQ,
/// HD]`, zeroed on reset, written at the same positions the arena writes.
struct DenseRef {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl DenseRef {
    fn new() -> DenseRef {
        let n = LAYERS * KHS * MAX_SEQ * HD;
        DenseRef {
            k: (0..SLOTS).map(|_| vec![0.0; n]).collect(),
            v: (0..SLOTS).map(|_| vec![0.0; n]).collect(),
        }
    }

    fn reset(&mut self, slot: u32) {
        self.k[slot as usize].fill(0.0);
        self.v[slot as usize].fill(0.0);
    }

    fn write(&mut self, slot: u32, layer: usize, pos: usize, kd: &[f32], vd: &[f32], src_row: usize) {
        for h in 0..KHS {
            let dst = ((layer * KHS + h) * MAX_SEQ + pos) * HD;
            let src = (src_row * KHS + h) * HD;
            self.k[slot as usize][dst..dst + HD].copy_from_slice(&kd[src..src + HD]);
            self.v[slot as usize][dst..dst + HD].copy_from_slice(&vd[src..src + HD]);
        }
    }

    fn append_step(&mut self, slots: &[u32], layer: usize, k: &HostTensor, v: &HostTensor, lens: &[i32]) {
        let (kd, vd) = (k.as_f32(), v.as_f32());
        for (b, &slot) in slots.iter().enumerate() {
            if slot == PAD_SLOT {
                continue;
            }
            let pos = lens[b] as usize;
            if layer == 0 && pos == 0 {
                self.reset(slot);
            }
            self.write(slot, layer, pos, kd, vd, b);
        }
    }

    fn append_chunk(&mut self, slot: u32, layer: usize, k: &HostTensor, v: &HostTensor, cached: usize, valid: usize) {
        let (kd, vd) = (k.as_f32(), v.as_f32());
        if layer == 0 && cached == 0 {
            self.reset(slot);
        }
        for i in 0..valid {
            self.write(slot, layer, cached + i, kd, vd, i);
        }
    }

    /// Dense mirror of `map_prefix`: the destination physically shares the
    /// donor's covering blocks, so it sees the donor's bytes for the whole
    /// covered range (`positions` = covering blocks × block size) — donor
    /// residue past the mapped token count included.
    fn map_from(&mut self, dst: u32, src: u32, positions: usize) {
        let sk = self.k[src as usize].clone();
        let sv = self.v[src as usize].clone();
        self.reset(dst);
        for layer in 0..LAYERS {
            for h in 0..KHS {
                let base = (layer * KHS + h) * MAX_SEQ * HD;
                let n = positions * HD;
                self.k[dst as usize][base..base + n].copy_from_slice(&sk[base..base + n]);
                self.v[dst as usize][base..base + n].copy_from_slice(&sv[base..base + n]);
            }
        }
    }

    fn gather(&self, slots: &[u32], layer: usize, bucket: usize, seq: usize) -> (Vec<f32>, Vec<f32>) {
        let row = KHS * seq * HD;
        let mut k = vec![0.0f32; bucket * row];
        let mut v = vec![0.0f32; bucket * row];
        for (b, &slot) in slots.iter().enumerate() {
            if slot == PAD_SLOT {
                continue;
            }
            for h in 0..KHS {
                let src = (layer * KHS + h) * MAX_SEQ * HD;
                let dst = b * row + h * seq * HD;
                let n = seq * HD;
                k[dst..dst + n].copy_from_slice(&self.k[slot as usize][src..src + n]);
                v[dst..dst + n].copy_from_slice(&self.v[slot as usize][src..src + n]);
            }
        }
        (k, v)
    }
}

fn rand_tensor(rng: &mut Rng, rows: usize) -> HostTensor {
    let data: Vec<f32> = (0..rows * KHS * HD).map(|_| rng.f64() as f32).collect();
    HostTensor::f32(vec![rows, KHS, HD], data)
}

/// Pick `n` distinct slots in random order.
fn pick_slots(rng: &mut Rng, n: usize) -> Vec<u32> {
    let mut all: Vec<u32> = (0..SLOTS as u32).collect();
    rng.shuffle(&mut all);
    all.truncate(n);
    all
}

fn check_gather(arena: &mut PagedKvArena, dense: &DenseRef, rng: &mut Rng, tag: &str) {
    let bucket = rng.usize(1, SLOTS + 1);
    let mut slots = pick_slots(rng, bucket);
    for s in slots.iter_mut() {
        if rng.chance(0.15) {
            *s = PAD_SLOT;
        }
    }
    let seq = [8usize, 16, 32, 64][rng.usize(0, 4)];
    let layer = rng.usize(0, LAYERS);
    let (pk, pv) = arena.gather(&slots, layer, bucket, seq);
    let (dk, dv) = dense.gather(&slots, layer, bucket, seq);
    assert_eq!(pk.shape(), &[bucket, KHS, seq, HD], "{tag}: gather shape");
    assert_eq!(pk.as_f32(), &dk[..], "{tag}: K diverges (layer {layer}, seq {seq})");
    assert_eq!(pv.as_f32(), &dv[..], "{tag}: V diverges (layer {layer}, seq {seq})");
}

/// With `cow`, ~7% of ops map a random prefix of one slot into another
/// (the prefix-cache hit path); returns whether physical sharing was ever
/// observed so the caller can assert coverage across repetitions.
fn run_case(seed: u64, block_size: usize, ops: usize, cow: bool) -> bool {
    let mut rng = Rng::new(seed);
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: LAYERS,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size,
        initial_blocks: 2, // force on-demand growth
        dtype: KvDtype::F32,
    });
    let mut dense = DenseRef::new();
    // the leader's view of each slot's cached length
    let mut lens = vec![0usize; SLOTS];
    let mut shared_seen = false;

    for op in 0..ops {
        let tag = format!("bs={block_size} seed={seed:#x} op={op}");
        match rng.usize(0, 100) {
            // decode step over a random wave
            0..=54 => {
                let bucket = rng.usize(1, SLOTS + 1);
                let mut slots = pick_slots(&mut rng, bucket);
                let mut step_lens = vec![0i32; bucket];
                for (b, s) in slots.iter_mut().enumerate() {
                    if rng.chance(0.2) || lens[*s as usize] + 1 > LEN_CAP {
                        *s = PAD_SLOT;
                    } else {
                        step_lens[b] = lens[*s as usize] as i32;
                    }
                }
                for layer in 0..LAYERS {
                    let k = rand_tensor(&mut rng, bucket);
                    let v = rand_tensor(&mut rng, bucket);
                    arena.append_step(&slots, layer, &k, &v, &step_lens);
                    dense.append_step(&slots, layer, &k, &v, &step_lens);
                }
                for &s in &slots {
                    if s != PAD_SLOT {
                        lens[s as usize] += 1;
                    }
                }
            }
            // prefill chunk (fresh or continuing)
            55..=74 => {
                let slot = rng.usize(0, SLOTS) as u32;
                let cached = if rng.chance(0.5) { 0 } else { lens[slot as usize] };
                let t = rng.usize(1, 9);
                if cached + t > LEN_CAP {
                    continue;
                }
                for layer in 0..LAYERS {
                    let k = rand_tensor(&mut rng, t);
                    let v = rand_tensor(&mut rng, t);
                    arena.append_chunk(slot, layer, &k, &v, cached, t);
                    dense.append_chunk(slot, layer, &k, &v, cached, t);
                }
                lens[slot as usize] = cached + t;
            }
            // retirement frees blocks immediately
            75..=86 => {
                let slot = rng.usize(0, SLOTS) as u32;
                arena.retire(slot);
                dense.reset(slot);
                lens[slot as usize] = 0;
            }
            // prefix-cache hit: share a donor prefix copy-on-write (any
            // token count — the arena must handle mid-block tails even
            // though the leader only issues block-aligned hits)
            87..=93 if cow => {
                let pair = pick_slots(&mut rng, 2);
                let (src, dst) = (pair[0], pair[1]);
                let srclen = lens[src as usize];
                if srclen == 0 {
                    continue;
                }
                let tokens = rng.usize(1, srclen + 1);
                arena.map_prefix(dst, src, tokens);
                dense.map_from(dst, src, tokens.div_ceil(block_size) * block_size);
                lens[dst as usize] = tokens;
            }
            // slot reuse without retire: the leader just starts a new
            // request at position 0 (decode path); the stale table must be
            // replaced by the arena's position-0 reset
            _ => {
                let slot = rng.usize(0, SLOTS);
                lens[slot] = 0;
            }
        }

        check_gather(&mut arena, &dense, &mut rng, &tag);

        // allocator invariant: blocks in use exactly cover cached tokens
        let table_lens: Vec<usize> = (0..SLOTS as u32).map(|s| arena.len_tokens(s)).collect();
        let st = arena.stats();
        assert_eq!(
            st.blocks_in_use,
            kv_blocks_needed(&table_lens, block_size),
            "{tag}: block accounting"
        );
        // refcount invariant: distinct resident blocks never exceed the
        // logical (per-mapper) count, and the byte views stay proportional
        assert!(
            st.physical_blocks_in_use <= st.blocks_in_use,
            "{tag}: physical blocks exceed logical"
        );
        assert_eq!(
            st.physical_bytes_in_use * st.blocks_in_use,
            st.bytes_in_use * st.physical_blocks_in_use,
            "{tag}: physical/logical byte views disagree"
        );
        shared_seen |= st.physical_blocks_in_use < st.blocks_in_use;
    }

    // no physical leaks: retiring every slot returns every block, shared
    // or not, exactly once
    for s in 0..SLOTS as u32 {
        arena.retire(s);
    }
    let end = arena.stats();
    assert_eq!(end.blocks_in_use, 0, "seed {seed:#x}: leaked logical blocks");
    assert_eq!(end.physical_blocks_in_use, 0, "seed {seed:#x}: leaked physical blocks");
    shared_seen
}

#[test]
fn prop_paged_gather_bit_identical_to_dense() {
    for &bs in &[1usize, 4, 16] {
        for rep in 0..6 {
            run_case(0x9a6ed + rep * 7919 + bs as u64, bs, 60, false);
        }
    }
}

#[test]
fn prop_cow_shared_prefixes_bit_identical_and_leak_free() {
    let mut shared_seen = false;
    for &bs in &[1usize, 4, 16] {
        for rep in 0..4 {
            shared_seen |= run_case(0xc0de5 + rep * 104_729 + bs as u64, bs, 80, true);
        }
    }
    assert!(shared_seen, "churn never exercised physical sharing");
}

#[test]
fn cow_divergence_isolates_slots_and_refcounts_free_lazily() {
    // share → both slots diverge into the shared mid-block tail → retire
    // donor → sharer intact → retire sharer → every block free
    let bs = 4;
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: LAYERS,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: 2,
        block_size: bs,
        initial_blocks: 1,
        dtype: KvDtype::F32,
    });
    let mut dense = DenseRef::new();
    let mut rng = Rng::new(0xc0_11ab);

    // donor (slot 0): 6 tokens — 2 blocks, the second half-full
    for layer in 0..LAYERS {
        let k = rand_tensor(&mut rng, 6);
        let v = rand_tensor(&mut rng, 6);
        arena.append_chunk(0, layer, &k, &v, 0, 6);
        dense.append_chunk(0, layer, &k, &v, 0, 6);
    }
    arena.map_prefix(1, 0, 6);
    dense.map_from(1, 0, 8); // 2 covering blocks = 8 positions
    let st = arena.stats();
    assert_eq!(st.blocks_in_use, 4, "logical: 2 blocks per slot");
    assert_eq!(st.physical_blocks_in_use, 2, "physical: both resident blocks shared");

    // both slots append at position 6 — inside the shared tail block. The
    // first writer must copy-on-write; neither may see the other's token.
    for layer in 0..LAYERS {
        let k = rand_tensor(&mut rng, 2);
        let v = rand_tensor(&mut rng, 2);
        arena.append_step(&[0, 1], layer, &k, &v, &[6, 6]);
        dense.append_step(&[0, 1], layer, &k, &v, &[6, 6]);
    }
    let st = arena.stats();
    assert_eq!(st.blocks_in_use, 4);
    assert_eq!(st.physical_blocks_in_use, 3, "divergence must clone exactly one block");
    for slot in [0u32, 1] {
        let (pk, pv) = arena.gather(&[slot], 0, 1, 8);
        let (dk, dv) = dense.gather(&[slot], 0, 1, 8);
        assert_eq!(pk.as_f32(), &dk[..], "slot {slot} K diverged after CoW");
        assert_eq!(pv.as_f32(), &dv[..], "slot {slot} V diverged after CoW");
    }

    // the donor retires; the still-shared head block survives for slot 1
    arena.retire(0);
    dense.reset(0);
    let st = arena.stats();
    assert_eq!(st.blocks_in_use, 2);
    assert_eq!(st.physical_blocks_in_use, 2);
    for layer in 0..LAYERS {
        let (pk, _) = arena.gather(&[1], layer, 1, 8);
        let (dk, _) = dense.gather(&[1], layer, 1, 8);
        assert_eq!(pk.as_f32(), &dk[..], "sharer lost data when the donor retired");
    }

    arena.retire(1);
    let st = arena.stats();
    assert_eq!(st.blocks_in_use, 0);
    assert_eq!(st.physical_blocks_in_use, 0, "last holder must free shared blocks");
}

#[test]
fn paged_memory_scales_with_live_context_not_capacity() {
    const BIG_MAX_SEQ: usize = 512;
    const BIG_SLOTS: usize = 16;
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: 2,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: BIG_MAX_SEQ,
        slots: BIG_SLOTS,
        block_size: 16,
        initial_blocks: BIG_SLOTS,
        dtype: KvDtype::F32,
    });
    let slots: Vec<u32> = (0..BIG_SLOTS as u32).collect();
    let k = HostTensor::zeros_f32(vec![BIG_SLOTS, KHS, HD]);
    for t in 0..8 {
        let lens = vec![t as i32; BIG_SLOTS];
        for layer in 0..2 {
            arena.append_step(&slots, layer, &k, &k, &lens);
        }
    }
    // dense layout would preallocate slots × max_seq regardless of context
    let dense_equiv = 2 * 2 * BIG_SLOTS * BIG_MAX_SEQ * KHS * HD * 4;
    let resident = arena.resident_bytes();
    assert!(
        resident * 4 <= dense_equiv,
        "paged resident {resident} not ≪ dense {dense_equiv}"
    );
    // and retirement returns every block
    for s in 0..BIG_SLOTS as u32 {
        arena.retire(s);
    }
    assert_eq!(arena.stats().blocks_in_use, 0);
    assert_eq!(arena.stats().internal_waste_tokens, 0);
}

#[test]
fn quantized_storage_multiplies_capacity_at_fixed_bytes() {
    // same geometry, three dtypes: resident bytes per block drop 2×/≈4×,
    // which is exactly the capacity gain a fixed --kv-budget (in bytes)
    // sees under quantized storage
    let mk = |dtype: KvDtype| {
        PagedKvArena::new(ArenaCfg {
            layers: 2,
            kv_heads: KHS,
            head_dim: 64,
            max_seq: MAX_SEQ,
            slots: 1,
            block_size: 16,
            initial_blocks: 4,
            dtype,
        })
    };
    let f32b = mk(KvDtype::F32).resident_bytes() as f64;
    let f16b = mk(KvDtype::F16).resident_bytes() as f64;
    let i8b = mk(KvDtype::Int8).resident_bytes() as f64;
    assert!((f32b / f16b - 2.0).abs() < 1e-9, "f16 must halve resident bytes");
    assert!(f32b / i8b >= 3.8, "int8 must ~quarter resident bytes (got {:.2}×)", f32b / i8b);
    // and the stats snapshot carries the same byte view
    let a = mk(KvDtype::Int8);
    assert_eq!(a.stats().total_bytes, a.resident_bytes());
}

#[test]
fn gather_truncates_consistently_when_bucket_smaller_than_context() {
    // seq_bucket below the cached length: both caches expose exactly the
    // first seq_bucket tokens
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: 1,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: 1,
        block_size: 4,
        initial_blocks: 1,
        dtype: KvDtype::F32,
    });
    let mut dense = DenseRef::new();
    let mut rng = Rng::new(0x7b1234);
    for t in 0..20 {
        let k = rand_tensor(&mut rng, 1);
        let v = rand_tensor(&mut rng, 1);
        arena.append_step(&[0], 0, &k, &v, &[t]);
        dense.append_step(&[0], 0, &k, &v, &[t]);
    }
    let (pk, _) = arena.gather(&[0], 0, 1, 8);
    let (dk, _) = dense.gather(&[0], 0, 1, 8);
    assert_eq!(pk.as_f32(), &dk[..]);
}
