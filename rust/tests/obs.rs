//! Shared-registry behavior of the obs metrics layer: get-or-create
//! identity across call sites, snapshot/reset determinism under real
//! `ScopedPool` concurrency, histogram merge laws, and the Prometheus
//! exposition text.
//!
//! These tests exercise the PROCESS-GLOBAL `obs::registry()` (the lib unit
//! tests deliberately stick to local `Registry::new()` instances), so the
//! whole binary serializes through one mutex and every test uses metric
//! names no other test touches.

use std::sync::Mutex;

use lamina::obs::registry::{bucket_bounds, bucket_index, HIST_BUCKETS};
use lamina::obs::{self, HistoSnapshot};
use lamina::util::threadpool::ScopedPool;

/// Global-registry tests must not interleave: `Registry::reset()` zeroes
/// every metric in the process.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn global_registry_handles_share_cells() {
    let _g = guard();
    let c1 = obs::registry().counter("test_obs.share.counter");
    let c2 = obs::registry().counter("test_obs.share.counter");
    c1.add(5);
    c2.add(2);
    assert_eq!(c1.get(), 7, "two lookups of one name share the cell");

    let h1 = obs::registry().histogram("test_obs.share.histo");
    let h2 = obs::registry().histogram("test_obs.share.histo");
    h1.record(10);
    h2.record(20);
    assert_eq!(h1.count(), 2);

    let g1 = obs::registry().gauge("test_obs.share.gauge");
    obs::registry().gauge("test_obs.share.gauge").set(42);
    assert_eq!(g1.get(), 42);
}

#[test]
fn concurrent_counter_and_histogram_updates_are_lossless() {
    let _g = guard();
    let c = obs::registry().counter("test_obs.conc.counter");
    let h = obs::registry().histogram("test_obs.conc.histo");
    c.reset();
    h.reset();

    const WORKERS: usize = 8;
    const PER_WORKER: u64 = 5_000;
    let pool = ScopedPool::new(WORKERS);
    let items: Vec<usize> = (0..WORKERS).collect();
    pool.map(&items, |&w| {
        // every worker resolves its own handles through the registry map
        // (the get-or-create path) and then hammers the shared atomics
        let c = obs::registry().counter("test_obs.conc.counter");
        let h = obs::registry().histogram("test_obs.conc.histo");
        for i in 0..PER_WORKER {
            c.inc();
            h.record(w as u64 * PER_WORKER + i);
        }
    });

    let total = WORKERS as u64 * PER_WORKER;
    assert_eq!(c.get(), total, "no lost counter increments");
    let s = h.snapshot();
    assert_eq!(s.count, total, "no lost histogram records");
    assert_eq!(
        s.counts.iter().sum::<u64>(),
        total,
        "bucket counts account for every record"
    );
    // sum of 0..total recorded exactly once
    assert_eq!(s.sum, total * (total - 1) / 2);
}

#[test]
fn snapshot_then_reset_is_deterministic() {
    let _g = guard();
    let c = obs::registry().counter("test_obs.reset.counter");
    let gauge = obs::registry().gauge("test_obs.reset.gauge");
    let h = obs::registry().histogram("test_obs.reset.histo");
    c.reset();
    gauge.reset();
    h.reset();

    c.add(9);
    gauge.set(-3);
    h.record(100);
    h.record(200);

    let snap = obs::registry().snapshot();
    assert_eq!(snap.counters["test_obs.reset.counter"], 9);
    assert_eq!(snap.gauges["test_obs.reset.gauge"], -3);
    assert_eq!(snap.histograms["test_obs.reset.histo"].count, 2);
    assert_eq!(snap.histograms["test_obs.reset.histo"].sum, 300);

    // a snapshot is a value: mutating after does not change it
    c.add(1);
    assert_eq!(snap.counters["test_obs.reset.counter"], 9);

    obs::registry().reset();
    let snap2 = obs::registry().snapshot();
    assert_eq!(snap2.counters["test_obs.reset.counter"], 0);
    assert_eq!(snap2.gauges["test_obs.reset.gauge"], 0);
    assert_eq!(snap2.histograms["test_obs.reset.histo"].count, 0);
    // registrations survive reset and cached handles stay wired up
    c.inc();
    assert_eq!(
        obs::registry().snapshot().counters["test_obs.reset.counter"],
        1
    );
}

#[test]
fn histogram_merge_matches_combined_recording() {
    let _g = guard();
    let a = obs::registry().histogram("test_obs.merge.a");
    let b = obs::registry().histogram("test_obs.merge.b");
    let both = obs::registry().histogram("test_obs.merge.both");
    a.reset();
    b.reset();
    both.reset();

    // deterministic pseudo-random values spanning many octaves
    let mut x = 0x12345u64;
    for i in 0..2_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let v = x >> (x % 50); // values from full-range down to tiny
        if i % 2 == 0 {
            a.record(v);
        } else {
            b.record(v);
        }
        both.record(v);
    }

    let merged = a.snapshot().merge(&b.snapshot());
    assert_eq!(merged, both.snapshot(), "merge == recording into one histogram");
    // merge with empty is identity
    assert_eq!(a.snapshot().merge(&HistoSnapshot::empty()), a.snapshot());
    // quantiles of the merged shard-view match the combined view
    let q_merged = merged.quantile(0.9);
    let q_both = both.snapshot().quantile(0.9);
    assert_eq!(q_merged.to_bits(), q_both.to_bits());
}

#[test]
fn quantile_relative_error_within_bucket_contract() {
    let _g = guard();
    let h = obs::registry().histogram("test_obs.err.histo");
    h.reset();
    // record an exact arithmetic ramp; the p50 estimate (bucket midpoint)
    // must sit within the 12.5% relative-error bound of the true median
    for v in 1..=10_000u64 {
        h.record(v);
    }
    let s = h.snapshot();
    let true_median = 5_000.0;
    let est = s.p50();
    assert!(
        (est - true_median).abs() / true_median <= 0.125 + 1e-9,
        "p50 estimate {est} vs true {true_median}"
    );
    let true_p99 = 9_900.0;
    let est99 = s.p99();
    assert!(
        (est99 - true_p99).abs() / true_p99 <= 0.125 + 1e-9,
        "p99 estimate {est99} vs true {true_p99}"
    );
}

#[test]
fn bucket_index_stays_in_table() {
    // pure math, no registry — belt-and-braces on the table extremes
    for v in [0u64, 1, 7, 8, 9, 1 << 20, u64::MAX / 2, u64::MAX - 1, u64::MAX] {
        let i = bucket_index(v);
        assert!(i < HIST_BUCKETS, "v={v} -> bucket {i}");
        let (lo, hi) = bucket_bounds(i);
        assert!(lo <= v && (v < hi || hi == u64::MAX));
    }
}

#[test]
fn prometheus_exposition_shape() {
    let _g = guard();
    let c = obs::registry().counter("test_obs.prom.counter");
    let gauge = obs::registry().gauge("test_obs.prom.gauge");
    let h = obs::registry().histogram("test_obs.prom.histo_ns");
    c.reset();
    gauge.reset();
    h.reset();
    c.add(12);
    gauge.set(-7);
    h.record(5);
    h.record(5);
    h.record(1_000);

    let text = obs::export::prometheus(&obs::registry().snapshot());
    assert!(text.contains("# TYPE lamina_test_obs_prom_counter counter"));
    assert!(text.contains("lamina_test_obs_prom_counter 12"));
    assert!(text.contains("# TYPE lamina_test_obs_prom_gauge gauge"));
    assert!(text.contains("lamina_test_obs_prom_gauge -7"));
    assert!(text.contains("# TYPE lamina_test_obs_prom_histo_ns histogram"));
    // value 5 is an exact unit bucket [5,6): cumulative 2 at le="6"
    assert!(text.contains("lamina_test_obs_prom_histo_ns_bucket{le=\"6\"} 2"));
    assert!(text.contains("lamina_test_obs_prom_histo_ns_bucket{le=\"+Inf\"} 3"));
    assert!(text.contains("lamina_test_obs_prom_histo_ns_sum 1010"));
    assert!(text.contains("lamina_test_obs_prom_histo_ns_count 3"));

    // cumulative bucket series is monotone nondecreasing
    let mut last = 0u64;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("lamina_test_obs_prom_histo_ns_bucket{le=\"") {
            if rest.starts_with("+Inf") {
                continue;
            }
            let cum: u64 = rest.split("} ").nth(1).unwrap().parse().unwrap();
            assert!(cum >= last, "cumulative buckets must not decrease");
            last = cum;
        }
    }
    assert_eq!(last, 3);
}

#[test]
fn serve_metric_names_are_registered_by_metrics_module() {
    let _g = guard();
    // ServeMetrics streams into these registry names at record time; a
    // rename there without updating dashboards/docs should fail loudly
    let mut m = lamina::metrics::ServeMetrics::new();
    m.record_request(0.010, Some(0.025), 8);
    m.record_rejection();
    let snap = obs::registry().snapshot();
    for name in ["serve.queue_ns", "serve.ttft_ns"] {
        assert!(
            snap.histograms.contains_key(name),
            "missing histogram {name}"
        );
    }
    assert!(snap.counters.contains_key("serve.rejected"));
    assert!(snap.histograms["serve.queue_ns"].count >= 1);
}
