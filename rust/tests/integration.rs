//! Cross-module integration tests + randomized property tests.
//!
//! Property tests use the in-repo PRNG (no proptest offline): each runs a
//! few hundred randomized cases with fixed seeds, checking invariants that
//! must hold for *any* workload.

use lamina::baseline::vllm::{run_vllm, VllmConfig};
use lamina::coordinator::batcher::ContinuousBatcher;
use lamina::coordinator::pipeline::StaggerPlan;
use lamina::coordinator::sim::{run_lamina, LaminaConfig};
use lamina::devices::specs::{H100, H20, LLAMA3_70B, LLAMA_33B, LLAMA_65B};
use lamina::kvcache::{head_level, request_level};
use lamina::netsim::stack::FHBN;
use lamina::opgraph::builder::{build_decode_graph, ArchShape};
use lamina::opgraph::graph::{OpGraph, OpKind};
use lamina::opgraph::mincut::min_cut;
use lamina::opgraph::schedule::emit_programs;
use lamina::opgraph::slicer::split_at_attention;
use lamina::trace::{synthesize, Request, ALL_TRACES};
use lamina::util::json::Json;
use lamina::util::prng::Rng;

// ---------------------------------------------------------------------------
// Integration: analytical experiment pipeline
// ---------------------------------------------------------------------------

#[test]
fn experiments_write_results() {
    let dir = std::env::temp_dir().join(format!("lamina-it-{}", std::process::id()));
    for id in ["table1", "fig4", "fig13"] {
        let j = lamina::figures::run(id, 100, 5).unwrap();
        lamina::figures::save(id, &j, &dir).unwrap();
        let back = Json::parse(&std::fs::read_to_string(dir.join(format!("{id}.json"))).unwrap())
            .unwrap();
        assert_eq!(back, j);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn equal_cost_comparison_consistency() {
    // At Table-5 configs, Lamina costs less and batches at least as large
    // on every trace shape (subsampled).
    for model in [&LLAMA_33B, &LLAMA_65B, &LLAMA3_70B] {
        let (dop, tp) = lamina::coordinator::planner::table5_configs(model);
        let lam_cfg = LaminaConfig::standard(model, &H100, &H20, dop, &FHBN);
        let vll_cfg = VllmConfig::standard(model, &H100, tp);
        assert!(lam_cfg.cost_per_hour() <= vll_cfg.cost_per_hour());

        let reqs = synthesize(&lamina::trace::AZURE_CONV, 600, 9);
        let lam = run_lamina(&lam_cfg, &reqs);
        let vll = run_vllm(&vll_cfg, &reqs);
        assert_eq!(lam.metrics.requests_completed, 600);
        assert_eq!(vll.metrics.requests_completed, 600);
        assert!(
            lam.metrics.mean_batch() >= vll.metrics.mean_batch(),
            "{}: lamina batch {} < vllm {}",
            model.name,
            lam.metrics.mean_batch(),
            vll.metrics.mean_batch()
        );
    }
}

#[test]
fn sim_tbt_higher_but_bounded() {
    // Paper: Lamina's TBT is larger (bigger batches) but within SLO (we use
    // 250 ms as the interactive bound the paper references).
    let reqs = synthesize(&lamina::trace::KIMI_TA, 500, 3);
    for model in [&LLAMA_65B, &LLAMA3_70B] {
        let (dop, tp) = lamina::coordinator::planner::table5_configs(model);
        let lam = run_lamina(&LaminaConfig::standard(model, &H100, &H20, dop, &FHBN), &reqs);
        let vll = run_vllm(&VllmConfig::standard(model, &H100, tp), &reqs);
        let lam_tbt = lam.metrics.mean_tbt();
        let vll_tbt = vll.metrics.mean_tbt();
        assert!(lam_tbt >= vll_tbt * 0.8, "unexpectedly fast");
        assert!(lam_tbt < 0.25, "SLO violated: {lam_tbt}");
    }
}

#[test]
fn converter_interface_matches_hand_written_slices() {
    // The min-cut context for the tiny artifact model must be exactly one
    // d-dim residual per request — the interface python's slice_mid uses.
    let shape = lamina::opgraph::builder::tiny_shape();
    let dg = build_decode_graph(shape);
    let sr = split_at_attention(&dg);
    for cut in &sr.cuts {
        assert_eq!(cut.cut_edges.len(), 1);
        assert!((cut.weight - shape.hidden_bytes()).abs() < 1e-9);
    }
    // and the emitted programs carry SendQ before SendKV, every mid slice
    let progs = emit_programs(&dg, &sr);
    assert_eq!(progs.len(), shape.layers + 1);
}

#[test]
fn staggered_pipeline_matches_sim_utilization() {
    // When attention workers are provisioned per the bubble-free rule, the
    // plan reports ~full utilisation of both pools.
    let t_m = 20e-3;
    let needed =
        lamina::coordinator::pipeline::min_attn_workers_for_bubble_free(t_m, 80e-3, 2, 16)
            .unwrap();
    let plan = StaggerPlan::new(2, t_m, 80e-3 / needed as f64);
    assert!(plan.is_bubble_free(1e-9));
    assert!(plan.model_utilization() > 0.99);
}

// ---------------------------------------------------------------------------
// Property tests (randomized, fixed seeds)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conservation() {
    // For any workload: every admitted request completes exactly once, the
    // reservation returns to zero, and reserved tokens never exceed
    // capacity at any step.
    let mut rng = Rng::new(0xba7c);
    for case in 0..200 {
        let n = rng.usize(1, 40);
        let cap = rng.usize(100, 5000);
        let max_batch = rng.usize(1, 32);
        let reqs: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt_tokens: rng.usize(1, 400),
                gen_tokens: rng.usize(1, 100),
            })
            .collect();
        let feasible = reqs.iter().filter(|r| r.max_context() <= cap).count();
        let mut b = ContinuousBatcher::new(cap, max_batch);
        b.submit_all(reqs.iter().copied());
        let mut completed = 0;
        let mut guard = 0;
        while !b.is_idle() {
            b.admit();
            assert!(b.reserved_tokens() <= cap, "case {case}: over-reserved");
            assert!(b.batch_size() <= max_batch);
            if b.batch_size() == 0 && b.waiting_len() == 0 {
                break;
            }
            let (_, done) = b.step();
            completed += done.len();
            guard += 1;
            assert!(guard < 100_000, "case {case}: stuck");
        }
        assert_eq!(completed, feasible, "case {case}");
        assert_eq!(b.reserved_tokens(), 0, "case {case}: leaked reservation");
    }
}

#[test]
fn prop_mincut_equals_bruteforce_on_small_dags() {
    // Dinic's min cut must equal brute-force enumeration over all valid
    // source/sink partitions on random small DAGs.
    let mut rng = Rng::new(0xd171c);
    for case in 0..150 {
        let n = rng.usize(4, 9);
        let mut g = OpGraph::default();
        for i in 0..n {
            g.add_node(format!("n{i}"), OpKind::MatMul, None);
        }
        // random DAG edges i<j
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.chance(0.45) {
                    g.add_edge(i, j, (rng.usize(1, 20)) as f64);
                }
            }
        }
        let s = 0;
        let t = n - 1;
        // ensure s→t connectivity via a direct path
        g.add_edge(s, t, (rng.usize(1, 20)) as f64);

        let cut = min_cut(&g, &[s], &[t], |_, _| false);

        // brute force: all bipartitions with s∈S, t∉S; cut = crossing sum,
        // but only partitions that are "closed" need not hold — min over
        // ALL partitions equals max-flow by LP duality on DAGs with these
        // infinite-free edges. (All edges cuttable here.)
        let mut best = f64::INFINITY;
        for mask in 0u32..(1 << n) {
            if mask & (1 << s) == 0 || mask & (1 << t) != 0 {
                continue;
            }
            let in_set: Vec<bool> = (0..n).map(|i| mask & (1 << i) != 0).collect();
            best = best.min(g.cut_bytes(&in_set));
        }
        assert!(
            (cut.weight - best).abs() < 1e-6,
            "case {case}: dinic {} vs brute {}",
            cut.weight,
            best
        );
    }
}

#[test]
fn prop_partitioning_conserves_and_bounds() {
    // Head-level: zero imbalance whenever divisible. Request-level: total
    // load conserved; imbalance ≥ 0; greedy ≤ 2× optimal lower bound.
    let mut rng = Rng::new(0xbeef);
    for _ in 0..200 {
        let w = rng.usize(1, 9);
        let n_reqs = rng.usize(w, 60);
        let lens: Vec<usize> = (0..n_reqs).map(|_| rng.usize(1, 32_000)).collect();
        let heads = w * rng.usize(1, 5);
        let h = head_level(heads, w, &lens, 2.0).unwrap();
        assert!(h.imbalance() < 1e-12);

        let r = request_level(w, &lens, 2.0).unwrap();
        let total: f64 = r.load.iter().sum();
        let expect = 2.0 * lens.iter().sum::<usize>() as f64;
        assert!((total - expect).abs() < 1e-6);
        let max = r.load.iter().cloned().fold(0.0, f64::max);
        let lower = (expect / w as f64).max(2.0 * *lens.iter().max().unwrap() as f64);
        assert!(max <= 2.0 * lower + 1e-9, "greedy bound violated");
    }
}

#[test]
fn prop_slicer_on_random_depths() {
    // Slicing must produce L+1 slices with single-residual cuts for any
    // layer count / GQA group.
    let mut rng = Rng::new(0x51ce);
    for _ in 0..25 {
        let layers = rng.usize(1, 12);
        let g = [1usize, 2, 4, 8][rng.usize(0, 4)];
        let heads_mult = g * 16; // ensure d divisible
        let shape = ArchShape {
            d: heads_mult * rng.usize(1, 4),
            layers,
            gqa_group: g,
            ffn: 64 * rng.usize(1, 8),
            vocab: 256,
            elem_bytes: 2.0,
        };
        let dg = build_decode_graph(shape);
        let sr = split_at_attention(&dg);
        assert_eq!(sr.slices.len(), layers + 1);
        for cut in &sr.cuts {
            assert_eq!(cut.cut_edges.len(), 1);
            assert!((cut.weight - shape.hidden_bytes()).abs() < 1e-9);
        }
        let progs = emit_programs(&dg, &sr);
        assert_eq!(progs.len(), layers + 1);
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(0x15a5);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.usize(0, 4) } else { rng.usize(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.usize(0, 1_000_000) as f64) / 8.0),
            3 => Json::Str(format!("s{}-\"esc\"\n", rng.usize(0, 999))),
            4 => Json::Num(-(rng.usize(1, 100) as f64)),
            5 => Json::Arr((0..rng.usize(0, 5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize(0, 5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..300 {
        let v = gen(&mut rng, 3);
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }
}

#[test]
fn prop_stagger_rotation_always_conflict_free() {
    let mut rng = Rng::new(0x57a6);
    for _ in 0..100 {
        let n = rng.usize(2, 9);
        let plan = StaggerPlan::new(n, 1.0, rng.f64());
        for k in 0..8 {
            let mut seen = std::collections::BTreeSet::new();
            for j in 0..plan.replicas {
                assert!(seen.insert(plan.replica_for(j, k)));
            }
        }
    }
}

#[test]
fn prop_sim_conserves_requests_across_traces() {
    // Every trace/model combination must complete exactly the feasible
    // request count (no losses, no duplicates).
    for t in ALL_TRACES {
        let reqs = synthesize(t, 120, 77);
        let cfg = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
        let feasible = reqs
            .iter()
            .filter(|r| {
                r.max_context() <= cfg.kv_capacity_tokens() / cfg.concurrent_batches
            })
            .count() as u64;
        let rep = run_lamina(&cfg, &reqs);
        assert_eq!(rep.metrics.requests_completed, feasible, "{}", t.name);
    }
}
