//! Open-loop (arrival-driven) serving simulation.
//!
//! The paper's throughput runs are closed-loop; its latency claim ("within
//! the SLO of online interactive LLM services") is an open-loop property:
//! under a live arrival process, queueing inflates request latency as the
//! offered load approaches capacity. This harness drives the Lamina and
//! vLLM engines with Poisson arrivals on a virtual clock and reports
//! sustained throughput, TBT, queue wait and SLO attainment per load level.

use std::collections::VecDeque;

use crate::baseline::vllm::{vllm_step_cost, VllmConfig};
use crate::coordinator::batcher::ContinuousBatcher;
use crate::coordinator::sim::{wave_cost, LaminaConfig};
use crate::trace::Request;
use crate::util::prng::Rng;
use crate::util::stats::Percentiles;

/// Outcome of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub offered_rps: f64,
    pub completed: usize,
    /// Sustained token throughput over the busy period.
    pub tokens_per_s: f64,
    pub mean_tbt_s: f64,
    pub p99_tbt_s: f64,
    /// Mean time a request waits before first admission.
    pub mean_queue_wait_s: f64,
    /// Fraction of decode iterations meeting the TBT SLO.
    pub slo_attainment: f64,
}

/// Engine abstraction: per-iteration cost given (batch, total context).
pub enum Engine2<'a> {
    Lamina(&'a LaminaConfig),
    Vllm(&'a VllmConfig),
}

impl Engine2<'_> {
    fn capacity_tokens(&self) -> usize {
        match self {
            Engine2::Lamina(c) => c.kv_capacity_tokens() / c.concurrent_batches,
            Engine2::Vllm(c) => c.kv_capacity_tokens(),
        }
    }

    fn max_batch(&self) -> usize {
        match self {
            Engine2::Lamina(c) => c.max_batch,
            Engine2::Vllm(c) => c.max_batch,
        }
    }

    /// (TBT, tokens emitted this iteration) for the current state.
    fn step_cost(&self, batch: usize, total_ctx: usize) -> (f64, usize) {
        match self {
            Engine2::Lamina(c) => {
                let w = wave_cost(c, batch, total_ctx);
                // n staggered waves emit n×batch tokens per TBT period; this
                // single-batcher model tracks one wave and scales tokens
                (w.tbt, batch * c.concurrent_batches)
            }
            Engine2::Vllm(c) => (vllm_step_cost(c, batch, total_ctx).total_s, batch),
        }
    }
}

/// Run an open-loop simulation: `requests` arrive Poisson at `rps`
/// requests/second on a virtual clock; SLO is a per-token TBT bound.
pub fn run_open_loop(
    engine: &Engine2,
    requests: &[Request],
    rps: f64,
    tbt_slo_s: f64,
    seed: u64,
) -> OpenLoopReport {
    assert!(rps > 0.0);
    let mut rng = Rng::new(seed);
    // arrival schedule
    let mut arrivals: VecDeque<(f64, Request)> = {
        let mut t = 0.0;
        requests
            .iter()
            .map(|r| {
                t += rng.exponential(rps);
                (t, *r)
            })
            .collect()
    };
    let mut arrival_time: std::collections::BTreeMap<u64, f64> = Default::default();

    let mut batcher = ContinuousBatcher::new(engine.capacity_tokens(), engine.max_batch());
    let mut clock = 0.0f64;
    let mut tokens = 0u64;
    let mut completed = 0usize;
    let mut busy_s = 0.0f64;
    let mut tbt = Percentiles::new();
    let mut queue_wait = Percentiles::new();
    let mut slo_ok = 0u64;
    let mut slo_total = 0u64;
    let mut admitted: std::collections::BTreeSet<u64> = Default::default();

    loop {
        // deliver arrivals up to the current clock
        while arrivals.front().map_or(false, |(t, _)| *t <= clock) {
            let (t, r) = arrivals.pop_front().unwrap();
            arrival_time.insert(r.id, t);
            batcher.submit(r);
        }
        batcher.admit();
        for r in batcher.running() {
            if admitted.insert(r.req.id) {
                queue_wait.add(clock - arrival_time[&r.req.id]);
            }
        }
        if batcher.batch_size() == 0 {
            match arrivals.front() {
                Some((t, _)) => {
                    clock = *t; // idle: jump to next arrival
                    continue;
                }
                None => break, // drained
            }
        }
        let (dt, toks) = engine.step_cost(batcher.batch_size(), batcher.total_context());
        let (_, done) = batcher.step();
        clock += dt;
        busy_s += dt;
        tokens += toks as u64;
        completed += done.len();
        tbt.add(dt);
        slo_total += 1;
        if dt <= tbt_slo_s {
            slo_ok += 1;
        }
    }

    OpenLoopReport {
        offered_rps: rps,
        completed,
        tokens_per_s: if busy_s > 0.0 { tokens as f64 / busy_s } else { 0.0 },
        mean_tbt_s: tbt.mean(),
        p99_tbt_s: if tbt.is_empty() { f64::NAN } else { tbt.p99() },
        mean_queue_wait_s: queue_wait.mean(),
        slo_attainment: if slo_total == 0 { 1.0 } else { slo_ok as f64 / slo_total as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, H20, LLAMA3_70B};
    use crate::netsim::stack::FHBN;
    use crate::trace::fixed_length;

    fn lamina() -> LaminaConfig {
        LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN)
    }

    #[test]
    fn completes_all_requests() {
        let cfg = lamina();
        let reqs = fixed_length(100, 1024, 8);
        let rep = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 50.0, 0.2, 1);
        assert_eq!(rep.completed, 100);
        assert!(rep.tokens_per_s > 0.0);
    }

    #[test]
    fn queue_wait_grows_with_load() {
        let cfg = lamina();
        let reqs = fixed_length(300, 4096, 32);
        let light = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 2.0, 0.2, 2);
        let heavy = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 500.0, 0.2, 2);
        assert!(
            heavy.mean_queue_wait_s > light.mean_queue_wait_s,
            "light={} heavy={}",
            light.mean_queue_wait_s,
            heavy.mean_queue_wait_s
        );
    }

    #[test]
    fn slo_attainment_high_at_light_load() {
        let cfg = lamina();
        let reqs = fixed_length(120, 2048, 8);
        let rep = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 1.0, 0.2, 3);
        assert!(rep.slo_attainment > 0.95, "slo={}", rep.slo_attainment);
    }

    #[test]
    fn vllm_engine_runs_too() {
        let cfg = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
        let reqs = fixed_length(80, 1024, 8);
        let rep = run_open_loop(&Engine2::Vllm(&cfg), &reqs, 20.0, 0.2, 4);
        assert_eq!(rep.completed, 80);
        assert!(rep.mean_tbt_s > 0.0 && rep.p99_tbt_s >= rep.mean_tbt_s);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = lamina();
        let reqs = fixed_length(50, 1024, 4);
        let a = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 10.0, 0.2, 7);
        let b = run_open_loop(&Engine2::Lamina(&cfg), &reqs, 10.0, 0.2, 7);
        assert_eq!(a.tokens_per_s, b.tokens_per_s);
        assert_eq!(a.mean_queue_wait_s, b.mean_queue_wait_s);
    }
}
