//! Rotational staggered pipelining (paper §4.3, Fig. 8).
//!
//! With a single batch, the model pool idles while the attention pool works
//! and vice versa. Lamina runs `n` batches concurrently over `n-1` model
//! replicas, each replica phase-shifted by `t_m/(n-1)`; all batches share
//! the attention pool. Choosing the attention-worker count so that
//! `t_a = t_m/(n-1)` makes the schedule bubble-free, and the rotation
//! `replica(j, k) = (j + k) mod (n-1)` keeps hand-offs conflict-free.

/// Static description of a staggered pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StaggerPlan {
    /// Number of concurrent batches n.
    pub batches: usize,
    /// Number of model replicas (n-1).
    pub replicas: usize,
    /// Per-batch model (non-attention) time for one full decode step.
    pub t_model: f64,
    /// Per-batch attention time for one full decode step.
    pub t_attn: f64,
}

impl StaggerPlan {
    pub fn new(batches: usize, t_model: f64, t_attn: f64) -> Self {
        assert!(batches >= 1);
        StaggerPlan { batches, replicas: batches.saturating_sub(1).max(1), t_model, t_attn }
    }

    /// The stagger offset between consecutive batch starts.
    pub fn stagger(&self) -> f64 {
        self.t_model / self.replicas as f64
    }

    /// Bubble-free iff t_a ≤ t_m/(n-1): attention (plus hand-off) finishes
    /// before the batch's next replica slot opens.
    pub fn is_bubble_free(&self, tolerance: f64) -> bool {
        self.t_attn <= self.stagger() * (1.0 + tolerance)
    }

    /// Steady-state time between tokens for each batch: one model pass plus
    /// the attention phases it must wait through. Bubble-free schedules give
    /// `t_m + stagger`; otherwise attention is the bottleneck and batches
    /// queue behind `n · t_a`.
    pub fn tbt(&self) -> f64 {
        if self.batches == 1 {
            // no pipelining: strictly sequential model → attention
            return self.t_model + self.t_attn;
        }
        let bubble_free = self.t_model + self.stagger();
        let attn_bound = self.batches as f64 * self.t_attn;
        let model_bound =
            (self.batches as f64 / self.replicas as f64) * self.t_model;
        bubble_free.max(attn_bound).max(model_bound)
    }

    /// Aggregate tokens/s per unit batch size (each of the n batches emits
    /// one token per TBT).
    pub fn throughput_factor(&self) -> f64 {
        self.batches as f64 / self.tbt()
    }

    /// Model-pool utilisation in steady state.
    pub fn model_utilization(&self) -> f64 {
        (self.batches as f64 * self.t_model) / (self.replicas as f64 * self.tbt())
    }

    /// Attention-pool utilisation in steady state.
    pub fn attn_utilization(&self) -> f64 {
        (self.batches as f64 * self.t_attn) / self.tbt()
    }

    /// The replica executing slice k of batch j (paper: (j+k) mod (n-1)+1;
    /// we index replicas from 0).
    pub fn replica_for(&self, batch: usize, slice: usize) -> usize {
        (batch + slice) % self.replicas
    }

    /// Context migration between consecutive slices is needed iff the
    /// replica changes — never for n = 2 (paper §4.3).
    pub fn needs_migration(&self) -> bool {
        self.replicas > 1
    }
}

/// Pick the smallest attention-worker count `b` such that the pipeline is
/// bubble-free (t_a(b) ≤ t_m/(n-1)), given attention time with one worker
/// scales as `t_attn_one / b`. Returns None if even `max_workers` cannot.
pub fn min_attn_workers_for_bubble_free(
    t_model: f64,
    t_attn_one_worker: f64,
    batches: usize,
    max_workers: usize,
) -> Option<usize> {
    let replicas = batches.saturating_sub(1).max(1);
    let budget = t_model / replicas as f64;
    (1..=max_workers).find(|&b| t_attn_one_worker / b as f64 <= budget)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_batch_single_replica() {
        let p = StaggerPlan::new(2, 10e-3, 8e-3);
        assert_eq!(p.replicas, 1);
        assert!(!p.needs_migration());
        assert!(p.is_bubble_free(0.0)); // 8 ≤ 10
        // TBT = t_m + stagger = 20 ms; throughput 2 tokens per 20 ms.
        assert!((p.tbt() - 20e-3).abs() < 1e-12);
        assert!((p.throughput_factor() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn bubble_free_condition() {
        // n=3 → stagger = t_m/2.
        let good = StaggerPlan::new(3, 10e-3, 5e-3);
        assert!(good.is_bubble_free(0.0));
        let bad = StaggerPlan::new(3, 10e-3, 6e-3);
        assert!(!bad.is_bubble_free(0.0));
    }

    #[test]
    fn attention_bound_when_underprovisioned() {
        // t_a ≫ stagger: TBT driven by n·t_a.
        let p = StaggerPlan::new(2, 4e-3, 10e-3);
        assert!((p.tbt() - 20e-3).abs() < 1e-12);
        assert!(p.attn_utilization() > 0.99);
    }

    #[test]
    fn utilizations_bounded() {
        for (n, tm, ta) in [(2, 10e-3, 9e-3), (4, 12e-3, 3e-3), (2, 5e-3, 20e-3)] {
            let p = StaggerPlan::new(n, tm, ta);
            assert!(p.model_utilization() <= 1.0 + 1e-9, "{p:?}");
            assert!(p.attn_utilization() <= 1.0 + 1e-9, "{p:?}");
        }
    }

    #[test]
    fn bubble_free_pipeline_fully_uses_model_pool() {
        // Perfectly tuned: t_a == stagger → model util = n/(n-1)·t_m / tbt,
        // with tbt = t_m + t_m/(n-1) → util = 1.
        let p = StaggerPlan::new(3, 10e-3, 5e-3);
        assert!((p.model_utilization() - 1.0).abs() < 1e-9);
        assert!((p.attn_utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rotation_is_conflict_free() {
        // At any slice step k, distinct batches map to distinct replicas.
        let p = StaggerPlan::new(4, 1.0, 0.3);
        for k in 0..10 {
            let mut used = std::collections::BTreeSet::new();
            for j in 0..p.replicas {
                assert!(used.insert(p.replica_for(j, k)));
            }
        }
    }

    #[test]
    fn rotation_advances_each_slice() {
        let p = StaggerPlan::new(3, 1.0, 0.5);
        assert_ne!(p.replica_for(0, 0), p.replica_for(0, 1));
        assert_eq!(p.replica_for(0, 0), p.replica_for(0, p.replicas));
    }

    #[test]
    fn min_workers_search() {
        // t_m = 10 ms, one-worker attention = 40 ms, n = 2 → need 4 workers.
        assert_eq!(min_attn_workers_for_bubble_free(10e-3, 40e-3, 2, 8), Some(4));
        assert_eq!(min_attn_workers_for_bubble_free(10e-3, 40e-3, 2, 3), None);
        // n=3 halves the budget → 8 workers.
        assert_eq!(min_attn_workers_for_bubble_free(10e-3, 40e-3, 3, 8), Some(8));
    }
}
