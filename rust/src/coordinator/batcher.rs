//! Continuous batcher (iteration-granularity batching, Orca-style; the
//! paper's systems — both Lamina and the vLLM baseline — batch this way).
//!
//! Requests wait in a FIFO; each decode iteration the batcher admits waiting
//! requests while (a) the KV capacity can hold their *full* trajectory
//! (prompt + all generated tokens — conservative reservation, no
//! preemption), and (b) the batch-size cap allows. Completed requests leave
//! and free their reservation at iteration boundaries.

use std::collections::VecDeque;

use crate::trace::Request;

/// A request admitted to the running set.
#[derive(Debug, Clone, Copy)]
pub struct Running {
    pub req: Request,
    /// Tokens currently in the KV cache (prompt + generated so far).
    pub context: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Iteration index at admission (for latency accounting).
    pub admitted_at: u64,
}

impl Running {
    pub fn done(&self) -> bool {
        self.generated >= self.req.gen_tokens
    }
}

/// Continuous batcher with token-reservation admission control.
#[derive(Debug)]
pub struct ContinuousBatcher {
    waiting: VecDeque<Request>,
    running: Vec<Running>,
    /// Total KV token capacity of the serving pool.
    capacity_tokens: usize,
    reserved_tokens: usize,
    max_batch: usize,
    iteration: u64,
}

impl ContinuousBatcher {
    pub fn new(capacity_tokens: usize, max_batch: usize) -> Self {
        assert!(max_batch > 0);
        ContinuousBatcher {
            waiting: VecDeque::new(),
            running: Vec::new(),
            capacity_tokens,
            reserved_tokens: 0,
            max_batch,
            iteration: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn submit_all(&mut self, reqs: impl IntoIterator<Item = Request>) {
        for r in reqs {
            self.submit(r);
        }
    }

    /// Admit as many waiting requests as fit. Returns number admitted.
    pub fn admit(&mut self) -> usize {
        let mut n = 0;
        while self.running.len() < self.max_batch {
            let Some(front) = self.waiting.front() else { break };
            let need = front.max_context();
            if need > self.capacity_tokens {
                // can never fit: reject outright (caller sees it dropped)
                log::warn!("request {} needs {} tokens > capacity {}", front.id, need,
                    self.capacity_tokens);
                self.waiting.pop_front();
                continue;
            }
            if self.reserved_tokens + need > self.capacity_tokens {
                break; // FIFO: do not skip ahead (no head-of-line bypass)
            }
            let req = self.waiting.pop_front().unwrap();
            self.reserved_tokens += need;
            self.running.push(Running {
                req,
                context: req.prompt_tokens,
                generated: 0,
                admitted_at: self.iteration,
            });
            n += 1;
        }
        n
    }

    /// One decode iteration: every running request appends one token;
    /// completed requests are removed and their reservation freed.
    /// Returns (batch size this iteration, completed requests).
    pub fn step(&mut self) -> (usize, Vec<Running>) {
        self.iteration += 1;
        let batch = self.running.len();
        for r in &mut self.running {
            r.context += 1;
            r.generated += 1;
        }
        let mut done = Vec::new();
        self.running.retain(|r| {
            if r.done() {
                done.push(*r);
                false
            } else {
                true
            }
        });
        for d in &done {
            self.reserved_tokens -= d.req.max_context();
        }
        (batch, done)
    }

    pub fn running(&self) -> &[Running] {
        &self.running
    }

    pub fn batch_size(&self) -> usize {
        self.running.len()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.waiting.is_empty()
    }

    /// Total context tokens currently cached (drives ATIME).
    pub fn total_context(&self) -> usize {
        self.running.iter().map(|r| r.context).sum()
    }

    pub fn reserved_tokens(&self) -> usize {
        self.reserved_tokens
    }

    pub fn capacity_tokens(&self) -> usize {
        self.capacity_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request { id, prompt_tokens: prompt, gen_tokens: gen }
    }

    #[test]
    fn admits_until_capacity() {
        let mut b = ContinuousBatcher::new(1000, 64);
        b.submit_all([req(0, 300, 100), req(1, 300, 100), req(2, 300, 100)]);
        assert_eq!(b.admit(), 2); // 400+400 fits; third would need 1200
        assert_eq!(b.batch_size(), 2);
        assert_eq!(b.reserved_tokens(), 800);
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn admits_more_after_completion() {
        let mut b = ContinuousBatcher::new(460, 64);
        b.submit_all([req(0, 100, 2), req(1, 300, 50), req(2, 50, 50)]);
        assert_eq!(b.admit(), 2); // 102 + 350 = 452 ≤ 460; req 2 must wait
        assert_eq!(b.waiting_len(), 1);
        // run until req 0 finishes
        let (_, done) = b.step();
        assert!(done.is_empty());
        let (_, done) = b.step();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].req.id, 0);
        assert_eq!(b.reserved_tokens(), 350);
    }

    #[test]
    fn batch_cap_respected() {
        let mut b = ContinuousBatcher::new(1_000_000, 4);
        b.submit_all((0..10).map(|i| req(i, 10, 10)));
        assert_eq!(b.admit(), 4);
        assert_eq!(b.batch_size(), 4);
    }

    #[test]
    fn fifo_no_bypass() {
        // A huge head request blocks smaller ones behind it (documented
        // FIFO behaviour — head-of-line blocking, no reorder).
        let mut b = ContinuousBatcher::new(1000, 64);
        b.submit_all([req(0, 600, 100), req(1, 900, 50), req(2, 10, 10)]);
        assert_eq!(b.admit(), 1); // only req 0
        assert_eq!(b.waiting_len(), 2);
    }

    #[test]
    fn oversized_request_dropped() {
        let mut b = ContinuousBatcher::new(100, 8);
        b.submit_all([req(0, 200, 10), req(1, 20, 10)]);
        assert_eq!(b.admit(), 1);
        assert_eq!(b.running()[0].req.id, 1);
    }

    #[test]
    fn step_counts_and_context_growth() {
        let mut b = ContinuousBatcher::new(10_000, 8);
        b.submit(req(0, 100, 5));
        b.admit();
        let (n, _) = b.step();
        assert_eq!(n, 1);
        assert_eq!(b.running()[0].context, 101);
        assert_eq!(b.total_context(), 101);
    }

    #[test]
    fn drains_to_idle() {
        let mut b = ContinuousBatcher::new(10_000, 8);
        b.submit_all((0..5).map(|i| req(i, 50, 3)));
        let mut iters = 0;
        let mut completed = 0;
        while !b.is_idle() {
            b.admit();
            let (_, done) = b.step();
            completed += done.len();
            iters += 1;
            assert!(iters < 100, "not draining");
        }
        assert_eq!(completed, 5);
        assert_eq!(b.reserved_tokens(), 0);
    }
}
