//! Fault tolerance (paper §5):
//!
//! * **model workers are stateless** — all request state (the KV caches)
//!   lives on the attention workers, so a failed model worker is replaced by
//!   a spare and decoding continues without losing progress;
//! * **attention-worker failure** loses KV shards — the cache is rebuilt by
//!   re-running the prompt + already-generated tokens (kept in the service
//!   front-end) through the prefill path on the surviving pool.

use crate::devices::roofline::mtime;
use crate::devices::specs::{DeviceSpec, LlmSpec};

/// Worker health state tracked by the global scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    Healthy,
    Failed,
    /// Replacement spun up, KV rebuild in progress (attention workers only).
    Rebuilding,
}

/// Pool membership + spare tracking for one worker class.
#[derive(Debug)]
pub struct WorkerPool {
    pub name: &'static str,
    states: Vec<WorkerState>,
    spares: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailoverError(pub String);

impl std::fmt::Display for FailoverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for FailoverError {}

impl WorkerPool {
    pub fn new(name: &'static str, workers: usize, spares: usize) -> Self {
        WorkerPool { name, states: vec![WorkerState::Healthy; workers], spares }
    }

    pub fn healthy(&self) -> usize {
        self.states.iter().filter(|s| **s == WorkerState::Healthy).count()
    }

    pub fn size(&self) -> usize {
        self.states.len()
    }

    pub fn state(&self, i: usize) -> WorkerState {
        self.states[i]
    }

    pub fn fail(&mut self, i: usize) {
        self.states[i] = WorkerState::Failed;
    }

    /// Swap in a spare for a failed worker. Model workers become healthy
    /// immediately (stateless); attention workers enter Rebuilding.
    pub fn replace(&mut self, i: usize, stateless: bool) -> Result<(), FailoverError> {
        if self.states[i] != WorkerState::Failed {
            return Err(FailoverError(format!("{} worker {i} is not failed", self.name)));
        }
        if self.spares == 0 {
            return Err(FailoverError(format!("{} pool out of spares", self.name)));
        }
        self.spares -= 1;
        self.states[i] = if stateless { WorkerState::Healthy } else { WorkerState::Rebuilding };
        Ok(())
    }

    pub fn finish_rebuild(&mut self, i: usize) {
        assert_eq!(self.states[i], WorkerState::Rebuilding);
        self.states[i] = WorkerState::Healthy;
    }
}

/// Time to reconstruct the lost KV shard by re-processing every affected
/// request's tokens through the model (prefill-style, compute-bound on the
/// model pool). `tokens_lost` = Σ per-request context length × the failed
/// worker's head share.
pub fn kv_rebuild_time(
    model: &LlmSpec,
    model_dev: &DeviceSpec,
    tp: usize,
    tokens_lost: usize,
    prefill_chunk: usize,
) -> f64 {
    if tokens_lost == 0 {
        return 0.0;
    }
    // Re-run tokens in chunks through the non-attention path (the dominant
    // cost; attention during rebuild is over the partial rebuilt cache and
    // folded into the same roofline bound).
    let chunks = tokens_lost.div_ceil(prefill_chunk);
    let per_chunk = mtime(model, model_dev, prefill_chunk.max(1), tp).time_s;
    chunks as f64 * per_chunk
}

/// Head-share of KV lost when one of `workers` attention workers fails
/// under head-level partitioning.
pub fn lost_fraction(workers: usize) -> f64 {
    1.0 / workers as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, LLAMA3_70B};

    #[test]
    fn model_worker_swap_is_instant() {
        let mut pool = WorkerPool::new("model", 2, 1);
        pool.fail(0);
        assert_eq!(pool.healthy(), 1);
        pool.replace(0, true).unwrap();
        assert_eq!(pool.healthy(), 2);
        assert_eq!(pool.state(0), WorkerState::Healthy);
    }

    #[test]
    fn attention_worker_rebuilds() {
        let mut pool = WorkerPool::new("attn", 4, 1);
        pool.fail(2);
        pool.replace(2, false).unwrap();
        assert_eq!(pool.state(2), WorkerState::Rebuilding);
        assert_eq!(pool.healthy(), 3);
        pool.finish_rebuild(2);
        assert_eq!(pool.healthy(), 4);
    }

    #[test]
    fn no_spares_errors() {
        let mut pool = WorkerPool::new("model", 2, 0);
        pool.fail(1);
        assert!(pool.replace(1, true).is_err());
    }

    #[test]
    fn replace_healthy_rejected() {
        let mut pool = WorkerPool::new("model", 2, 1);
        assert!(pool.replace(0, true).is_err());
    }

    #[test]
    fn rebuild_time_scales_with_tokens() {
        let t1 = kv_rebuild_time(&LLAMA3_70B, &H100, 2, 100_000, 512);
        let t2 = kv_rebuild_time(&LLAMA3_70B, &H100, 2, 200_000, 512);
        assert!(t1 > 0.0);
        assert!((t2 / t1 - 2.0).abs() < 0.05);
        assert_eq!(kv_rebuild_time(&LLAMA3_70B, &H100, 2, 0, 512), 0.0);
    }

    #[test]
    fn rebuild_seconds_not_hours() {
        // Losing 1/4 of a 300-request × 4k-context batch's KV must rebuild
        // in seconds — the practicality claim behind §5.
        let tokens = 300 * 4096 / 4;
        let t = kv_rebuild_time(&LLAMA3_70B, &H100, 2, tokens, 512);
        assert!(t < 60.0, "rebuild {t}s");
    }

    #[test]
    fn lost_fraction_head_level() {
        assert_eq!(lost_fraction(4), 0.25);
    }
}
