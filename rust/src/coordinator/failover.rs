//! Failure detection and recovery policy for the disaggregated decode
//! path (paper §5).
//!
//! The paper's claim: model-attention disaggregation stays viable under
//! component failure. **Model workers are stateless** — all request state
//! (the KV caches) lives on the attention workers, so a failed leader is
//! replaced and the front-end replays from its token history (pinned by
//! `model_worker_failover_is_stateless` in `e2e_pipeline`). An
//! **attention-worker failure** loses that worker's KV head-shard of
//! *every* live request; the leader rebuilds it by replaying each
//! request's effective prompt (prompt ⧺ tokens generated so far) through
//! the ordinary chunked-prefill path onto a replacement worker.
//!
//! This module is the *policy* half of that story — the mechanism lives
//! in [`crate::workers::leader`], which drives real links. The live
//! protocol, end to end:
//!
//! 1. **Deadline** — every leader-side blocking receive runs under
//!    [`HealthPolicy::recv_deadline`] instead of blocking forever.
//! 2. **Retry/backoff** — a deadline expiry alone does not condemn a
//!    worker (the wire may just be slow): [`HealthTracker`] allows
//!    [`HealthPolicy::recv_retries`] further attempts, each deadline
//!    scaled by [`HealthPolicy::backoff`], before giving up. Any healthy
//!    message resets the worker's strike count. Fatal link errors —
//!    [`TransportError::Disconnected`], [`TransportError::Codec`] (framing
//!    is unrecoverable) — and `WireMsg::WorkerError` reports skip the
//!    retry ladder entirely.
//! 3. **Declare dead** — the failure is classified as a [`DeathCause`]
//!    and surfaced as a typed [`WorkerDeath`] (never a panic; the
//!    `failover.worker_deaths` / `failover.detection_ns` metrics record
//!    it).
//! 4. **Preempt-replay-rebuild** — the leader marks the shard lost,
//!    preempts every live request through the scheduler's promoted-token
//!    replay (PR 6 machinery: requeued at the queue front, effective
//!    prompt = prompt ⧺ generated-so-far), respawns a replacement worker,
//!    discards in-flight traffic on the surviving links (a `KvStatsReq`
//!    round-trip is the FIFO barrier), and resumes serving. Re-prefill
//!    happens through the normal admission path; recovered output is
//!    bit-identical to an unfailed run on the native backend (asserted by
//!    the `net_fault` chaos suite and the scripted `fault-smoke`).
//!
//! The analytical half ([`kv_rebuild_time`], [`lost_fraction`]) keeps the
//! paper-model cost estimates: rebuild is prefill-shaped and takes
//! seconds, not hours, which is what makes discard-and-replay a sane
//! policy at all.
//!
//! # Membership lifecycle (elastic shard pool)
//!
//! The worker pool is no longer fixed-width: membership is **elastic and
//! epoch-fenced**, governed by [`MembershipPolicy`]. The life of a worker:
//!
//! ```text
//!   spawn/respawn/adopt ──Hello──▶ leader validates codec version
//!                                      │
//!                                      ▼ Welcome{epoch, kv range, arena geometry}
//!                              IN MEMBERSHIP (data plane open)
//!                                      │ death (ladder exhausted / fatal link error)
//!                                      ▼
//!                     respawn allowed? ──yes──▶ respawn + reshard (same W)
//!                            │ no
//!                            ▼
//!             W−1 ≥ min_workers? ──yes──▶ DEGRADE: reshard over survivors (W−1)
//!                            │ no
//!                            ▼
//!              typed session failure (all requests cancelled, zero leaks)
//!
//!   adopt_worker() ──handshake──▶ quiesce at step boundary ──▶ reshard W→W+1
//! ```
//!
//! **Epoch/fencing rules.** Every reshard — respawn recovery, degrade, or
//! adoption — bumps the membership epoch and re-`Welcome`s *every* member.
//! A `Welcome` makes the worker rebuild its arena from the carried
//! geometry (dropping all cached blocks — the KV is rebuilt by replay, so
//! nothing stale can survive) and echo the new epoch on every subsequent
//! `KvStats`. The leader's post-reshard barrier sends `KvStatsReq` on
//! every link and discards replies whose epoch predates the current
//! membership, so an in-flight snapshot (or any frame queued behind it)
//! from a dead geometry can never alias into the new one. Leader-side
//! request state is rebuilt via the PR 6 promoted-token replay, which is
//! what makes a degraded or adopted run **bit-identical** to an unfailed
//! one on the native backend.
//!
//! After any *successful* reshard the leader resets every surviving
//! worker's [`HealthTracker`] (see [`HealthTracker::reset`]): a later,
//! unrelated death must face the full retry ladder again rather than
//! inheriting strikes accumulated before the recovery.

use std::time::Duration;

use crate::devices::roofline::mtime;
use crate::devices::specs::{DeviceSpec, LlmSpec};
use crate::net::TransportError;

/// Leader-side health policy knobs (CLI: `--recv-deadline-ms`,
/// `--recv-retries`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthPolicy {
    /// Per-attempt receive deadline for worker replies.
    pub recv_deadline: Duration,
    /// Extra attempts after the first expiry before declaring death.
    pub recv_retries: u32,
    /// Deadline multiplier per retry (exponential backoff).
    pub backoff: f64,
}

impl Default for HealthPolicy {
    fn default() -> HealthPolicy {
        HealthPolicy {
            // generous against CI scheduling noise; a real deployment
            // would tune this near the p99.9 step latency
            recv_deadline: Duration::from_secs(5),
            recv_retries: 2,
            backoff: 2.0,
        }
    }
}

impl HealthPolicy {
    /// Deadline for the `attempt`-th receive try (0-based): the base
    /// deadline scaled by `backoff^attempt`, saturating sanely.
    pub fn attempt_deadline(&self, attempt: u32) -> Duration {
        let scale = self.backoff.max(1.0).powi(attempt.min(16) as i32);
        self.recv_deadline.mul_f64(scale)
    }

    /// Total attempts a blocking receive makes before declaring death.
    pub fn attempts(&self) -> u32 {
        self.recv_retries + 1
    }
}

/// Elastic-membership policy knobs (CLI: `--no-respawn`, `--min-workers`).
/// Decides what the leader does when a worker death survives the retry
/// ladder: respawn a replacement at the same width (the PR 8 behaviour),
/// or degrade the pool to the survivors — down to a floor below which the
/// session fails typed instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MembershipPolicy {
    /// Respawn a replacement worker on death (`--no-respawn` clears this;
    /// a cleared flag makes every death a degradation).
    pub allow_respawn: bool,
    /// Minimum pool width to keep serving at; degrading below it is a
    /// typed session failure with zero leaked blocks.
    pub min_workers: usize,
}

impl Default for MembershipPolicy {
    fn default() -> MembershipPolicy {
        MembershipPolicy { allow_respawn: true, min_workers: 1 }
    }
}

impl MembershipPolicy {
    /// Whether the pool may keep serving at `survivors` workers.
    pub fn can_degrade_to(&self, survivors: usize) -> bool {
        survivors >= self.min_workers.max(1)
    }
}

/// Why a worker was declared dead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeathCause {
    /// All receive attempts timed out — the worker (or its link) hangs.
    Hang,
    /// The link reported the peer gone.
    Disconnected,
    /// The worker sent bytes that failed frame validation.
    Corrupt,
    /// The worker reported a fatal error of its own (`WorkerError`).
    Protocol(String),
}

impl DeathCause {
    /// Stable low-cardinality label (metrics / spans).
    pub fn name(&self) -> &'static str {
        match self {
            DeathCause::Hang => "hang",
            DeathCause::Disconnected => "disconnected",
            DeathCause::Corrupt => "corrupt",
            DeathCause::Protocol(_) => "protocol",
        }
    }

    /// Classify a transport error (used once retries are exhausted for
    /// `TimedOut`; fatal errors classify immediately).
    pub fn of_transport(e: &TransportError) -> DeathCause {
        match e {
            TransportError::TimedOut => DeathCause::Hang,
            TransportError::Disconnected { .. } => DeathCause::Disconnected,
            TransportError::Codec(_) => DeathCause::Corrupt,
            TransportError::Io { msg, .. } => DeathCause::Protocol(msg.clone()),
        }
    }
}

impl std::fmt::Display for DeathCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeathCause::Protocol(msg) => write!(f, "protocol: {msg}"),
            other => f.write_str(other.name()),
        }
    }
}

/// Typed "worker `worker` is dead" failure the leader propagates instead
/// of panicking; `step()` catches it and runs recovery.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerDeath {
    pub worker: usize,
    pub cause: DeathCause,
}

impl std::fmt::Display for WorkerDeath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "attention worker {} declared dead: {}", self.worker, self.cause)
    }
}

impl std::error::Error for WorkerDeath {}

/// Typed terminal membership failure: a worker died, respawn is disabled,
/// and the surviving pool would fall below the [`MembershipPolicy`] floor.
/// Unlike [`WorkerDeath`] this is **not** recoverable — the leader refuses
/// to degrade, flushes what bookkeeping it can (zero leaked KV blocks on
/// the survivors), and surfaces this to the caller on every subsequent
/// step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipRefused {
    /// Workers that would remain after dropping the dead one.
    pub survivors: usize,
    /// The effective `min_workers` floor (≥ 1).
    pub floor: usize,
    /// Why the dead worker was condemned.
    pub cause: DeathCause,
}

impl std::fmt::Display for MembershipRefused {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot degrade to {} worker(s): below the --min-workers floor {} (death: {})",
            self.survivors, self.floor, self.cause
        )
    }
}

impl std::error::Error for MembershipRefused {}

/// Per-worker strike bookkeeping for the retry ladder. One tracker per
/// worker link lives on the leader; strikes accumulate across *separate*
/// receives too (a worker that limps from deadline to deadline without
/// ever completing a step is also dead, even if each call squeaks by).
#[derive(Debug, Clone, Default)]
pub struct HealthTracker {
    strikes: u32,
}

/// Verdict of [`HealthTracker::on_timeout`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Try again with [`HealthPolicy::attempt_deadline`] of the returned
    /// attempt number.
    Retry(u32),
    /// Retries exhausted: declare the worker dead.
    Dead,
}

impl HealthTracker {
    /// A message arrived: the worker is alive, forget prior strikes.
    pub fn on_alive(&mut self) {
        self.strikes = 0;
    }

    /// A receive deadline expired; decide whether to retry or declare.
    pub fn on_timeout(&mut self, policy: &HealthPolicy) -> Verdict {
        self.strikes += 1;
        if self.strikes >= policy.attempts() {
            Verdict::Dead
        } else {
            Verdict::Retry(self.strikes)
        }
    }

    pub fn strikes(&self) -> u32 {
        self.strikes
    }

    /// Forget all strikes. Called for every *surviving* worker after a
    /// successful recovery/reshard: `on_alive` only fires on the link that
    /// received a message, so without this a worker that accumulated
    /// strikes while the pool was busy recovering from an unrelated death
    /// would face a later failure with an already-exhausted ladder.
    pub fn reset(&mut self) {
        self.strikes = 0;
    }
}

/// Time to reconstruct the lost KV shard by re-processing every affected
/// request's tokens through the model (prefill-style, compute-bound on the
/// model pool). `tokens_lost` = Σ per-request context length × the failed
/// worker's head share.
pub fn kv_rebuild_time(
    model: &LlmSpec,
    model_dev: &DeviceSpec,
    tp: usize,
    tokens_lost: usize,
    prefill_chunk: usize,
) -> f64 {
    if tokens_lost == 0 {
        return 0.0;
    }
    // Re-run tokens in chunks through the non-attention path (the dominant
    // cost; attention during rebuild is over the partial rebuilt cache and
    // folded into the same roofline bound).
    let chunks = tokens_lost.div_ceil(prefill_chunk);
    let per_chunk = mtime(model, model_dev, prefill_chunk.max(1), tp).time_s;
    chunks as f64 * per_chunk
}

/// Head-share of KV lost when one of `workers` attention workers fails
/// under head-level partitioning.
pub fn lost_fraction(workers: usize) -> f64 {
    1.0 / workers as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, LLAMA3_70B};
    use crate::net::CodecError;

    #[test]
    fn backoff_ladder_scales_deadlines() {
        let p = HealthPolicy {
            recv_deadline: Duration::from_millis(100),
            recv_retries: 2,
            backoff: 2.0,
        };
        assert_eq!(p.attempts(), 3);
        assert_eq!(p.attempt_deadline(0), Duration::from_millis(100));
        assert_eq!(p.attempt_deadline(1), Duration::from_millis(200));
        assert_eq!(p.attempt_deadline(2), Duration::from_millis(400));
        // backoff < 1 never shrinks the deadline
        let flat = HealthPolicy { backoff: 0.5, ..p };
        assert_eq!(flat.attempt_deadline(3), Duration::from_millis(100));
    }

    #[test]
    fn tracker_retries_then_declares_then_resets() {
        let p = HealthPolicy {
            recv_deadline: Duration::from_millis(10),
            recv_retries: 2,
            backoff: 1.0,
        };
        let mut t = HealthTracker::default();
        assert_eq!(t.on_timeout(&p), Verdict::Retry(1));
        assert_eq!(t.on_timeout(&p), Verdict::Retry(2));
        assert_eq!(t.on_timeout(&p), Verdict::Dead);
        t.on_alive();
        assert_eq!(t.strikes(), 0);
        assert_eq!(t.on_timeout(&p), Verdict::Retry(1));
    }

    #[test]
    fn zero_retries_declares_immediately() {
        let p = HealthPolicy {
            recv_deadline: Duration::from_millis(10),
            recv_retries: 0,
            backoff: 1.0,
        };
        let mut t = HealthTracker::default();
        assert_eq!(t.on_timeout(&p), Verdict::Dead);
    }

    #[test]
    fn death_causes_classify_and_label() {
        assert_eq!(DeathCause::of_transport(&TransportError::TimedOut), DeathCause::Hang);
        assert_eq!(
            DeathCause::of_transport(&TransportError::Disconnected { mid_frame: true }),
            DeathCause::Disconnected
        );
        assert_eq!(
            DeathCause::of_transport(&TransportError::Codec(CodecError::BadChecksum {
                want: 1,
                got: 2
            })),
            DeathCause::Corrupt
        );
        let d = WorkerDeath { worker: 3, cause: DeathCause::Hang };
        assert_eq!(d.to_string(), "attention worker 3 declared dead: hang");
        assert_eq!(DeathCause::Protocol("x".into()).name(), "protocol");
    }

    #[test]
    fn rebuild_time_scales_with_tokens() {
        let t1 = kv_rebuild_time(&LLAMA3_70B, &H100, 2, 100_000, 512);
        let t2 = kv_rebuild_time(&LLAMA3_70B, &H100, 2, 200_000, 512);
        assert!(t1 > 0.0);
        assert!((t2 / t1 - 2.0).abs() < 0.05);
        assert_eq!(kv_rebuild_time(&LLAMA3_70B, &H100, 2, 0, 512), 0.0);
    }

    #[test]
    fn rebuild_seconds_not_hours() {
        // Losing 1/4 of a 300-request × 4k-context batch's KV must rebuild
        // in seconds — the practicality claim behind §5.
        let tokens = 300 * 4096 / 4;
        let t = kv_rebuild_time(&LLAMA3_70B, &H100, 2, tokens, 512);
        assert!(t < 60.0, "rebuild {t}s");
    }

    #[test]
    fn lost_fraction_head_level() {
        assert_eq!(lost_fraction(4), 0.25);
    }

    #[test]
    fn tracker_reset_restores_full_ladder() {
        let p = HealthPolicy {
            recv_deadline: Duration::from_millis(10),
            recv_retries: 2,
            backoff: 1.0,
        };
        let mut t = HealthTracker::default();
        assert_eq!(t.on_timeout(&p), Verdict::Retry(1));
        assert_eq!(t.on_timeout(&p), Verdict::Retry(2));
        // recovery completed elsewhere: the survivor's ladder is restored
        t.reset();
        assert_eq!(t.strikes(), 0);
        assert_eq!(t.on_timeout(&p), Verdict::Retry(1));
        assert_eq!(t.on_timeout(&p), Verdict::Retry(2));
        assert_eq!(t.on_timeout(&p), Verdict::Dead);
    }

    #[test]
    fn membership_policy_floor() {
        let m = MembershipPolicy::default();
        assert!(m.allow_respawn);
        assert!(m.can_degrade_to(1));
        let m = MembershipPolicy { allow_respawn: false, min_workers: 2 };
        assert!(m.can_degrade_to(3));
        assert!(m.can_degrade_to(2));
        assert!(!m.can_degrade_to(1));
        // a zero floor still refuses an empty pool
        let m = MembershipPolicy { allow_respawn: false, min_workers: 0 };
        assert!(m.can_degrade_to(1));
        assert!(!m.can_degrade_to(0));
    }
}
