//! Decode-serving simulator for the disaggregated (Lamina) engine at paper
//! scale (LLaMA-33B/65B/70B on H100+H20 pools).
//!
//! The real testbed is hardware we do not have (DESIGN.md §2): iteration
//! costs come from the calibrated roofline model (`devices::roofline`) and
//! the network-stack models (`netsim::stack`), while *all the systems logic*
//! — continuous batching, KV admission control, staggered pipelining,
//! per-layer communication with optional resource-utilisation overlapping —
//! runs for real. This regenerates Figs. 10, 11, 12 and 14.

use crate::coordinator::batcher::ContinuousBatcher;
use crate::coordinator::pipeline::StaggerPlan;
use crate::devices::roofline::{atime_tokens, max_batch_disaggregated, mtime};
use crate::devices::specs::{DeviceSpec, LlmSpec};
use crate::metrics::{ServeMetrics, StepBreakdown};
use crate::netsim::stack::{NetStackModel, LINE_RATE_400G};
use crate::opgraph::schedule::{layer_latency_overlapped, layer_latency_sequential, LayerTimings};
use crate::trace::Request;

/// Configuration of one Lamina deployment.
#[derive(Debug, Clone)]
pub struct LaminaConfig {
    pub model: &'static LlmSpec,
    pub model_dev: &'static DeviceSpec,
    pub attn_dev: &'static DeviceSpec,
    /// DOP = (a, b): `a` model GPUs (tensor-parallel, one replica per
    /// `a/replicas` group) and `b` attention GPUs.
    pub dop: (usize, usize),
    /// Concurrent batches n (staggered pipelining); replicas = n-1.
    pub concurrent_batches: usize,
    pub stack: &'static NetStackModel,
    /// Enable §4.2.2 resource-utilisation overlapping.
    pub overlap: bool,
    /// Fraction of attention-pool memory usable for KV.
    pub mem_util: f64,
    /// Per-iteration scheduling overhead (Ray-style task dispatch).
    pub sched_overhead_s: f64,
    /// Cap on per-wave batch size.
    pub max_batch: usize,
}

impl LaminaConfig {
    /// Table-5 style constructor: n = 2 concurrent batches, overlap on.
    pub fn standard(
        model: &'static LlmSpec,
        model_dev: &'static DeviceSpec,
        attn_dev: &'static DeviceSpec,
        dop: (usize, usize),
        stack: &'static NetStackModel,
    ) -> Self {
        LaminaConfig {
            model,
            model_dev,
            attn_dev,
            dop,
            concurrent_batches: 2,
            stack,
            overlap: true,
            mem_util: 0.92,
            sched_overhead_s: 150e-6,
            max_batch: 1024,
        }
    }

    pub fn cost_per_hour(&self) -> f64 {
        self.dop.0 as f64 * self.model_dev.price_hr + self.dop.1 as f64 * self.attn_dev.price_hr
    }

    /// Replicas of the model (n-1); `dop.0` GPUs are split across them.
    pub fn replicas(&self) -> usize {
        self.concurrent_batches.saturating_sub(1).max(1)
    }

    /// Tensor-parallel degree within one model replica.
    pub fn tp_per_replica(&self) -> usize {
        (self.dop.0 / self.replicas()).max(1)
    }

    /// KV capacity in tokens across the attention pool.
    pub fn kv_capacity_tokens(&self) -> usize {
        max_batch_disaggregated(self.model, self.attn_dev, self.dop.1, 1, self.mem_util)
    }
}

/// Result of one simulated serving run.
#[derive(Debug)]
pub struct SimReport {
    pub metrics: ServeMetrics,
    pub config_cost_hr: f64,
    /// Throughput normalised by $/hr (Fig. 11's cost-efficiency).
    pub tokens_per_dollar: f64,
}

/// Per-iteration cost of one wave (used by the figure harnesses too).
#[derive(Debug, Clone, Copy)]
pub struct WaveCost {
    pub t_model: f64,
    pub t_attn: f64,
    pub t_net_visible: f64,
    pub tbt: f64,
}

/// Compute the decode-iteration cost for a wave of `batch` requests with
/// `total_ctx` cached tokens under `cfg`.
pub fn wave_cost(cfg: &LaminaConfig, batch: usize, total_ctx: usize) -> WaveCost {
    let l = cfg.model.layers as f64;
    let t_model = mtime(cfg.model, cfg.model_dev, batch, cfg.tp_per_replica()).time_s;
    let t_attn = atime_tokens(cfg.model, cfg.attn_dev, total_ctx as f64, cfg.dop.1).time_s;

    let e = cfg.model.elem_bytes;
    let d = cfg.model.d as f64;
    let g = cfg.model.gqa_group as f64;
    let b = batch as f64;
    let q_bytes = e * d * b;
    let kv_bytes = 2.0 * e * d / g * b;
    let out_bytes = e * d * b;

    // Q is ready once the previous layer's FFN + Q-proj finish; only the
    // K/V projections (2·d²/G of GEMM volume) can execute after SendQ.
    // GEMM volume per slice ≈ o(1) + q(1) + kv(2/G) + ffn(3·3.5) in d² units.
    let kv_share = (2.0 / g) / (2.0 + 2.0 / g + 10.5);
    let t = LayerTimings {
        t_slice: t_model / l,
        q_ready_frac: 1.0 - kv_share,
        t_attn_prev: t_attn / l,
        t_attn_new: 2e-6,
        net_q: cfg.stack.one_way(q_bytes, LINE_RATE_400G),
        net_kv: cfg.stack.one_way(kv_bytes, LINE_RATE_400G),
        net_out: cfg.stack.one_way(out_bytes, LINE_RATE_400G),
    };
    let per_layer = if cfg.overlap {
        layer_latency_overlapped(&t)
    } else {
        layer_latency_sequential(&t)
    };
    let critical_path = per_layer * l;

    // Steady-state TBT: the staggered pipeline bounds (shared pools) and the
    // wave's own critical path.
    let plan = StaggerPlan::new(cfg.concurrent_batches, t_model, t_attn);
    let tbt = plan.tbt().max(critical_path) + cfg.sched_overhead_s;

    WaveCost {
        t_model,
        t_attn,
        t_net_visible: (critical_path - t_model - t_attn).max(0.0),
        tbt,
    }
}

/// Run a closed-loop decode-only serving simulation: all requests queued at
/// t=0 (the paper's throughput experiments replay traces decode-only).
pub fn run_lamina(cfg: &LaminaConfig, requests: &[Request]) -> SimReport {
    let capacity = cfg.kv_capacity_tokens();
    let n = cfg.concurrent_batches;
    // one batcher per concurrent wave; KV capacity split evenly (all waves
    // share the pool; even split is the steady-state share)
    let mut waves: Vec<ContinuousBatcher> = (0..n)
        .map(|_| ContinuousBatcher::new(capacity / n, cfg.max_batch))
        .collect();
    for (i, r) in requests.iter().enumerate() {
        waves[i % n].submit(*r);
    }

    let mut metrics = ServeMetrics::new();
    let max_iters = 100_000_000u64;
    let mut iters = 0u64;
    while waves.iter().any(|w| !w.is_idle()) {
        iters += 1;
        assert!(iters < max_iters, "simulation not draining");
        let mut round_batch = 0usize;
        let mut worst = WaveCost { t_model: 0.0, t_attn: 0.0, t_net_visible: 0.0, tbt: 0.0 };
        for w in waves.iter_mut() {
            w.admit();
        }
        // Steady-state measurement (the paper replays 8–23k-request traces
        // and reports sustained throughput): only record while the system
        // still has backlog — the drain tail is not steady state.
        let loaded = waves.iter().all(|w| w.waiting_len() > 0);
        // The staggered rounds share the attention pool: the round's TBT is
        // the max over waves (they are phase-shifted, same period).
        for w in waves.iter() {
            if w.batch_size() == 0 {
                continue;
            }
            let c = wave_cost(cfg, w.batch_size(), w.total_context());
            if c.tbt > worst.tbt {
                worst = c;
            }
        }
        for w in waves.iter_mut() {
            if w.batch_size() == 0 {
                continue;
            }
            let (batch, done) = w.step();
            round_batch += batch;
            metrics.record_completion(done.len() as u64);
        }
        if round_batch == 0 {
            // nothing running (all remaining requests too big) — bail
            break;
        }
        if loaded || metrics.steps() == 0 {
            metrics.record_step(
                round_batch,
                StepBreakdown {
                    model_s: worst.t_model,
                    attn_s: worst.t_attn,
                    network_s: worst.t_net_visible,
                    sched_s: cfg.sched_overhead_s,
                    total_s: worst.tbt,
                },
            );
        }
    }

    let cost = cfg.cost_per_hour();
    let thr = metrics.throughput();
    SimReport { metrics, config_cost_hr: cost, tokens_per_dollar: thr * 3600.0 / cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, H20, LLAMA3_70B, LLAMA_65B};
    use crate::netsim::stack::FHBN;
    use crate::trace::fixed_length;

    fn cfg70b() -> LaminaConfig {
        LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN)
    }

    #[test]
    fn drains_all_requests() {
        let cfg = cfg70b();
        let reqs = fixed_length(64, 1024, 8);
        let rep = run_lamina(&cfg, &reqs);
        assert_eq!(rep.metrics.requests_completed, 64);
        // steady-state gating records at most the total token count
        assert!(rep.metrics.tokens_generated > 0);
        assert!(rep.metrics.tokens_generated <= 64 * 8);
    }

    #[test]
    fn tbt_in_plausible_range() {
        // 70B decode TBT on this class of hardware: tens of ms.
        let cfg = cfg70b();
        let reqs = fixed_length(128, 4096, 16);
        let rep = run_lamina(&cfg, &reqs);
        let tbt = rep.metrics.mean_tbt();
        assert!(tbt > 5e-3 && tbt < 0.4, "tbt={tbt}");
    }

    #[test]
    fn overlap_improves_tbt() {
        // Like the paper's Fig. 14 protocol: rotational pipelining disabled
        // so the critical path (where overlap acts) is the TBT.
        let base = LaminaConfig { concurrent_batches: 1, ..cfg70b() };
        let reqs = fixed_length(96, 4096, 8);
        let on = run_lamina(&base, &reqs);
        let off = run_lamina(&LaminaConfig { overlap: false, ..base }, &reqs);
        assert!(
            on.metrics.mean_tbt() < off.metrics.mean_tbt(),
            "on={} off={}",
            on.metrics.mean_tbt(),
            off.metrics.mean_tbt()
        );
    }

    #[test]
    fn more_attention_workers_more_throughput() {
        // Fig. 11: adding attention workers grows attainable batch.
        let reqs = fixed_length(600, 4096, 8);
        let small = run_lamina(
            &LaminaConfig::standard(&LLAMA_65B, &H100, &H20, (2, 2), &FHBN),
            &reqs,
        );
        let large = run_lamina(
            &LaminaConfig::standard(&LLAMA_65B, &H100, &H20, (2, 6), &FHBN),
            &reqs,
        );
        assert!(
            large.metrics.throughput() > 1.2 * small.metrics.throughput(),
            "small={} large={}",
            small.metrics.throughput(),
            large.metrics.throughput()
        );
    }

    #[test]
    fn kv_capacity_bounds_batch() {
        let cfg = LaminaConfig::standard(&LLAMA_65B, &H100, &H20, (2, 2), &FHBN);
        // 65B MHA: KV/token = 2·2·8192·80 = 2.6 MB; 2×H20 ≈ 190 GB → ~72k tokens.
        let cap = cfg.kv_capacity_tokens();
        assert!(cap > 50_000 && cap < 100_000, "cap={cap}");
        let reqs = fixed_length(512, 8192, 4);
        let rep = run_lamina(&cfg, &reqs);
        // mean batch bounded by capacity/context ≈ 72k/8.2k ≈ 8
        assert!(rep.metrics.mean_batch() < 16.0, "batch={}", rep.metrics.mean_batch());
    }

    #[test]
    fn wave_cost_components_positive() {
        let cfg = cfg70b();
        let c = wave_cost(&cfg, 64, 64 * 4096);
        assert!(c.t_model > 0.0 && c.t_attn > 0.0 && c.tbt >= c.t_model);
    }

    #[test]
    fn gqa_model_supports_bigger_batches() {
        // Fig. 10 note: 70B (GQA) reaches much larger batches than 65B.
        let c70 = cfg70b();
        let c65 = LaminaConfig::standard(&LLAMA_65B, &H100, &H20, (2, 4), &FHBN);
        assert!(c70.kv_capacity_tokens() > 6 * c65.kv_capacity_tokens());
    }
}
