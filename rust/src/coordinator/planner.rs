//! Hardware-configuration planner (paper Table 5 & Fig. 11): enumerate
//! DOP/TP configurations, compute hourly cost, simulate throughput, and
//! select cost-efficient deployments.

use crate::baseline::vllm::{run_vllm, VllmConfig};
use crate::coordinator::sim::{run_lamina, LaminaConfig};
use crate::devices::specs::{DeviceSpec, LlmSpec};
use crate::netsim::stack::NetStackModel;
use crate::trace::Request;

/// One planned configuration and its simulated outcome.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub label: String,
    pub cost_hr: f64,
    pub throughput_tps: f64,
    pub tokens_per_dollar: f64,
    pub mean_batch: f64,
    pub mean_tbt_s: f64,
}

/// Sweep Lamina DOPs (Fig. 11 heterogeneous series).
pub fn sweep_lamina_dops(
    model: &'static LlmSpec,
    model_dev: &'static DeviceSpec,
    attn_dev: &'static DeviceSpec,
    stack: &'static NetStackModel,
    dops: &[(usize, usize)],
    requests: &[Request],
) -> Vec<PlanPoint> {
    dops.iter()
        .map(|&dop| {
            let cfg = LaminaConfig::standard(model, model_dev, attn_dev, dop, stack);
            let rep = run_lamina(&cfg, requests);
            let m = rep.metrics;
            PlanPoint {
                label: format!("Lamina({},{})", dop.0, dop.1),
                cost_hr: rep.config_cost_hr,
                throughput_tps: m.throughput(),
                tokens_per_dollar: rep.tokens_per_dollar,
                mean_batch: m.mean_batch(),
                mean_tbt_s: m.mean_tbt(),
            }
        })
        .collect()
}

/// Sweep vLLM TP degrees (Fig. 11 homogeneous series). Skips configurations
/// where the model does not fit.
pub fn sweep_vllm_tps(
    model: &'static LlmSpec,
    dev: &'static DeviceSpec,
    tps: &[usize],
    requests: &[Request],
) -> Vec<PlanPoint> {
    tps.iter()
        .filter_map(|&tp| {
            let cfg = VllmConfig::standard(model, dev, tp);
            if !cfg.fits() {
                return None;
            }
            let rep = run_vllm(&cfg, requests);
            let m = rep.metrics;
            Some(PlanPoint {
                label: format!("vLLM-TP{tp}"),
                cost_hr: rep.config_cost_hr,
                throughput_tps: m.throughput(),
                tokens_per_dollar: rep.tokens_per_dollar,
                mean_batch: m.mean_batch(),
                mean_tbt_s: m.mean_tbt(),
            })
        })
        .collect()
}

/// The most cost-efficient point of a sweep (Fig. 11 bolds it).
pub fn best_cost_efficiency(points: &[PlanPoint]) -> Option<&PlanPoint> {
    points.iter().max_by(|a, b| {
        a.tokens_per_dollar
            .partial_cmp(&b.tokens_per_dollar)
            .unwrap()
    })
}

/// Table 5's equal-cost pairings: for each model, the Lamina DOP and the
/// vLLM TP whose hourly costs are closest.
pub fn table5_configs(model: &'static LlmSpec) -> ((usize, usize), usize) {
    // Paper: 33B → DOP=(1,2) vs 2×H100; 65B/70B → DOP=(2,4) vs 4×H100.
    if model.name.contains("33B") {
        ((1, 2), 2)
    } else {
        ((2, 4), 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, H20, LLAMA3_70B, LLAMA_33B, LLAMA_65B};
    use crate::netsim::stack::FHBN;
    use crate::trace::fixed_length;

    #[test]
    fn table5_costs_comparable() {
        // Lamina must cost at most the vLLM baseline (paper: 20.32 vs 22.12
        // and 40.64 vs 44.24 $/hr).
        for model in [&LLAMA_33B, &LLAMA_65B, &LLAMA3_70B] {
            let (dop, tp) = table5_configs(model);
            let lamina = LaminaConfig::standard(model, &H100, &H20, dop, &FHBN);
            let vllm = VllmConfig::standard(model, &H100, tp);
            assert!(lamina.cost_per_hour() < vllm.cost_per_hour());
            assert!(lamina.cost_per_hour() > 0.85 * vllm.cost_per_hour());
        }
    }

    #[test]
    fn table5_exact_dollar_values() {
        let lamina = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
        assert!((lamina.cost_per_hour() - 40.64).abs() < 0.01);
        let vllm = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
        assert!((vllm.cost_per_hour() - 44.24).abs() < 0.01);
    }

    #[test]
    fn sweep_produces_points_and_best() {
        let reqs = fixed_length(96, 2048, 4);
        let pts = sweep_lamina_dops(
            &LLAMA_65B, &H100, &H20, &FHBN,
            &[(2, 2), (2, 4)],
            &reqs,
        );
        assert_eq!(pts.len(), 2);
        assert!(best_cost_efficiency(&pts).is_some());
        assert!(pts.iter().all(|p| p.throughput_tps > 0.0));
    }

    #[test]
    fn vllm_sweep_skips_nonfitting() {
        let reqs = fixed_length(16, 512, 2);
        let pts = sweep_vllm_tps(&LLAMA3_70B, &H100, &[1, 2, 4], &reqs);
        // TP=1 (80 GB) and TP=2 (160 GB > 137.5 GB ✓) → TP1 skipped.
        assert_eq!(pts.len(), 2);
        assert!(pts[0].label.contains("TP2"));
    }

    #[test]
    fn throughput_grows_with_attention_workers_in_sweep() {
        let reqs = fixed_length(400, 4096, 4);
        let pts = sweep_lamina_dops(
            &LLAMA_65B, &H100, &H20, &FHBN,
            &[(2, 2), (2, 4), (2, 6)],
            &reqs,
        );
        assert!(pts[1].throughput_tps > pts[0].throughput_tps);
        assert!(pts[2].throughput_tps >= pts[1].throughput_tps * 0.95);
    }
}
