//! The Lamina coordinator — the paper's L3 systems contribution: continuous
//! batching, rotational staggered pipelining, DOP planning, failover, and
//! the serving simulator that drives the paper-scale experiments.

pub mod batcher;
pub mod failover;
pub mod openloop;
pub mod pipeline;
pub mod planner;
pub mod sim;

pub use batcher::ContinuousBatcher;
pub use pipeline::StaggerPlan;
pub use sim::{run_lamina, LaminaConfig, SimReport};
