//! Homogeneous tensor-parallel decode baseline (the paper's §6 comparator:
//! vLLM on H100s, prefill removed, continuous batching, paged KV).
//!
//! Same batching/admission logic as the Lamina simulator, same roofline cost
//! model, same device specs — the only differences are architectural: model
//! and attention share the H100s (no disaggregation, no pipelining, no
//! cross-pool network), and KV capacity is what the weights leave free.

use crate::coordinator::batcher::ContinuousBatcher;
use crate::coordinator::sim::SimReport;
use crate::devices::roofline::{atime_tokens, max_batch_homogeneous, mtime};
use crate::devices::specs::{DeviceSpec, LlmSpec};
use crate::metrics::{ServeMetrics, StepBreakdown};
use crate::trace::Request;

#[derive(Debug, Clone)]
pub struct VllmConfig {
    pub model: &'static LlmSpec,
    pub dev: &'static DeviceSpec,
    /// Tensor-parallel degree = number of GPUs.
    pub tp: usize,
    pub mem_util: f64,
    pub sched_overhead_s: f64,
    /// vLLM's `max_num_seqs` scheduler cap (default 256 upstream).
    pub max_batch: usize,
    /// Achievable fraction of peak HBM bandwidth for PagedAttention:
    /// block-table indirection and fragmented 16-token block reads keep the
    /// paged kernel below the dense-streaming efficiency the attention
    /// workers reach on contiguous caches (Lamina stores per-worker dense
    /// shards). 0.62 is a conservative published-benchmarks figure.
    pub attn_bw_eff: f64,
}

impl VllmConfig {
    pub fn standard(model: &'static LlmSpec, dev: &'static DeviceSpec, tp: usize) -> Self {
        VllmConfig {
            model,
            dev,
            tp,
            mem_util: 0.92,
            sched_overhead_s: 100e-6,
            max_batch: 256,
            attn_bw_eff: 0.62,
        }
    }

    pub fn cost_per_hour(&self) -> f64 {
        self.tp as f64 * self.dev.price_hr
    }

    /// KV token capacity: pool memory minus weights.
    pub fn kv_capacity_tokens(&self) -> usize {
        max_batch_homogeneous(self.model, self.dev, self.tp, 1, self.mem_util)
    }

    /// Whether the model even fits on this pool.
    pub fn fits(&self) -> bool {
        self.model.param_bytes() < self.dev.mem_bytes() * self.tp as f64 * self.mem_util
    }
}

/// One decode iteration's cost on the homogeneous pool.
pub fn vllm_step_cost(cfg: &VllmConfig, batch: usize, total_ctx: usize) -> StepBreakdown {
    let m = mtime(cfg.model, cfg.dev, batch, cfg.tp);
    let a = atime_tokens(cfg.model, cfg.dev, total_ctx as f64, cfg.tp);
    // attention is memory-bound: paged-gather efficiency scales its time
    let attn_s = a.time_s * (cfg.dev.bw_eff / cfg.attn_bw_eff);
    StepBreakdown {
        model_s: m.time_s,
        attn_s,
        network_s: 0.0, // NVLink collectives are inside mtime
        sched_s: cfg.sched_overhead_s,
        total_s: m.time_s + attn_s + cfg.sched_overhead_s,
    }
}

/// Closed-loop decode-only run (mirrors `run_lamina`).
pub fn run_vllm(cfg: &VllmConfig, requests: &[Request]) -> SimReport {
    assert!(cfg.fits(), "{} does not fit on {}×{}", cfg.model.name, cfg.tp, cfg.dev.name);
    let mut batcher = ContinuousBatcher::new(cfg.kv_capacity_tokens(), cfg.max_batch);
    batcher.submit_all(requests.iter().copied());

    let mut metrics = ServeMetrics::new();
    let mut iters = 0u64;
    while !batcher.is_idle() {
        iters += 1;
        assert!(iters < 100_000_000, "simulation not draining");
        batcher.admit();
        if batcher.batch_size() == 0 {
            break; // remaining requests can never fit
        }
        // Steady-state gating: drop the drain tail (see run_lamina).
        let loaded = batcher.waiting_len() > 0;
        let bd = vllm_step_cost(cfg, batcher.batch_size(), batcher.total_context());
        let (batch, done) = batcher.step();
        metrics.record_completion(done.len() as u64);
        if loaded || metrics.steps() == 0 {
            metrics.record_step(batch, bd);
        }
    }

    let cost = cfg.cost_per_hour();
    let thr = metrics.throughput();
    SimReport { metrics, config_cost_hr: cost, tokens_per_dollar: thr * 3600.0 / cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, LLAMA3_70B, LLAMA_33B, LLAMA_65B};
    use crate::trace::fixed_length;

    #[test]
    fn model_fit_checks() {
        assert!(VllmConfig::standard(&LLAMA_33B, &H100, 2).fits());
        assert!(!VllmConfig::standard(&LLAMA3_70B, &H100, 1).fits());
        assert!(VllmConfig::standard(&LLAMA3_70B, &H100, 4).fits());
    }

    #[test]
    fn kv_capacity_small_after_weights() {
        // 4×H100 = 320 GB; 70B weights 137.5 GB → ~157 GB KV at 0.92 util.
        let cfg = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
        let cap = cfg.kv_capacity_tokens();
        // 157 GB / 327 680 B per token ≈ 480k tokens
        assert!(cap > 300_000 && cap < 600_000, "cap={cap}");
        // For MHA 65B it is far smaller: weights 130 GB, KV/token 2.6 MB.
        let cfg65 = VllmConfig::standard(&LLAMA_65B, &H100, 4);
        assert!(cfg65.kv_capacity_tokens() < 80_000);
    }

    #[test]
    fn drains_and_counts() {
        let cfg = VllmConfig::standard(&LLAMA_33B, &H100, 2);
        let reqs = fixed_length(32, 512, 8);
        let rep = run_vllm(&cfg, &reqs);
        assert_eq!(rep.metrics.requests_completed, 32);
        // steady-state gating records at most the total token count
        assert!(rep.metrics.tokens_generated > 0);
        assert!(rep.metrics.tokens_generated <= 32 * 8);
    }

    #[test]
    fn throughput_positive_and_batch_bounded() {
        let cfg = VllmConfig::standard(&LLAMA_65B, &H100, 4);
        let reqs = fixed_length(256, 8192, 8);
        let rep = run_vllm(&cfg, &reqs);
        assert!(rep.metrics.throughput() > 0.0);
        // 65B at 8k ctx: capacity ~55k tokens → batch ≲ 7
        assert!(rep.metrics.mean_batch() < 10.0, "batch={}", rep.metrics.mean_batch());
    }

    #[test]
    #[should_panic]
    fn run_panics_if_model_does_not_fit() {
        let cfg = VllmConfig::standard(&LLAMA3_70B, &H100, 1);
        run_vllm(&cfg, &fixed_length(1, 10, 1));
    }
}
