//! Baseline systems the paper compares against (vLLM on homogeneous H100s,
//! decode-only, continuous batching).

pub mod vllm;

pub use vllm::{run_vllm, VllmConfig};
