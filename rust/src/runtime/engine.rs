//! The PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client (once per entry point, cached), uploads weights to
//! device buffers (once), and executes decode-step slices / attention calls
//! from the Rust serving path. Python never runs here.
//!
//! Interchange is HLO **text** — see `/opt/xla-example/README.md`: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects in proto
//! form; the text parser reassigns ids.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::host::{Dtype, HostTensor};
use super::manifest::Manifest;
use super::weights::Weights;

/// Compiled-executable cache key: (entry, batch bucket, seq bucket).
type Key = (String, usize, usize);

/// The engine owns the PJRT client, the executable cache and the
/// device-resident weights.
pub struct Engine {
    pub manifest: Manifest,
    pub weights: Weights,
    client: xla::PjRtClient,
    executables: Mutex<BTreeMap<Key, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Device buffers of weight tensors, keyed by tensor name.
    weight_bufs: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtBuffer>>>,
    /// Execution counters (perf accounting).
    pub stats: Mutex<EngineStats>,
}

#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub executions: u64,
    pub compilations: u64,
    pub upload_bytes: u64,
    pub exec_seconds: f64,
}

impl Engine {
    /// Load manifest + weights from `artifacts_dir` and create the CPU
    /// PJRT client. Executables compile lazily on first use.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir).map_err(|e| anyhow!(e.to_string()))?;
        let weights = Weights::load(&manifest).map_err(|e| anyhow!(e.to_string()))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            manifest,
            weights,
            client,
            executables: Mutex::new(BTreeMap::new()),
            weight_bufs: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    /// Pre-compile every entry point (optional warmup; otherwise lazy).
    pub fn warmup(&self) -> Result<()> {
        for e in &self.manifest.entrypoints {
            self.executable(&e.entry, e.batch, e.seq)?;
        }
        Ok(())
    }

    /// Pre-compile a single entry point.
    pub fn execute_warm(&self, entry: &str, batch: usize, seq: Option<usize>) -> Result<()> {
        self.executable(entry, batch, seq).map(|_| ())
    }

    fn executable(
        &self,
        entry: &str,
        batch: usize,
        seq: Option<usize>,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = (entry.to_string(), batch, seq.unwrap_or(0));
        if let Some(e) = self.executables.lock().unwrap().get(&key) {
            return Ok(std::sync::Arc::clone(e));
        }
        let ep = self
            .manifest
            .entrypoint(entry, batch, seq)
            .ok_or_else(|| anyhow!("no artifact for {entry} b{batch} s{seq:?}"))?;
        let path = self.manifest.hlo_path(ep);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", ep.file))?;
        let exe = std::sync::Arc::new(exe);
        self.stats.lock().unwrap().compilations += 1;
        self.executables
            .lock()
            .unwrap()
            .insert(key, std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Device buffer of a weight tensor (uploaded once, then reused).
    fn weight_buffer(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(b));
        }
        let t = self.weights.get(name);
        let buf = self
            .client
            .buffer_from_host_buffer(t.as_f32(), t.shape(), None)
            .with_context(|| format!("upload weight {name}"))?;
        let buf = std::sync::Arc::new(buf);
        self.stats.lock().unwrap().upload_bytes += t.byte_size() as u64;
        self.weight_bufs
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&buf));
        Ok(buf)
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        // `as_f32`/`as_i32` hand PJRT the view's slice directly — no host
        // staging copy even when `t` is an Arc-backed view.
        let buf = match t.dtype() {
            Dtype::F32 => self.client.buffer_from_host_buffer(t.as_f32(), t.shape(), None)?,
            Dtype::I32 => self.client.buffer_from_host_buffer(t.as_i32(), t.shape(), None)?,
        };
        Ok(buf)
    }

    fn download(buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        literal_to_host(&lit)
    }

    /// Execute an entry point: activations + named weight args (weights go
    /// as cached device buffers). Returns the output tuple as host tensors.
    pub fn execute(
        &self,
        entry: &str,
        batch: usize,
        seq: Option<usize>,
        activations: &[&HostTensor],
        weight_names: &[String],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.executable(entry, batch, seq)?;
        let t0 = std::time::Instant::now();

        let mut args: Vec<std::sync::Arc<xla::PjRtBuffer>> = Vec::new();
        for a in activations {
            args.push(std::sync::Arc::new(self.upload(a)?));
        }
        for name in weight_names {
            args.push(self.weight_buffer(name)?);
        }
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let result = exe.execute_b(&arg_refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let out = parts
            .iter()
            .map(literal_to_host)
            .collect::<Result<Vec<_>>>()?;

        let mut st = self.stats.lock().unwrap();
        st.executions += 1;
        st.exec_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Raw execute with host tensors only (tests / attention worker paths
    /// where caches are per-worker state, not weights).
    pub fn execute_raw(
        &self,
        entry: &str,
        batch: usize,
        seq: Option<usize>,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.execute(entry, batch, seq, inputs, &[])
    }

    pub fn snapshot_stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    /// Download helper exposed for integration tests.
    pub fn roundtrip(&self, t: &HostTensor) -> Result<HostTensor> {
        let buf = self.upload(t)?;
        Self::download(&buf)
    }
}

fn literal_to_host(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
        other => Err(anyhow!("unsupported artifact dtype {other:?}")),
    }
}
