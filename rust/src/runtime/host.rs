//! Host-side tensors: the plain-Rust representation of activations moving
//! through the serving pipeline (and over the simulated network).

/// Dense host tensor, f32 or i32 (the tiny model's artifact dtypes).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes (for network accounting).
    pub fn byte_size(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Pad the leading (batch) dimension up to `batch`, filling zeros.
    pub fn pad_batch(&self, batch: usize) -> HostTensor {
        let shape = self.shape();
        assert!(!shape.is_empty() && shape[0] <= batch);
        if shape[0] == batch {
            return self.clone();
        }
        let row: usize = shape[1..].iter().product::<usize>().max(1);
        let mut new_shape = shape.to_vec();
        new_shape[0] = batch;
        match self {
            HostTensor::F32 { data, .. } => {
                let mut d = data.clone();
                d.resize(batch * row, 0.0);
                HostTensor::F32 { shape: new_shape, data: d }
            }
            HostTensor::I32 { data, .. } => {
                let mut d = data.clone();
                d.resize(batch * row, 0);
                HostTensor::I32 { shape: new_shape, data: d }
            }
        }
    }

    /// Truncate the leading (batch) dimension down to `batch`.
    pub fn take_batch(&self, batch: usize) -> HostTensor {
        let shape = self.shape();
        assert!(!shape.is_empty() && shape[0] >= batch);
        if shape[0] == batch {
            return self.clone();
        }
        let row: usize = shape[1..].iter().product::<usize>().max(1);
        let mut new_shape = shape.to_vec();
        new_shape[0] = batch;
        match self {
            HostTensor::F32 { data, .. } => {
                HostTensor::F32 { shape: new_shape, data: data[..batch * row].to_vec() }
            }
            HostTensor::I32 { data, .. } => {
                HostTensor::I32 { shape: new_shape, data: data[..batch * row].to_vec() }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_size(), 24);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn pad_and_take_batch_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = t.pad_batch(4);
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(&p.as_f32()[6..], &[0.0; 6]);
        let back = p.take_batch(2);
        assert_eq!(back, t);
    }

    #[test]
    fn pad_i32_and_1d() {
        let t = HostTensor::i32(vec![3], vec![7, 8, 9]);
        let p = t.pad_batch(5);
        assert_eq!(p.as_i32(), &[7, 8, 9, 0, 0]);
        assert_eq!(p.take_batch(3).as_i32(), &[7, 8, 9]);
    }
}
