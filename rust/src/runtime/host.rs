//! Host-side tensors: the plain-Rust representation of activations moving
//! through the serving pipeline (and over the simulated network).
//!
//! Payloads are `Arc`-backed with an element offset, so a [`HostTensor`] is
//! a cheap *view*: `clone()`, [`HostTensor::take_batch`],
//! [`HostTensor::view_rows`] and [`HostTensor::reshape`] never touch the
//! data. This is what makes the leader↔worker wire path zero-copy on the
//! host side — a `WireMsg` send moves an `Arc`, not a buffer — while
//! `netsim::transport` keeps charging the *logical* `byte_size()` to the
//! modelled network. Operations that must materialise bytes (padding, head
//! slicing across shard boundaries, KV gathers) report what they moved
//! through [`copies`], so benches can prove the steady-state decode path
//! copies nothing.

use std::sync::Arc;

/// Process-wide accounting of host-side tensor bytes physically copied.
///
/// Incremented by every deep-copying tensor op (`pad_batch`'s copy path,
/// cross-shard head slicing, KV-cache gathers, attention-output assembly).
/// Zero-copy views add nothing. `cargo bench` resets/reads this around the
/// decode hot loop to report bytes-copied-per-step in `BENCH_decode.json`.
///
/// The storage is the obs registry counter `host.copied_bytes`
/// (`crate::obs::registry`), so the same number shows up in every registry
/// snapshot / Prometheus dump; this module keeps the historical `add` /
/// `total` / `reset` API over a cached handle (one relaxed `fetch_add`
/// per call — identical hot-path cost to the old private atomic).
pub mod copies {
    use crate::obs::{self, Counter};
    use std::sync::OnceLock;

    fn cell() -> &'static Counter {
        static C: OnceLock<Counter> = OnceLock::new();
        C.get_or_init(|| obs::registry().counter("host.copied_bytes"))
    }

    pub fn add(bytes: usize) {
        cell().add(bytes as u64);
    }

    pub fn total() -> u64 {
        cell().get()
    }

    pub fn reset() {
        cell().reset();
    }
}

/// Process-wide accounting of KV-arena **bytes read** by the native
/// attention kernels (the decode path's bandwidth term, distinct from
/// [`copies`] which counts bytes *moved*).
///
/// Charged per batch row per layer step with the row's unique working set
/// (`PagedKvArena::kv_read_bytes`): every visited block's K and V regions
/// across all shard heads, in the arena's *storage* dtype — so f16/int8
/// block storage shows up directly as a 2×/≈4× drop. `cargo bench`
/// resets/reads this around the decode hot loop to report
/// `kv_read_bytes_per_iter` in `BENCH_decode.json`, where the reduction is
/// machine-checked.
///
/// Like [`copies`], the storage is the obs registry counter
/// `kv.read_bytes`; the historical `add`/`total`/`reset` API is preserved
/// over a cached handle.
pub mod kv_reads {
    use crate::obs::{self, Counter};
    use std::sync::OnceLock;

    fn cell() -> &'static Counter {
        static C: OnceLock<Counter> = OnceLock::new();
        C.get_or_init(|| obs::registry().counter("kv.read_bytes"))
    }

    pub fn add(bytes: usize) {
        cell().add(bytes as u64);
    }

    pub fn total() -> u64 {
        cell().get()
    }

    pub fn reset() {
        cell().reset();
    }
}

/// Element type of a [`HostTensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Debug, Clone)]
enum Data {
    F32(Arc<[f32]>),
    I32(Arc<[i32]>),
}

/// Dense host tensor view, f32 or i32 (the tiny model's artifact dtypes).
/// Cloning shares the underlying buffer.
#[derive(Debug, Clone)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Data,
    /// Element offset of this view into the shared buffer.
    offset: usize,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::F32(data.into()), offset: 0 }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::I32(data.into()), offset: 0 }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    /// Wrap an already-shared buffer without copying. The backing allocation
    /// may be *larger* than the view (a reused scratch buffer, a codec read
    /// buffer); the view covers the first `shape.product()` elements.
    pub fn f32_arc(shape: Vec<usize>, data: Arc<[f32]>) -> Self {
        assert!(
            shape.iter().product::<usize>() <= data.len(),
            "arc buffer smaller than view"
        );
        HostTensor { shape, data: Data::F32(data), offset: 0 }
    }

    /// i32 variant of [`HostTensor::f32_arc`].
    pub fn i32_arc(shape: Vec<usize>, data: Arc<[i32]>) -> Self {
        assert!(
            shape.iter().product::<usize>() <= data.len(),
            "arc buffer smaller than view"
        );
        HostTensor { shape, data: Data::I32(data), offset: 0 }
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            Data::F32(_) => Dtype::F32,
            Data::I32(_) => Dtype::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes of *this view* (for network accounting).
    pub fn byte_size(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(d) => &d[self.offset..self.offset + self.len()],
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(d) => &d[self.offset..self.offset + self.len()],
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Do two tensors share the same underlying allocation?
    pub fn shares_buffer(&self, other: &HostTensor) -> bool {
        match (&self.data, &other.data) {
            (Data::F32(a), Data::F32(b)) => Arc::ptr_eq(a, b),
            (Data::I32(a), Data::I32(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Zero-copy view of rows `start..start + rows` of the leading dim.
    pub fn view_rows(&self, start: usize, rows: usize) -> HostTensor {
        let shape = self.shape();
        assert!(!shape.is_empty() && start + rows <= shape[0]);
        let row: usize = shape[1..].iter().product::<usize>().max(1);
        let mut new_shape = shape.to_vec();
        new_shape[0] = rows;
        HostTensor {
            shape: new_shape,
            data: self.data.clone(),
            offset: self.offset + start * row,
        }
    }

    /// Zero-copy reinterpretation under a new shape (same element count).
    pub fn reshape(&self, shape: Vec<usize>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), self.len(), "reshape element mismatch");
        HostTensor { shape, data: self.data.clone(), offset: self.offset }
    }

    /// Pad the leading (batch) dimension up to `batch`, filling zeros.
    /// The only staging op that must copy (it appends rows); charged to
    /// [`copies`].
    pub fn pad_batch(&self, batch: usize) -> HostTensor {
        let shape = self.shape();
        assert!(!shape.is_empty() && shape[0] <= batch);
        if shape[0] == batch {
            return self.clone();
        }
        let row: usize = shape[1..].iter().product::<usize>().max(1);
        let mut new_shape = shape.to_vec();
        new_shape[0] = batch;
        copies::add(self.byte_size());
        match &self.data {
            Data::F32(_) => {
                let mut d = Vec::with_capacity(batch * row);
                d.extend_from_slice(self.as_f32());
                d.resize(batch * row, 0.0);
                HostTensor::f32(new_shape, d)
            }
            Data::I32(_) => {
                let mut d = Vec::with_capacity(batch * row);
                d.extend_from_slice(self.as_i32());
                d.resize(batch * row, 0);
                HostTensor::i32(new_shape, d)
            }
        }
    }

    /// Truncate the leading (batch) dimension down to `batch` — a zero-copy
    /// view over the shared buffer.
    pub fn take_batch(&self, batch: usize) -> HostTensor {
        let shape = self.shape();
        assert!(!shape.is_empty() && shape[0] >= batch);
        self.view_rows(0, batch)
    }
}

/// Content equality (a view equals an owned tensor with the same elements).
impl PartialEq for HostTensor {
    fn eq(&self, other: &Self) -> bool {
        if self.shape != other.shape {
            return false;
        }
        match (&self.data, &other.data) {
            (Data::F32(_), Data::F32(_)) => self.as_f32() == other.as_f32(),
            (Data::I32(_), Data::I32(_)) => self.as_i32() == other.as_i32(),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_size(), 24);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn pad_and_take_batch_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = t.pad_batch(4);
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(&p.as_f32()[6..], &[0.0; 6]);
        let back = p.take_batch(2);
        assert_eq!(back, t);
    }

    #[test]
    fn pad_i32_and_1d() {
        let t = HostTensor::i32(vec![3], vec![7, 8, 9]);
        let p = t.pad_batch(5);
        assert_eq!(p.as_i32(), &[7, 8, 9, 0, 0]);
        assert_eq!(p.take_batch(3).as_i32(), &[7, 8, 9]);
    }

    #[test]
    fn take_batch_is_zero_copy_view() {
        let t = HostTensor::f32(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let v = t.take_batch(2);
        assert!(v.shares_buffer(&t), "take_batch must not copy");
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.byte_size(), 16); // view-sized, not buffer-sized
        assert_eq!(v.as_f32(), &[0., 1., 2., 3.]);
    }

    #[test]
    fn view_rows_offsets_into_buffer() {
        let t = HostTensor::i32(vec![4, 2], (0..8).collect());
        let v = t.view_rows(1, 2);
        assert!(v.shares_buffer(&t));
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.as_i32(), &[2, 3, 4, 5]);
        // view of a view composes offsets
        let vv = v.view_rows(1, 1);
        assert_eq!(vv.as_i32(), &[4, 5]);
        assert_eq!(vv.byte_size(), 8);
    }

    #[test]
    fn reshape_is_zero_copy() {
        let t = HostTensor::f32(vec![1, 2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.reshape(vec![2, 3]);
        assert!(r.shares_buffer(&t));
        assert_eq!(r.shape(), &[2, 3]);
        assert_eq!(r.as_f32(), t.as_f32());
    }

    #[test]
    fn clone_shares_buffer() {
        let t = HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let c = t.clone();
        assert!(c.shares_buffer(&t));
        assert_eq!(c, t);
    }

    #[test]
    fn pad_batch_on_view_materialises_view_contents() {
        let t = HostTensor::f32(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let v = t.view_rows(1, 2); // rows 1..3
        let p = v.pad_batch(3);
        assert!(!p.shares_buffer(&t)); // padding must copy
        assert_eq!(p.as_f32(), &[2., 3., 4., 5., 0., 0.]);
    }

    #[test]
    fn equality_across_view_and_owned() {
        let t = HostTensor::f32(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let v = t.view_rows(2, 2);
        let owned = HostTensor::f32(vec![2, 2], vec![4., 5., 6., 7.]);
        assert_eq!(v, owned);
        assert_ne!(v, t.view_rows(0, 2));
    }

    #[test]
    fn copies_counter_monotonic_on_pad() {
        let t = HostTensor::f32(vec![2, 3], vec![1.; 6]);
        let before = copies::total();
        let _p = t.pad_batch(8);
        // pad copies the source view's bytes (other tests may add more in
        // parallel, so assert monotonically-at-least).
        assert!(copies::total() >= before + 24);
    }
}
