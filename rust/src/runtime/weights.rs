//! Weight loading: `weights.bin` (little-endian f32, laid out per the
//! manifest tensor table) → named host tensors → per-entry-point argument
//! lists matching the AOT input signatures.

use std::collections::BTreeMap;

use super::host::HostTensor;
use super::manifest::{Manifest, ManifestError};

/// All model weights, keyed by manifest tensor name
/// (`embed`, `final_norm`, `lm_head`, `layer{i}.{name}`).
#[derive(Debug)]
pub struct Weights {
    tensors: BTreeMap<String, HostTensor>,
    pub layers: usize,
}

impl Weights {
    pub fn load(manifest: &Manifest) -> Result<Weights, ManifestError> {
        let path = manifest.weights_path();
        let blob = std::fs::read(&path)
            .map_err(|e| ManifestError(format!("read {}: {e}", path.display())))?;
        let mut tensors = BTreeMap::new();
        for t in &manifest.tensors {
            let end = t.offset + t.size;
            if end > blob.len() {
                return Err(ManifestError(format!(
                    "tensor {} [{}..{}] beyond weights.bin ({} bytes)",
                    t.name, t.offset, end, blob.len()
                )));
            }
            let data: Vec<f32> = blob[t.offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(t.name.clone(), HostTensor::f32(t.shape.clone(), data));
        }
        Ok(Weights { tensors, layers: manifest.config.layers })
    }

    pub fn get(&self, name: &str) -> &HostTensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight tensor '{name}'"))
    }

    pub fn layer(&self, layer: usize, name: &str) -> &HostTensor {
        self.get(&format!("layer{layer}.{name}"))
    }

    /// Weight arguments for `slice_first` (aot.py input order after the
    /// activations): embed, attn_norm₀, wq₀, wk₀, wv₀.
    pub fn slice_first_args(&self) -> Vec<&HostTensor> {
        vec![
            self.get("embed"),
            self.layer(0, "attn_norm"),
            self.layer(0, "wq"),
            self.layer(0, "wk"),
            self.layer(0, "wv"),
        ]
    }

    /// Weight arguments for `slice_mid` joining attention layer `i` to
    /// layer `i+1`: woᵢ, ffn_normᵢ, w_gateᵢ, w_upᵢ, w_downᵢ,
    /// attn_normᵢ₊₁, wqᵢ₊₁, wkᵢ₊₁, wvᵢ₊₁.
    pub fn slice_mid_args(&self, i: usize) -> Vec<&HostTensor> {
        assert!(i + 1 < self.layers, "slice_mid after last layer");
        vec![
            self.layer(i, "wo"),
            self.layer(i, "ffn_norm"),
            self.layer(i, "w_gate"),
            self.layer(i, "w_up"),
            self.layer(i, "w_down"),
            self.layer(i + 1, "attn_norm"),
            self.layer(i + 1, "wq"),
            self.layer(i + 1, "wk"),
            self.layer(i + 1, "wv"),
        ]
    }

    /// Weight arguments for `slice_last`: wo, ffn_norm, w_gate, w_up,
    /// w_down (of the last layer), final_norm, lm_head.
    pub fn slice_last_args(&self) -> Vec<&HostTensor> {
        let i = self.layers - 1;
        vec![
            self.layer(i, "wo"),
            self.layer(i, "ffn_norm"),
            self.layer(i, "w_gate"),
            self.layer(i, "w_up"),
            self.layer(i, "w_down"),
            self.get("final_norm"),
            self.get("lm_head"),
        ]
    }

    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn load_and_count() {
        let Some(m) = manifest() else { return };
        let w = Weights::load(&m).unwrap();
        assert_eq!(w.param_count(), m.config.param_count);
        assert_eq!(w.get("embed").shape(), &[m.config.vocab, m.config.d]);
    }

    #[test]
    fn arg_lists_shapes() {
        let Some(m) = manifest() else { return };
        let w = Weights::load(&m).unwrap();
        let c = &m.config;
        let first = w.slice_first_args();
        assert_eq!(first.len(), 5);
        assert_eq!(first[2].shape(), &[c.d, c.heads * c.head_dim]);
        let mid = w.slice_mid_args(0);
        assert_eq!(mid.len(), 9);
        assert_eq!(mid[0].shape(), &[c.heads * c.head_dim, c.d]);
        let last = w.slice_last_args();
        assert_eq!(last.len(), 7);
        assert_eq!(last[6].shape(), &[c.d, c.vocab]);
    }

    #[test]
    #[should_panic]
    fn mid_after_last_layer_panics() {
        let Some(m) = manifest() else { panic!("no artifacts — vacuous pass") };
        let w = Weights::load(&m).unwrap();
        let _ = w.slice_mid_args(m.config.layers - 1);
    }
}
