//! Runtime: PJRT-based execution of the AOT artifacts (`artifacts/*.hlo.txt`
//! + `weights.bin` + `manifest.json`).
//!
//! PJRT handles hold raw pointers (`!Send`), so each worker thread owns its
//! own [`Engine`] — which mirrors the paper's architecture: every device
//! (model worker, attention worker) is a separate executor; tensors cross
//! between them as plain host data over the (simulated) network.

pub mod engine;
pub mod host;
pub mod manifest;
pub mod weights;

pub use engine::{Engine, EngineStats};
pub use host::HostTensor;
pub use manifest::{Manifest, ModelCfg};
pub use weights::Weights;
