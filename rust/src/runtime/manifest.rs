//! Artifact manifest loader — the build-time contract with `python/compile/
//! aot.py` (`artifacts/manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Tiny-model architecture parameters, mirrored from python ModelConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub param_count: usize,
}

impl ModelCfg {
    pub fn gqa_group(&self) -> usize {
        self.heads / self.kv_heads
    }
}

/// One weight tensor's location in weights.bin.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One AOT-lowered HLO entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct EntryPoint {
    pub entry: String,
    pub batch: usize,
    /// Sequence bucket (None for slice entry points).
    pub seq: Option<usize>,
    pub file: String,
    pub input_names: Vec<String>,
    pub input_shapes: Vec<Vec<usize>>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelCfg,
    pub batch_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    pub weights_file: String,
    pub tensors: Vec<TensorMeta>,
    pub entrypoints: Vec<EntryPoint>,
    pub layer_weight_names: Vec<String>,
    pub global_weight_names: Vec<String>,
    by_key: BTreeMap<(String, usize, usize), usize>,
}

#[derive(Debug)]
pub struct ManifestError(pub String);

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

fn need_usize(j: &Json, key: &str) -> Result<usize, ManifestError> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| ManifestError(format!("missing/invalid field '{key}'")))
}

fn need_str(j: &Json, key: &str) -> Result<String, ManifestError> {
    j.get(key)
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| ManifestError(format!("missing/invalid field '{key}'")))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| ManifestError(format!("read {}: {e}", path.display())))?;
        let j = Json::parse(&text).map_err(|e| ManifestError(e.to_string()))?;

        let c = j.get("config");
        let config = ModelCfg {
            name: need_str(c, "name")?,
            vocab: need_usize(c, "vocab")?,
            d: need_usize(c, "d")?,
            layers: need_usize(c, "layers")?,
            heads: need_usize(c, "heads")?,
            kv_heads: need_usize(c, "kv_heads")?,
            ffn: need_usize(c, "ffn")?,
            max_seq: need_usize(c, "max_seq")?,
            head_dim: need_usize(c, "head_dim")?,
            param_count: need_usize(c, "param_count")?,
        };

        let batch_buckets = j
            .get("buckets")
            .get("batch")
            .usize_vec()
            .ok_or_else(|| ManifestError("bad buckets.batch".into()))?;
        let seq_buckets = j
            .get("buckets")
            .get("seq")
            .usize_vec()
            .ok_or_else(|| ManifestError("bad buckets.seq".into()))?;

        let tensors = j
            .get("weights")
            .get("tensors")
            .as_arr()
            .ok_or_else(|| ManifestError("bad weights.tensors".into()))?
            .iter()
            .map(|t| {
                Ok(TensorMeta {
                    name: need_str(t, "name")?,
                    shape: t
                        .get("shape")
                        .usize_vec()
                        .ok_or_else(|| ManifestError("bad tensor shape".into()))?,
                    offset: need_usize(t, "offset")?,
                    size: need_usize(t, "size")?,
                })
            })
            .collect::<Result<Vec<_>, ManifestError>>()?;

        let entrypoints = j
            .get("entrypoints")
            .as_arr()
            .ok_or_else(|| ManifestError("bad entrypoints".into()))?
            .iter()
            .map(|e| {
                let inputs = e
                    .get("inputs")
                    .as_arr()
                    .ok_or_else(|| ManifestError("bad inputs".into()))?;
                Ok(EntryPoint {
                    entry: need_str(e, "entry")?,
                    batch: need_usize(e, "batch")?,
                    seq: e.get("seq").as_usize(),
                    file: need_str(e, "file")?,
                    input_names: inputs
                        .iter()
                        .map(|i| need_str(i, "name"))
                        .collect::<Result<_, _>>()?,
                    input_shapes: inputs
                        .iter()
                        .map(|i| {
                            i.get("shape")
                                .usize_vec()
                                .ok_or_else(|| ManifestError("bad input shape".into()))
                        })
                        .collect::<Result<_, _>>()?,
                    outputs: e
                        .get("outputs")
                        .as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                        .unwrap_or_default(),
                })
            })
            .collect::<Result<Vec<_>, ManifestError>>()?;

        let names = |key: &str| -> Vec<String> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };

        let mut by_key = BTreeMap::new();
        for (i, e) in entrypoints.iter().enumerate() {
            by_key.insert((e.entry.clone(), e.batch, e.seq.unwrap_or(0)), i);
        }

        Ok(Manifest {
            dir,
            config,
            batch_buckets,
            seq_buckets,
            weights_file: need_str(j.get("weights"), "file")?,
            tensors,
            entrypoints,
            layer_weight_names: names("layer_weight_names"),
            global_weight_names: names("global_weight_names"),
            by_key,
        })
    }

    /// Look up an entry point by (name, batch bucket, seq bucket).
    pub fn entrypoint(&self, entry: &str, batch: usize, seq: Option<usize>) -> Option<&EntryPoint> {
        self.by_key
            .get(&(entry.to_string(), batch, seq.unwrap_or(0)))
            .map(|&i| &self.entrypoints[i])
    }

    /// Smallest batch bucket ≥ `batch`.
    pub fn batch_bucket(&self, batch: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().filter(|&b| b >= batch).min()
    }

    /// Smallest seq bucket ≥ `tokens`.
    pub fn seq_bucket(&self, tokens: usize) -> Option<usize> {
        self.seq_buckets.iter().copied().filter(|&s| s >= tokens).min()
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorMeta> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn hlo_path(&self, e: &EntryPoint) -> PathBuf {
        self.dir.join(&e.file)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.config.name, "tiny");
        assert_eq!(m.config.d, m.config.heads * m.config.head_dim);
        assert!(!m.entrypoints.is_empty());
        assert!(m.entrypoint("slice_mid", m.batch_buckets[0], None).is_some());
        assert!(m
            .entrypoint("attention", m.batch_buckets[0], Some(m.seq_buckets[0]))
            .is_some());
        // weight table covers all params
        let total: usize = m.tensors.iter().map(|t| t.size / 4).sum();
        assert_eq!(total, m.config.param_count);
    }

    #[test]
    fn bucket_selection() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert_eq!(m.batch_bucket(1), Some(1));
        assert_eq!(m.batch_bucket(3), Some(4));
        assert_eq!(m.batch_bucket(100_000), None);
        assert_eq!(m.seq_bucket(1), Some(m.seq_buckets[0]));
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
