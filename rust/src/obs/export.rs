//! Exporters: Chrome `trace_event` JSON, JSONL event stream, Prometheus
//! text — all built on `util::json` (no serde in the offline toolchain).
//!
//! * [`chrome_trace`] — the `{"traceEvents": [...]}` document Perfetto and
//!   `chrome://tracing` load: complete events (`ph:"X"`, `ts`/`dur` in
//!   microseconds), thread-scoped instants (`ph:"i"`, `"s":"t"`), and
//!   `thread_name` metadata naming track 0 `leader` and track *i*+1
//!   `attn-worker-i`. Everything is `pid` 1; `tid` is the obs track.
//! * [`jsonl`] — one compact JSON object per line per event, in capture
//!   order; the `--step-trace` output format, greppable and streamable.
//! * [`prometheus`] — `# TYPE`-annotated exposition text of a registry
//!   snapshot: counters, gauges, and histograms as cumulative `_bucket`
//!   series (only non-empty buckets are emitted; `le` is the bucket's
//!   upper bound, so quantile error stays within the histogram's 12.5%
//!   contract) plus `_sum`/`_count`. ROADMAP item 5's `/metrics` endpoint
//!   serves this string verbatim.
//!
//! File writers are atomic: content is assembled in memory, written to a
//! `.tmp` sibling, fsynced and renamed into place — a crash mid-export
//! leaves the previous file intact, never a torn one.

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

use super::registry::{bucket_bounds, RegistrySnapshot};
use super::trace::{ArgVal, TraceEvent};

fn args_json(args: &[(&'static str, ArgVal)]) -> Json {
    Json::Obj(
        args.iter()
            .map(|(k, v)| {
                (
                    k.to_string(),
                    match v {
                        ArgVal::I(i) => Json::num(*i as f64),
                        ArgVal::S(s) => Json::str(s.clone()),
                    },
                )
            })
            .collect(),
    )
}

fn event_json(e: &TraceEvent, chrome: bool) -> Json {
    let mut pairs = vec![
        ("name", Json::str(e.name.as_ref())),
        ("cat", Json::str(e.cat)),
        ("ph", Json::str(e.ph.to_string())),
        ("ts", Json::num(e.ts_us)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(e.track as f64)),
    ];
    if e.ph == 'X' {
        pairs.push(("dur", Json::num(e.dur_us)));
    }
    if chrome && e.ph == 'i' {
        pairs.push(("s", Json::str("t"))); // thread-scoped instant
    }
    if !e.args.is_empty() {
        pairs.push(("args", args_json(&e.args)));
    }
    Json::obj(pairs)
}

/// Human-readable name for an obs track (leader / attn-worker-N).
pub fn track_name(track: u64) -> String {
    if track == 0 {
        "leader".to_string()
    } else {
        format!("attn-worker-{}", track - 1)
    }
}

/// Render events as a Chrome `trace_event` JSON document.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let mut tracks: Vec<u64> = events.iter().map(|e| e.track).collect();
    tracks.sort_unstable();
    tracks.dedup();
    let mut evs: Vec<Json> = Vec::with_capacity(events.len() + tracks.len());
    for &t in &tracks {
        evs.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("ts", Json::num(0.0)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(t as f64)),
            ("args", Json::obj(vec![("name", Json::str(track_name(t)))])),
        ]));
    }
    for e in events {
        evs.push(event_json(e, true));
    }
    Json::obj(vec![("traceEvents", Json::Arr(evs))]).dump()
}

/// Render events as one compact JSON object per line.
pub fn jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&event_json(e, false).dump());
        out.push('\n');
    }
    out
}

/// Write `data` via tmp-file + rename so a crash never leaves a torn file.
fn write_atomic(path: &Path, data: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(data.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

/// Write a Perfetto-loadable trace file (atomically).
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    write_atomic(path, &chrome_trace(events))
}

/// Write a JSONL event stream (atomically).
pub fn write_jsonl(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    write_atomic(path, &jsonl(events))
}

/// `lamina_`-prefixed Prometheus metric name (non-alphanumerics → `_`).
fn sanitize(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 7);
    s.push_str("lamina_");
    for c in name.chars() {
        s.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    s
}

/// Render a registry snapshot in Prometheus exposition format.
pub fn prometheus(snap: &RegistrySnapshot) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n} counter");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, v) in &snap.gauges {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, h) in &snap.histograms {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n} histogram");
        let mut cum = 0u64;
        for (i, &c) in h.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            let (_, hi) = bucket_bounds(i);
            let _ = writeln!(out, "{n}_bucket{{le=\"{hi}\"}} {cum}");
        }
        let _ = writeln!(out, "{n}_bucket{{le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(out, "{n}_sum {}", h.sum);
        let _ = writeln!(out, "{n}_count {}", h.count);
    }
    out
}
