//! Unified observability: metrics registry, span tracing, exporters.
//!
//! The paper's whole argument is about *where time and bytes go* — attention
//! on memory-optimized devices, everything else on compute-optimized ones,
//! joined by a wire that must stay cheap. This module is how the repo proves
//! that claim on every run instead of arguing from end-of-run aggregates:
//!
//! * [`registry`] — a global, thread-safe table of named **counters**,
//!   **gauges** and log-bucketed **histograms**. Handles are `Arc`-backed
//!   atomics: callers resolve a name once (typically into a `OnceLock`) and
//!   the hot path is a single relaxed `fetch_add` — no locks, no formatting.
//!   The process-wide byte meters (`runtime::host::copies` / `kv_reads`) and
//!   the `ServeMetrics` per-session aggregates all publish here, making the
//!   registry the single source of truth a future `/metrics` endpoint
//!   (ROADMAP item 5) serves verbatim.
//! * [`trace`] — scoped-timer **span tracing** over the decode iteration:
//!   admit → prefill-chunk / decode dispatch → per-worker wire send/recv →
//!   kernel compute → combine → sample → retire, tagged with request id,
//!   slot, worker shard and layer. Disabled (the default) a span is one
//!   relaxed atomic load and an all-`None` struct — nothing allocates,
//!   nothing locks. Spans record themselves on `Drop`, so a panicking
//!   worker (the failover path) still closes its open spans during unwind
//!   and the event buffer stays well-formed; the buffer is bounded
//!   ([`trace::MAX_EVENTS`]) and *truncates* under pressure rather than
//!   growing without bound or corrupting output.
//! * [`export`] — renderers over the captured data, all on `util::json`
//!   (no serde in the offline toolchain): a Chrome `trace_event` JSON file
//!   (`--trace-out trace.json`, loadable in Perfetto / `chrome://tracing`;
//!   leader is tid 0, attention worker *i* is tid *i*+1), a line-per-event
//!   JSONL stream (the `--step-trace` surface), and a Prometheus-style text
//!   snapshot of the registry (`--metrics-dump`).
//!
//! # Naming conventions
//!
//! Metric names are dot-separated lowercase paths with a unit suffix:
//! `host.copied_bytes`, `kv.read_bytes`, `serve.tbt_ns`, `serve.tokens`,
//! `kv.blocks_in_use`. The Prometheus exporter prefixes `lamina_` and maps
//! every non-alphanumeric character to `_`. Span categories are one of
//! `leader`, `sched`, `wire`, `worker`, `kernel`, `failover`; span names
//! are the function-level phase (`decode-step`, `send_q`, `paged_attn`,
//! `recover`, …). Fault injection marks `wire`-category instants
//! (`fault_kill`, `fault_drop`); death detection and recovery mark the
//! `failover` category (`worker-dead` instants, `recover` spans), so a
//! faulted run's timeline shows the kill, the detection, and the replay
//! window in one view.
//!
//! # Overhead contract
//!
//! With tracing disabled, an instrumented call site costs one relaxed
//! atomic load (the `obs/span disabled` bench row pins it); the end-to-end
//! contract — instrumented-but-disabled decode step within 2% of the raw
//! kernel — is asserted inside `benches.rs` (`obs/decode-step` rows) and
//! regression-gated by `scripts/bench_guard.py`. Registry handles held in
//! `OnceLock` statics cost one relaxed `fetch_add` per update.

pub mod export;
pub mod registry;
pub mod trace;

pub use registry::{
    registry, Counter, Gauge, HistoSnapshot, Histogram, Registry, RegistrySnapshot,
};
pub use trace::{instant, set_thread_track, span, ArgVal, Span, TraceEvent};

/// Poison-immune mutex lock: observability must keep working (and never
/// double-panic) after a worker thread died mid-update, so every obs lock
/// goes through here instead of `.unwrap()`.
pub(crate) fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
