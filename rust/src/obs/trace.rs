//! Span tracing: scoped timers over the decode pipeline.
//!
//! A [`Span`] measures one phase of work on one thread ("track"): the
//! leader is track 0, attention worker *i* is track *i*+1 (workers call
//! [`set_thread_track`] on startup). Spans nest naturally — creation
//! order on a track is the nesting order, and the Chrome `trace_event`
//! renderers reconstruct the stack from `ts`/`dur`.
//!
//! # Cost model
//!
//! Tracing is **off** by default. A disabled [`span`] call is one relaxed
//! atomic load returning `Span(None)` — no clock read, no allocation, no
//! lock; `.arg(..)` on it is a no-op. Enabled spans read the monotonic
//! clock twice and push one event into a global bounded buffer under a
//! mutex at `Drop` time.
//!
//! # Panic/drop safety (the failover contract)
//!
//! Events are recorded in `Drop`, which runs during unwinding, so a worker
//! that dies mid-step closes its open spans before the thread dies; the
//! sink mutex is poison-immune (`obs::lock`), so one panicked writer never
//! wedges tracing for everyone else. The buffer is bounded at
//! [`MAX_EVENTS`]: under pressure new events are *dropped and counted*
//! ([`dropped`]), never partially written — exporters always see a
//! well-formed event list.

use std::borrow::Cow;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::lock;

/// Event-buffer capacity. ~40 events per decode step across 2 layers keeps
/// multi-thousand-step sessions inside the cap; longer sessions truncate
/// (see [`dropped`]) instead of growing without bound.
pub const MAX_EVENTS: usize = 1 << 18;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

thread_local! {
    static TRACK: Cell<u64> = Cell::new(0);
}

/// Monotonic epoch shared by every track (first use pins it).
fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

#[inline]
fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// A span/instant argument value.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgVal {
    I(i64),
    S(String),
}

/// One recorded event: a complete span (`ph == 'X'`, with duration) or an
/// instant marker (`ph == 'i'`). Field names mirror the Chrome
/// `trace_event` format the exporter writes.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: Cow<'static, str>,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: f64,
    pub dur_us: f64,
    pub track: u64,
    pub args: Vec<(&'static str, ArgVal)>,
}

/// Clear the buffer and enable collection.
pub fn start() {
    let _ = epoch();
    lock(&SINK).clear();
    DROPPED.store(0, Ordering::Relaxed);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disable collection and drain the captured events.
pub fn stop() -> Vec<TraceEvent> {
    ENABLED.store(false, Ordering::SeqCst);
    std::mem::take(&mut *lock(&SINK))
}

/// Is collection currently enabled? (One relaxed load — callers may guard
/// arg-building work behind this.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Events discarded since [`start`] because the buffer was full.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Set this thread's track id (leader = 0 is the default; attention worker
/// `shard` calls `set_thread_track(shard + 1)` at startup).
pub fn set_thread_track(track: u64) {
    TRACK.with(|t| t.set(track));
}

fn push(ev: TraceEvent) {
    // A span that outlives `stop()` (e.g. a worker draining during
    // shutdown) is silently discarded — the exported file is already cut.
    if !enabled() {
        return;
    }
    let mut sink = lock(&SINK);
    if sink.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    sink.push(ev);
}

/// A scoped timer; records a complete event on `Drop`. Disabled spans are
/// `None` inside and free to construct/drop.
#[must_use = "a span measures until it is dropped — bind it to a `_sp` local"]
pub struct Span(Option<SpanInner>);

struct SpanInner {
    name: Cow<'static, str>,
    cat: &'static str,
    start_us: f64,
    track: u64,
    args: Vec<(&'static str, ArgVal)>,
}

/// Open a span in category `cat`. The returned guard records on drop.
#[inline]
pub fn span(cat: &'static str, name: impl Into<Cow<'static, str>>) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanInner {
        name: name.into(),
        cat,
        start_us: now_us(),
        track: TRACK.with(|t| t.get()),
        args: Vec::new(),
    }))
}

impl Span {
    /// Attach an integer argument (builder-style; no-op when disabled).
    #[inline]
    pub fn arg(mut self, key: &'static str, v: i64) -> Span {
        if let Some(s) = self.0.as_mut() {
            s.args.push((key, ArgVal::I(v)));
        }
        self
    }

    /// Attach a string argument (only materialize the string when
    /// [`enabled`] — guard expensive formatting at the call site).
    #[inline]
    pub fn arg_str(mut self, key: &'static str, v: impl Into<String>) -> Span {
        if let Some(s) = self.0.as_mut() {
            s.args.push((key, ArgVal::S(v.into())));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            let end = now_us();
            push(TraceEvent {
                name: s.name,
                cat: s.cat,
                ph: 'X',
                ts_us: s.start_us,
                dur_us: (end - s.start_us).max(0.0),
                track: s.track,
                args: s.args,
            });
        }
    }
}

/// Record a point-in-time marker with arguments. Callers building
/// non-trivial `args` should guard on [`enabled`] first.
pub fn instant(
    cat: &'static str,
    name: impl Into<Cow<'static, str>>,
    args: Vec<(&'static str, ArgVal)>,
) {
    if !enabled() {
        return;
    }
    push(TraceEvent {
        name: name.into(),
        cat,
        ph: 'i',
        ts_us: now_us(),
        dur_us: 0.0,
        track: TRACK.with(|t| t.get()),
        args,
    });
}
