//! Global metrics registry: named counters, gauges and log-bucketed
//! histograms with atomic hot paths.
//!
//! A metric handle ([`Counter`], [`Gauge`], [`Histogram`]) is a clonable
//! `Arc` around atomics. [`Registry::counter`]/`gauge`/`histogram` resolve
//! a name to its handle under a short-lived mutex (get-or-create, names are
//! stable for the process lifetime); call sites cache the handle — usually
//! in a `OnceLock` static — so updates never touch the registry map again.
//!
//! Histograms are HDR-style base-2 log buckets with [`HIST_SUB_BITS`]
//! sub-bucket bits per octave: values 0..8 are exact, above that each
//! octave splits into 8 sub-buckets, bounding the relative quantile error
//! at 1/8 = 12.5%. 496 buckets cover the full `u64` range, so nanosecond
//! latencies and byte counts share one shape. Recording is three relaxed
//! `fetch_add`s; snapshots are read-only and mergeable across shards.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::lock;

/// Sub-bucket bits per octave (8 sub-buckets → ≤12.5% relative error).
pub const HIST_SUB_BITS: u32 = 3;
const SUB: u64 = 1 << HIST_SUB_BITS;
/// Total bucket count covering all of `u64` (62 octaves × 8 sub-buckets).
pub const HIST_BUCKETS: usize = (64 - HIST_SUB_BITS as usize + 1) * SUB as usize;

/// Bucket index for a recorded value.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= HIST_SUB_BITS
        let shift = msb - HIST_SUB_BITS;
        let octave = (msb - HIST_SUB_BITS + 1) as u64;
        (octave * SUB + ((v >> shift) - SUB)) as usize
    }
}

/// Half-open `[lo, hi)` value range of bucket `i`. The topmost bucket's
/// upper bound saturates at `u64::MAX` (it would otherwise be 2^64).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    let i = i as u64;
    if i < SUB {
        (i, i + 1)
    } else {
        let octave = i / SUB;
        let shift = (octave - 1) as u32;
        let lo = (SUB + i % SUB) << shift;
        (lo, lo.saturating_add(1u64 << shift))
    }
}

/// Monotone event counter. `Clone` shares the underlying cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-value gauge (signed: deltas may go negative).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.set(0);
    }
}

#[derive(Debug)]
struct HistoCell {
    buckets: Vec<AtomicU64>, // HIST_BUCKETS long
    count: AtomicU64,
    sum: AtomicU64,
}

/// Log-bucketed histogram. `Clone` shares the underlying cells.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistoCell>);

impl Histogram {
    fn new() -> Histogram {
        Histogram(Arc::new(HistoCell {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in seconds as integer nanoseconds (the unit every
    /// `*_ns` histogram uses).
    #[inline]
    pub fn record_secs(&self, s: f64) {
        self.record(if s <= 0.0 { 0 } else { (s * 1e9) as u64 });
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy. Concurrent recording keeps working; a snapshot
    /// taken mid-record may be ahead/behind by in-flight updates (the three
    /// per-record adds are individually atomic, not a transaction).
    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            counts: self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.0.count.load(Ordering::Relaxed),
            sum: self.0.sum.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        for b in &self.0.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.0.count.store(0, Ordering::Relaxed);
        self.0.sum.store(0, Ordering::Relaxed);
    }
}

/// Immutable histogram state: per-bucket counts + total count/sum.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistoSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistoSnapshot {
    pub fn empty() -> HistoSnapshot {
        HistoSnapshot { counts: vec![0; HIST_BUCKETS], count: 0, sum: 0 }
    }

    /// Bucket-wise sum (shard merge — the same operation `KvCacheStats`
    /// uses across workers).
    pub fn merge(&self, other: &HistoSnapshot) -> HistoSnapshot {
        HistoSnapshot {
            counts: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(a, b)| a + b)
                .collect(),
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate: midpoint of the bucket holding the rank-`q`
    /// sample (relative error ≤ 1/2^`HIST_SUB_BITS`). NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let (lo, hi) = bucket_bounds(i);
                return if hi == u64::MAX {
                    lo as f64
                } else {
                    (lo as f64 + hi as f64) / 2.0
                };
            }
        }
        f64::NAN // unreachable when counts/count agree
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Name → handle tables. One global instance lives behind [`registry`];
/// separate instances exist only in tests.
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }

    /// Get-or-create the counter `name`. Cache the returned handle; this
    /// call takes the registry lock.
    pub fn counter(&self, name: &str) -> Counter {
        lock(&self.counters)
            .entry(name.to_string())
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        lock(&self.gauges)
            .entry(name.to_string())
            .or_insert_with(|| Gauge(Arc::new(AtomicI64::new(0))))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        lock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(Histogram::new)
            .clone()
    }

    /// Point-in-time copy of every registered metric (deterministically
    /// ordered — the maps are `BTreeMap`s).
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: lock(&self.counters)
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: lock(&self.gauges)
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: lock(&self.histograms)
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }

    /// Zero every metric, keeping registrations (cached handles stay
    /// valid). Test/bench scaffolding — a serving process never resets.
    pub fn reset(&self) {
        for c in lock(&self.counters).values() {
            c.reset();
        }
        for g in lock(&self.gauges).values() {
            g.reset();
        }
        for h in lock(&self.histograms).values() {
            h.reset();
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// Deterministic value snapshot of a [`Registry`].
#[derive(Debug, Clone, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistoSnapshot>,
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these unit tests only use process-local `Registry::new()`
    // instances and the pure bucket math — never `registry()` —
    // so they cannot interfere with other lib tests running in parallel
    // (the shared-registry behavior is covered by `tests/obs.rs`, which
    // serializes itself).

    #[test]
    fn bucket_roundtrip_exhaustive_small() {
        for v in 0u64..4096 {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert!(lo <= v && v < hi, "v={v} not in [{lo},{hi})");
        }
    }

    #[test]
    fn bucket_roundtrip_powers_and_extremes() {
        for e in 3..64u32 {
            for d in [-1i64, 0, 1] {
                let v = (1u128 << e) as i128 + d as i128;
                if v < 0 || v > u64::MAX as i128 {
                    continue;
                }
                let v = v as u64;
                let (lo, hi) = bucket_bounds(bucket_index(v));
                assert!(lo <= v, "v={v} lo={lo}");
                assert!(v < hi || hi == u64::MAX, "v={v} hi={hi}");
            }
        }
        let (lo, hi) = bucket_bounds(bucket_index(u64::MAX));
        assert_eq!(hi, u64::MAX, "top bucket saturates");
        assert!(lo <= u64::MAX);
    }

    #[test]
    fn bucket_relative_error_bound() {
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            if hi == u64::MAX {
                continue; // saturated top bucket
            }
            let width = hi - lo;
            assert!(
                width <= (lo / SUB).max(1),
                "bucket {i} [{lo},{hi}) wider than {}% of lo",
                100 / SUB
            );
        }
    }

    #[test]
    fn bucket_bounds_are_contiguous() {
        let mut expect = 0u64;
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expect, "bucket {i} not contiguous");
            assert!(hi > lo);
            if hi == u64::MAX {
                assert_eq!(i, HIST_BUCKETS - 1);
                break;
            }
            expect = hi;
        }
    }

    #[test]
    fn local_registry_counter_gauge_histogram() {
        let r = Registry::new();
        let c = r.counter("c");
        c.add(3);
        c.inc();
        assert_eq!(r.counter("c").get(), 4, "same name, same cell");
        let g = r.gauge("g");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
        let h = r.histogram("h");
        h.record(5);
        h.record(5);
        h.record(100);
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 110);
        assert_eq!(s.counts[bucket_index(5)], 2);
        let snap = r.snapshot();
        assert_eq!(snap.counters["c"], 4);
        assert_eq!(snap.gauges["g"], 5);
        r.reset();
        assert_eq!(c.get(), 0, "cached handle sees the reset");
        assert_eq!(h.snapshot().count, 0);
    }

    #[test]
    fn quantiles_small_values_exact_bucket() {
        let r = Registry::new();
        let h = r.histogram("q");
        for v in [1u64, 2, 3, 4, 5, 6, 7] {
            h.record(v);
        }
        let s = h.snapshot();
        // values < 8 land in exact unit buckets: p50 of 1..=7 is bucket 4,
        // whose midpoint is 4.5
        assert!((s.p50() - 4.5).abs() < 1e-9, "p50={}", s.p50());
        assert!((s.quantile(1.0) - 7.5).abs() < 1e-9);
        assert!((s.mean() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let s = HistoSnapshot::empty();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }
}
