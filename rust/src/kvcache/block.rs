//! Paged KV-cache block allocator (PagedAttention-style, paper §8 notes
//! vLLM's fine-grained KV management as a composable optimisation).
//!
//! KV memory on each attention worker is divided into fixed-size blocks of
//! `block_size` token slots; requests own chains of blocks via
//! [`super::table::BlockTable`]. The allocator is a free-list with O(1)
//! alloc/free, exact accounting, and a **per-block reference count**:
//! several block tables may map the same physical block read-only (prefix
//! sharing), [`BlockAllocator::retain`] adds a reference, and
//! [`BlockAllocator::release`] decrements — a block returns to the free
//! list only when its last reference drops. Writers must check
//! [`BlockAllocator::ref_count`] first and copy-on-write shared blocks
//! (see `super::arena`). Fragmentation can only be *internal* (tail of the
//! last block), which `internal_waste` reports.

/// Identifier of a physical KV block on one worker.
pub type BlockId = u32;

#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    free: Vec<BlockId>,
    /// Reference count per block id; 0 = on the free list.
    refs: Vec<u32>,
    total: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocError {
    pub requested: usize,
    pub available: usize,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV alloc of {} blocks failed ({} free)", self.requested, self.available)
    }
}

impl std::error::Error for AllocError {}

impl BlockAllocator {
    /// `total_blocks` physical blocks of `block_size` token slots each.
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        BlockAllocator {
            block_size,
            // LIFO free list: hot blocks are reused first (cache-friendly)
            free: (0..total_blocks as BlockId).rev().collect(),
            refs: vec![0; total_blocks],
            total: total_blocks,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total - self.free.len()
    }

    /// Blocks needed to hold `tokens` token slots.
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can `n` more blocks be allocated?
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Add `extra` fresh physical blocks to the pool (arena growth). New
    /// block ids continue from the previous total, so existing id→offset
    /// mappings stay valid; callers must extend their backing buffers to
    /// `total_blocks()` before handing the new ids out.
    pub fn grow(&mut self, extra: usize) {
        let start = self.total as BlockId;
        self.free.extend((start..start + extra as BlockId).rev());
        self.refs.resize(self.total + extra, 0);
        self.total += extra;
    }

    pub fn alloc(&mut self) -> Result<BlockId, AllocError> {
        let b = self
            .free
            .pop()
            .ok_or(AllocError { requested: 1, available: 0 })?;
        self.refs[b as usize] = 1;
        Ok(b)
    }

    pub fn alloc_n(&mut self, n: usize) -> Result<Vec<BlockId>, AllocError> {
        if self.free.len() < n {
            return Err(AllocError { requested: n, available: self.free.len() });
        }
        Ok((0..n)
            .map(|_| {
                let b = self.free.pop().unwrap();
                self.refs[b as usize] = 1;
                b
            })
            .collect())
    }

    /// Add one reference to a live block (prefix sharing: another table now
    /// maps it read-only).
    pub fn retain(&mut self, block: BlockId) {
        debug_assert!(self.refs[block as usize] > 0, "retain of free block {block}");
        self.refs[block as usize] += 1;
    }

    /// References currently held on `block` (0 = free).
    pub fn ref_count(&self, block: BlockId) -> u32 {
        self.refs[block as usize]
    }

    /// Does more than one table map `block`? (Writers must copy-on-write.)
    pub fn is_shared(&self, block: BlockId) -> bool {
        self.refs[block as usize] > 1
    }

    /// Drop one reference; the block returns to the free list when the last
    /// reference goes away.
    pub fn release(&mut self, block: BlockId) {
        debug_assert!((block as usize) < self.total);
        debug_assert!(self.refs[block as usize] > 0, "double free of block {block}");
        self.refs[block as usize] -= 1;
        if self.refs[block as usize] == 0 {
            self.free.push(block);
        }
    }

    pub fn release_all(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            self.release(b);
        }
    }

    /// Token slots wasted in the tails of partially-filled last blocks,
    /// given the live sequence lengths.
    pub fn internal_waste(&self, seq_lens: &[usize]) -> usize {
        seq_lens
            .iter()
            .map(|&l| self.blocks_for_tokens(l) * self.block_size - l)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = BlockAllocator::new(10, 16);
        let blocks = a.alloc_n(10).unwrap();
        assert_eq!(a.free_blocks(), 0);
        assert!(a.alloc().is_err());
        a.release_all(&blocks);
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn alloc_n_all_distinct() {
        let mut a = BlockAllocator::new(100, 8);
        let blocks = a.alloc_n(100).unwrap();
        let mut sorted = blocks.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
    }

    #[test]
    fn failed_alloc_keeps_state() {
        let mut a = BlockAllocator::new(4, 8);
        let _held = a.alloc_n(3).unwrap();
        let err = a.alloc_n(2).unwrap_err();
        assert_eq!(err.available, 1);
        assert_eq!(a.free_blocks(), 1); // nothing leaked
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for_tokens(0), 0);
        assert_eq!(a.blocks_for_tokens(1), 1);
        assert_eq!(a.blocks_for_tokens(16), 1);
        assert_eq!(a.blocks_for_tokens(17), 2);
    }

    #[test]
    fn lifo_reuse() {
        let mut a = BlockAllocator::new(5, 4);
        let b1 = a.alloc().unwrap();
        a.release(b1);
        let b2 = a.alloc().unwrap();
        assert_eq!(b1, b2);
    }

    #[test]
    fn internal_waste() {
        let a = BlockAllocator::new(10, 16);
        // 17 tokens → 2 blocks → 15 wasted; 32 tokens → 0 wasted
        assert_eq!(a.internal_waste(&[17, 32]), 15);
    }

    #[test]
    fn grow_extends_pool_with_fresh_ids() {
        let mut a = BlockAllocator::new(2, 8);
        let held = a.alloc_n(2).unwrap();
        assert!(a.alloc().is_err());
        a.grow(3);
        assert_eq!(a.total_blocks(), 5);
        assert_eq!(a.free_blocks(), 3);
        let more = a.alloc_n(3).unwrap();
        let mut all: Vec<_> = held.iter().chain(more.iter()).copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 5, "grown ids must not collide");
        assert!(more.iter().all(|&b| (b as usize) < 5));
    }

    #[test]
    #[should_panic]
    fn double_free_debug_panics() {
        let mut a = BlockAllocator::new(2, 4);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn retain_defers_free_until_last_release() {
        let mut a = BlockAllocator::new(2, 4);
        let b = a.alloc().unwrap();
        assert_eq!(a.ref_count(b), 1);
        assert!(!a.is_shared(b));
        a.retain(b);
        a.retain(b);
        assert_eq!(a.ref_count(b), 3);
        assert!(a.is_shared(b));
        a.release(b);
        a.release(b);
        assert_eq!(a.free_blocks(), 1, "still one reference held");
        assert_eq!(a.used_blocks(), 1);
        a.release(b);
        assert_eq!(a.ref_count(b), 0);
        assert_eq!(a.free_blocks(), 2, "last release frees the block");
    }

    #[test]
    fn grown_blocks_carry_refcounts() {
        let mut a = BlockAllocator::new(1, 4);
        let _b0 = a.alloc().unwrap();
        a.grow(2);
        let b = a.alloc().unwrap();
        assert_eq!(a.ref_count(b), 1);
        a.retain(b);
        a.release(b);
        a.release(b);
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    #[should_panic]
    fn retain_of_free_block_debug_panics() {
        let mut a = BlockAllocator::new(2, 4);
        let b = a.alloc().unwrap();
        a.release(b);
        a.retain(b);
    }
}
