//! Attention-work partitioning across memory devices (paper §5, Fig. 9).
//!
//! Two strategies:
//! * **head-level** — each worker owns `KH / W` KV heads of *every* request:
//!   perfectly balanced (each worker reads the same bytes), but requires the
//!   worker count to divide the head count. Lamina's choice.
//! * **request-level** — each worker owns entire requests: flexible, but
//!   imbalanced when sequence lengths differ.
//!
//! `imbalance` quantifies the trade-off the paper argues qualitatively.

/// Assignment of work shards to workers.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// worker → load (bytes of KV it must read per iteration)
    pub load: Vec<f64>,
    /// shard → worker (shard = head for head-level, request for req-level)
    pub assignment: Vec<usize>,
}

impl Partition {
    /// max/mean load ratio − 1: 0 = perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let max = self.load.iter().cloned().fold(0.0, f64::max);
        let mean = self.load.iter().sum::<f64>() / self.load.len() as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean - 1.0
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionError(pub String);

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for PartitionError {}

/// Head-level partitioning: KV heads dealt round-robin to workers. Every
/// worker touches every request, so per-worker load is
/// `heads_owned · Σ seq_len` — balanced iff workers divide heads.
pub fn head_level(
    kv_heads: usize,
    workers: usize,
    seq_lens: &[usize],
    bytes_per_head_token: f64,
) -> Result<Partition, PartitionError> {
    if workers == 0 || kv_heads == 0 {
        return Err(PartitionError("need ≥1 worker and ≥1 head".into()));
    }
    if kv_heads % workers != 0 {
        return Err(PartitionError(format!(
            "head-level partitioning needs workers ({workers}) to divide kv heads ({kv_heads})"
        )));
    }
    let total_tokens: usize = seq_lens.iter().sum();
    let mut load = vec![0.0; workers];
    let assignment: Vec<usize> = (0..kv_heads).map(|h| h % workers).collect();
    for (h, &w) in assignment.iter().enumerate() {
        let _ = h;
        load[w] += total_tokens as f64 * bytes_per_head_token;
    }
    Ok(Partition { load, assignment })
}

/// One worker's contiguous KV-head range under elastic membership.
///
/// Unlike [`head_level`]'s round-robin deal (which requires the worker
/// count to divide the head count), a [`ShardRange`] plan splits the heads
/// into contiguous runs whose sizes differ by at most one — any worker
/// count `1..=kv_heads` is valid, which is what lets the pool degrade to
/// W−1 survivors or adopt a W+1-th member mid-session. The leader slices
/// q/k/v by these ranges and interleaves attention outputs back at each
/// range's query offset; the per-head online-softmax math is shard-width
/// independent, so any plan over the same heads is bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    /// First KV head of the range.
    pub start: usize,
    /// KV heads in the range (≥ 1).
    pub count: usize,
}

impl ShardRange {
    /// The matching query-head range under GQA: query heads follow their
    /// KV group, so the range scales by `group` = `heads / kv_heads`.
    pub fn q_range(&self, group: usize) -> ShardRange {
        ShardRange { start: self.start * group, count: self.count * group }
    }
}

/// Contiguous largest-remainder split of `kv_heads` across `workers`:
/// the first `kv_heads % workers` workers get one extra head. Total always
/// covers every head exactly once; sizes differ by ≤ 1.
pub fn head_ranges(kv_heads: usize, workers: usize) -> Result<Vec<ShardRange>, PartitionError> {
    if workers == 0 || kv_heads == 0 {
        return Err(PartitionError("need ≥1 worker and ≥1 head".into()));
    }
    if workers > kv_heads {
        return Err(PartitionError(format!(
            "cannot split {kv_heads} kv heads across {workers} workers (each needs ≥1)"
        )));
    }
    let base = kv_heads / workers;
    let extra = kv_heads % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let count = base + usize::from(w < extra);
        out.push(ShardRange { start, count });
        start += count;
    }
    Ok(out)
}

/// KV blocks a paged arena needs to hold `seq_lens` at `block_size` tokens
/// per block. Under head-level sharding every worker caches a head shard of
/// *every* request, so the block count is worker-invariant (only the bytes
/// per block shrink with the shard width) — useful for sizing
/// `ArenaCfg::initial_blocks` and admission headroom.
pub fn kv_blocks_needed(seq_lens: &[usize], block_size: usize) -> usize {
    assert!(block_size > 0);
    seq_lens.iter().map(|&l| l.div_ceil(block_size)).sum()
}

/// The same requirement in **bytes**: blocks × the arena's per-block byte
/// size (`PagedKvArena::block_bytes × layers` for a full worker footprint).
/// With quantized block storage (`--kv-dtype f16|int8`) the byte size of a
/// block shrinks 2×/≈4×, so a fixed byte budget admits proportionally more
/// context. This is the unit the scheduler's byte-denominated `--kv-budget`
/// reserves in (`scheduler::KvBudget::Bytes`; the per-worker per-block
/// byte size comes from the pool's `KvStats` snapshot) — blocks remain
/// available as the legacy `--kv-budget-blocks` spelling.
pub fn kv_bytes_needed(seq_lens: &[usize], block_size: usize, bytes_per_block: usize) -> usize {
    kv_blocks_needed(seq_lens, block_size) * bytes_per_block
}

/// Request-level partitioning: requests greedily assigned (longest-first) to
/// the least-loaded worker — the strongest reasonable baseline; still
/// imbalanced for skewed length distributions.
pub fn request_level(
    workers: usize,
    seq_lens: &[usize],
    bytes_per_req_token: f64,
) -> Result<Partition, PartitionError> {
    if workers == 0 {
        return Err(PartitionError("need ≥1 worker".into()));
    }
    let mut idx: Vec<usize> = (0..seq_lens.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(seq_lens[i]));
    let mut load = vec![0.0; workers];
    let mut assignment = vec![0usize; seq_lens.len()];
    for &i in &idx {
        let w = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assignment[i] = w;
        load[w] += seq_lens[i] as f64 * bytes_per_req_token;
    }
    Ok(Partition { load, assignment })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_level_perfectly_balanced() {
        let p = head_level(8, 4, &[100, 5000, 32, 9], 64.0).unwrap();
        assert!(p.imbalance() < 1e-12);
        assert_eq!(p.assignment.len(), 8);
    }

    #[test]
    fn head_level_requires_divisibility() {
        assert!(head_level(8, 3, &[10], 1.0).is_err());
        assert!(head_level(8, 8, &[10], 1.0).is_ok());
        assert!(head_level(8, 16, &[10], 1.0).is_err());
    }

    #[test]
    fn request_level_balanced_when_uniform() {
        let p = request_level(4, &[100; 16], 1.0).unwrap();
        assert!(p.imbalance() < 1e-12);
    }

    #[test]
    fn request_level_imbalanced_when_skewed() {
        // One giant request dominates a worker — the paper's Fig. 9 point.
        let lens = [32_000, 100, 100, 100, 100, 100, 100, 100];
        let p = request_level(4, &lens, 1.0).unwrap();
        assert!(p.imbalance() > 1.0, "imbalance={}", p.imbalance());
        let h = head_level(8, 4, &lens, 1.0).unwrap();
        assert!(h.imbalance() < 1e-12);
    }

    #[test]
    fn request_level_greedy_beats_naive_roundrobin() {
        let lens = [1000, 900, 800, 10, 10, 10];
        let greedy = request_level(2, &lens, 1.0).unwrap();
        // naive round-robin: (1000+800+10)=1810 vs (900+10+10)=920
        let naive_imb: f64 = 1810.0 / 1365.0 - 1.0;
        assert!(greedy.imbalance() < naive_imb);
    }

    #[test]
    fn loads_conserve_total() {
        let lens = [100, 200, 300];
        let p = request_level(2, &lens, 2.0).unwrap();
        let total: f64 = p.load.iter().sum();
        assert!((total - 1200.0).abs() < 1e-9);
    }

    #[test]
    fn kv_blocks_needed_rounds_per_request() {
        assert_eq!(kv_blocks_needed(&[], 16), 0);
        assert_eq!(kv_blocks_needed(&[1, 16, 17], 16), 4);
        // per-request rounding: 2×(15 tokens) needs 2 blocks, not ceil(30/16)
        assert_eq!(kv_blocks_needed(&[15, 15], 16), 2);
    }

    #[test]
    fn kv_bytes_follow_blocks() {
        // same block count, byte need scales with the storage dtype's
        // per-block size (f32 4096 B vs int8 ~1028+scale per region etc.)
        assert_eq!(kv_bytes_needed(&[1, 16, 17], 16, 4096), 4 * 4096);
        assert_eq!(kv_bytes_needed(&[1, 16, 17], 16, 1056), 4 * 1056);
        assert_eq!(kv_bytes_needed(&[], 16, 4096), 0);
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(head_level(8, 0, &[1], 1.0).is_err());
        assert!(request_level(0, &[1], 1.0).is_err());
        assert!(head_ranges(8, 0).is_err());
        assert!(head_ranges(0, 2).is_err());
    }

    #[test]
    fn head_ranges_cover_exactly_once_any_width() {
        for kv_heads in 1..=16usize {
            for workers in 1..=kv_heads {
                let plan = head_ranges(kv_heads, workers).unwrap();
                assert_eq!(plan.len(), workers);
                let mut next = 0;
                for r in &plan {
                    assert_eq!(r.start, next, "contiguous");
                    assert!(r.count >= 1);
                    next += r.count;
                }
                assert_eq!(next, kv_heads, "covers every head");
                let (min, max) = plan
                    .iter()
                    .fold((usize::MAX, 0), |(lo, hi), r| (lo.min(r.count), hi.max(r.count)));
                assert!(max - min <= 1, "sizes differ by ≤ 1");
            }
        }
    }

    #[test]
    fn head_ranges_nonuniform_split() {
        // 4 kv heads over 3 workers: 2,1,1 — the chaos degrade geometry
        let plan = head_ranges(4, 3).unwrap();
        assert_eq!(
            plan,
            vec![
                ShardRange { start: 0, count: 2 },
                ShardRange { start: 2, count: 1 },
                ShardRange { start: 3, count: 1 },
            ]
        );
        // more workers than heads is a typed error, not a zero-head shard
        assert!(head_ranges(4, 5).is_err());
    }

    #[test]
    fn q_range_scales_by_gqa_group() {
        let r = ShardRange { start: 2, count: 1 };
        assert_eq!(r.q_range(2), ShardRange { start: 4, count: 2 });
        // MHA (group 1) leaves the range unchanged
        assert_eq!(r.q_range(1), r);
    }
}
