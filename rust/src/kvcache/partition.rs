//! Attention-work partitioning across memory devices (paper §5, Fig. 9).
//!
//! Two strategies:
//! * **head-level** — each worker owns `KH / W` KV heads of *every* request:
//!   perfectly balanced (each worker reads the same bytes), but requires the
//!   worker count to divide the head count. Lamina's choice.
//! * **request-level** — each worker owns entire requests: flexible, but
//!   imbalanced when sequence lengths differ.
//!
//! `imbalance` quantifies the trade-off the paper argues qualitatively.

/// Assignment of work shards to workers.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// worker → load (bytes of KV it must read per iteration)
    pub load: Vec<f64>,
    /// shard → worker (shard = head for head-level, request for req-level)
    pub assignment: Vec<usize>,
}

impl Partition {
    /// max/mean load ratio − 1: 0 = perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let max = self.load.iter().cloned().fold(0.0, f64::max);
        let mean = self.load.iter().sum::<f64>() / self.load.len() as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean - 1.0
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionError(pub String);

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for PartitionError {}

/// Head-level partitioning: KV heads dealt round-robin to workers. Every
/// worker touches every request, so per-worker load is
/// `heads_owned · Σ seq_len` — balanced iff workers divide heads.
pub fn head_level(
    kv_heads: usize,
    workers: usize,
    seq_lens: &[usize],
    bytes_per_head_token: f64,
) -> Result<Partition, PartitionError> {
    if workers == 0 || kv_heads == 0 {
        return Err(PartitionError("need ≥1 worker and ≥1 head".into()));
    }
    if kv_heads % workers != 0 {
        return Err(PartitionError(format!(
            "head-level partitioning needs workers ({workers}) to divide kv heads ({kv_heads})"
        )));
    }
    let total_tokens: usize = seq_lens.iter().sum();
    let mut load = vec![0.0; workers];
    let assignment: Vec<usize> = (0..kv_heads).map(|h| h % workers).collect();
    for (h, &w) in assignment.iter().enumerate() {
        let _ = h;
        load[w] += total_tokens as f64 * bytes_per_head_token;
    }
    Ok(Partition { load, assignment })
}

/// KV blocks a paged arena needs to hold `seq_lens` at `block_size` tokens
/// per block. Under head-level sharding every worker caches a head shard of
/// *every* request, so the block count is worker-invariant (only the bytes
/// per block shrink with the shard width) — useful for sizing
/// `ArenaCfg::initial_blocks` and admission headroom.
pub fn kv_blocks_needed(seq_lens: &[usize], block_size: usize) -> usize {
    assert!(block_size > 0);
    seq_lens.iter().map(|&l| l.div_ceil(block_size)).sum()
}

/// The same requirement in **bytes**: blocks × the arena's per-block byte
/// size (`PagedKvArena::block_bytes × layers` for a full worker footprint).
/// With quantized block storage (`--kv-dtype f16|int8`) the byte size of a
/// block shrinks 2×/≈4×, so a fixed byte budget admits proportionally more
/// context. This is the unit the scheduler's byte-denominated `--kv-budget`
/// reserves in (`scheduler::KvBudget::Bytes`; the per-worker per-block
/// byte size comes from the pool's `KvStats` snapshot) — blocks remain
/// available as the legacy `--kv-budget-blocks` spelling.
pub fn kv_bytes_needed(seq_lens: &[usize], block_size: usize, bytes_per_block: usize) -> usize {
    kv_blocks_needed(seq_lens, block_size) * bytes_per_block
}

/// Request-level partitioning: requests greedily assigned (longest-first) to
/// the least-loaded worker — the strongest reasonable baseline; still
/// imbalanced for skewed length distributions.
pub fn request_level(
    workers: usize,
    seq_lens: &[usize],
    bytes_per_req_token: f64,
) -> Result<Partition, PartitionError> {
    if workers == 0 {
        return Err(PartitionError("need ≥1 worker".into()));
    }
    let mut idx: Vec<usize> = (0..seq_lens.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(seq_lens[i]));
    let mut load = vec![0.0; workers];
    let mut assignment = vec![0usize; seq_lens.len()];
    for &i in &idx {
        let w = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assignment[i] = w;
        load[w] += seq_lens[i] as f64 * bytes_per_req_token;
    }
    Ok(Partition { load, assignment })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_level_perfectly_balanced() {
        let p = head_level(8, 4, &[100, 5000, 32, 9], 64.0).unwrap();
        assert!(p.imbalance() < 1e-12);
        assert_eq!(p.assignment.len(), 8);
    }

    #[test]
    fn head_level_requires_divisibility() {
        assert!(head_level(8, 3, &[10], 1.0).is_err());
        assert!(head_level(8, 8, &[10], 1.0).is_ok());
        assert!(head_level(8, 16, &[10], 1.0).is_err());
    }

    #[test]
    fn request_level_balanced_when_uniform() {
        let p = request_level(4, &[100; 16], 1.0).unwrap();
        assert!(p.imbalance() < 1e-12);
    }

    #[test]
    fn request_level_imbalanced_when_skewed() {
        // One giant request dominates a worker — the paper's Fig. 9 point.
        let lens = [32_000, 100, 100, 100, 100, 100, 100, 100];
        let p = request_level(4, &lens, 1.0).unwrap();
        assert!(p.imbalance() > 1.0, "imbalance={}", p.imbalance());
        let h = head_level(8, 4, &lens, 1.0).unwrap();
        assert!(h.imbalance() < 1e-12);
    }

    #[test]
    fn request_level_greedy_beats_naive_roundrobin() {
        let lens = [1000, 900, 800, 10, 10, 10];
        let greedy = request_level(2, &lens, 1.0).unwrap();
        // naive round-robin: (1000+800+10)=1810 vs (900+10+10)=920
        let naive_imb: f64 = 1810.0 / 1365.0 - 1.0;
        assert!(greedy.imbalance() < naive_imb);
    }

    #[test]
    fn loads_conserve_total() {
        let lens = [100, 200, 300];
        let p = request_level(2, &lens, 2.0).unwrap();
        let total: f64 = p.load.iter().sum();
        assert!((total - 1200.0).abs() < 1e-9);
    }

    #[test]
    fn kv_blocks_needed_rounds_per_request() {
        assert_eq!(kv_blocks_needed(&[], 16), 0);
        assert_eq!(kv_blocks_needed(&[1, 16, 17], 16), 4);
        // per-request rounding: 2×(15 tokens) needs 2 blocks, not ceil(30/16)
        assert_eq!(kv_blocks_needed(&[15, 15], 16), 2);
    }

    #[test]
    fn kv_bytes_follow_blocks() {
        // same block count, byte need scales with the storage dtype's
        // per-block size (f32 4096 B vs int8 ~1028+scale per region etc.)
        assert_eq!(kv_bytes_needed(&[1, 16, 17], 16, 4096), 4 * 4096);
        assert_eq!(kv_bytes_needed(&[1, 16, 17], 16, 1056), 4 * 1056);
        assert_eq!(kv_bytes_needed(&[], 16, 4096), 0);
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(head_level(8, 0, &[1], 1.0).is_err());
        assert!(request_level(0, &[1], 1.0).is_err());
    }
}
