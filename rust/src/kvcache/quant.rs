//! KV block quantization: the storage dtypes a [`super::PagedKvArena`] can
//! keep its block buffers in, plus the software f32↔f16 and f32↔int8
//! conversions (no external half-float crate in the offline toolchain).
//!
//! The paper's decode attention is memory-bandwidth-bound, so the bytes the
//! kernel reads per step — and the KV a fixed arena budget can hold — are
//! the two remaining levers after the copy elimination of PRs 1–3. Storing
//! blocks compactly attacks both at once:
//!
//! * **f16** — IEEE 754 binary16 kept as bit-cast `u16` lanes. Lossy once
//!   on append (round-to-nearest-even, ≤ 2⁻¹¹ relative error for values in
//!   the f16 normal range), exact to widen back. Halves block bytes.
//! * **int8** — symmetric linear quantization with **one f32 scale per
//!   (block, head)** K region and V region, maintained at append time: the
//!   scale is `maxabs / 127`, and when a later token in the same block
//!   raises the running max, the region's existing codes are requantized
//!   in place. Each requantization re-rounds earlier codes, adding up to
//!   `s_new/2` of error, so the worst-case per-element error is
//!   **block_size-dependent**: one initial rounding plus at most
//!   `block_size − 1` raises, each ≤ `maxabs_final/254`, i.e.
//!   `≤ (block_size/2)·maxabs/127` if every row in a region sets a new
//!   max (`2·maxabs/127` at block_size 4, `8·maxabs/127` at the default
//!   16). Typical error is far smaller — raises are records of a random
//!   sequence (~H(block_size) of them) and roundings are random-signed —
//!   but bounds derived from this module must be stated per block size
//!   (`tests/kernel_native.rs` and `tests/kv_quant.rs` derive and assert
//!   theirs at block_size 4). Quarters block bytes (+4 B per region for
//!   the scale).
//!
//! Quantization is a **worker-local storage decision**: the wire protocol,
//! codec, and engine (PJRT) backend stay f32 — appends quantize on the way
//! in, `gather` widens on the way out, and only the native kernel consumes
//! the compact lanes directly (dequantizing in-register inside its
//! dot/axpy loops — see `kernels::paged_attn`).

/// Storage dtype of a KV arena's block buffers (`--kv-dtype`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// 4 B/elem, bit-exact storage (the PR-3 behaviour; default).
    #[default]
    F32,
    /// 2 B/elem IEEE binary16, software convert (no_std-external-crate-free).
    F16,
    /// 1 B/elem symmetric int8 + one f32 scale per (block, head) region.
    Int8,
}

impl KvDtype {
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s {
            "f32" => Some(KvDtype::F32),
            "f16" => Some(KvDtype::F16),
            "int8" => Some(KvDtype::Int8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }

    /// Bytes per stored KV element.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::Int8 => 1,
        }
    }

    /// Extra bytes per (block, head) K or V region (the int8 scale).
    pub fn scale_bytes(self) -> usize {
        match self {
            KvDtype::Int8 => 4,
            _ => 0,
        }
    }
}

// ---- f32 ↔ f16 (IEEE 754 binary16 as u16 bits) ----------------------------

/// Convert f32 → f16 bits with round-to-nearest-even.
///
/// Edge cases follow IEEE narrowing: NaN stays NaN (quiet bit forced,
/// top mantissa payload bits kept), ±inf and ±0 are preserved, values
/// ≥ 65520 overflow to ±inf, values below the f16 subnormal range
/// round to ±0, and the f16 subnormal range (|x| < 2⁻¹⁴) is rounded
/// correctly rather than flushed.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let abs = b & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // inf / NaN: keep NaN-ness (force the quiet bit so a payload that
        // shifts away cannot turn a NaN into inf)
        let payload = if abs > 0x7f80_0000 { 0x0200 | ((abs >> 13) as u16 & 0x03ff) } else { 0 };
        return sign | 0x7c00 | payload;
    }
    if abs >= 0x4780_0000 {
        // ≥ 2^16: past the largest finite f16 even before rounding
        return sign | 0x7c00;
    }
    let e = (abs >> 23) as i32; // biased f32 exponent
    let m = abs & 0x007f_ffff;
    if e > 112 {
        // normal f16: rebias exponent, round 13 mantissa bits away (RNE).
        // A mantissa carry propagates into the exponent; at e == 142 that
        // correctly yields inf (values in [65520, 65536) round up).
        let mut out = (((e - 112) as u32) << 10) | (m >> 13);
        let round = m & 0x1fff;
        if round > 0x1000 || (round == 0x1000 && (out & 1) == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    if e < 102 {
        // below half the smallest f16 subnormal (2⁻²⁵): rounds to ±0.
        // (Covers all f32 subnormals too.)
        return sign;
    }
    // f16 subnormal: value = m16 · 2⁻²⁴ with m16 = round(1.m · 2^(e-102))
    let full = m | 0x0080_0000; // implicit bit
    let shift = (126 - e) as u32; // 14..=24
    let halfway = 1u32 << (shift - 1);
    let rem = full & ((1 << shift) - 1);
    let mut m16 = full >> shift;
    if rem > halfway || (rem == halfway && (m16 & 1) == 1) {
        m16 += 1; // may carry to 0x0400 = smallest normal — still correct
    }
    sign | m16 as u16
}

/// Widen f16 bits → f32. Exact for every f16 value (binary16 ⊂ binary32);
/// NaN payloads and signs are preserved.
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // subnormal: normalise into f32's larger exponent range
        let mut e = 113u32; // f32 biased exponent of 2⁻¹⁴
        let mut m = man;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        return f32::from_bits(sign | (e << 23) | ((m & 0x03ff) << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Branchless single-lane widen used by [`f16_bits_widen`]. Same result,
/// bit for bit, as [`f16_bits_to_f32`], but shaped for auto-vectorization:
/// the exponent rebias (including subnormals) is one exact multiply by
/// 2¹¹², and the inf/NaN fixup is a select instead of a branch.
///
/// Why the multiply works: `(h & 0x7fff) << 13` re-interprets the f16
/// exponent/mantissa as an f32 with the same *unbiased* exponent minus
/// 112 (bias 15 vs 127, mantissa left-aligned). Scaling by 2¹¹² restores
/// the value exactly — f16 subnormals land as f32 *normals* (m·2⁻²⁴ ≥
/// 2⁻²⁴ ≫ f32's min normal), so no lane loses bits. Only exp = 0x1f
/// (inf/NaN) comes out finite and needs the patch-up.
#[inline]
fn f16_widen_lane(h: u16) -> f32 {
    let bits = ((h & 0x7fff) as u32) << 13;
    let widened = (f32::from_bits(bits) * f32::from_bits((127 + 112) << 23)).to_bits();
    // exp == 0x1f ⇔ bits ≥ 0x7c00 << 13: rebuild inf/NaN (payload kept)
    let special = 0x7f80_0000 | (bits & 0x007f_e000);
    let mag = if bits >= 0x0f80_0000 { special } else { widened };
    f32::from_bits(mag | (((h & 0x8000) as u32) << 16))
}

/// Bulk f16 → f32 widen: `dst[i] = f32(src[i])`, bit-identical to mapping
/// [`f16_bits_to_f32`] per lane.
///
/// The scalar widen's exponent branches made it the f16 decode-path
/// bottleneck (per-lane widen inside the native kernel's dot/axpy loops);
/// this processes fixed-width chunks of [`f16_widen_lane`] so the
/// compiler can keep the whole pipeline — mask, multiply, select — in
/// SIMD registers. The `kernel/f16_widen_*` bench rows measure the delta.
pub fn f16_bits_widen(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    const CHUNK: usize = 16;
    let mut s = src.chunks_exact(CHUNK);
    let mut d = dst.chunks_exact_mut(CHUNK);
    for (sc, dc) in (&mut s).zip(&mut d) {
        for i in 0..CHUNK {
            dc[i] = f16_widen_lane(sc[i]);
        }
    }
    for (dd, &h) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dd = f16_widen_lane(h);
    }
}

// ---- f32 ↔ int8 with per-region scale -------------------------------------

/// Symmetric scale for a region whose max |value| is `maxabs`: codes span
/// the full ±127 range at any magnitude (scales work from 1e-30 to 1e30).
#[inline]
pub fn i8_scale_for(maxabs: f32) -> f32 {
    maxabs / 127.0
}

/// Quantize one value at `scale` (round-to-nearest, clamped to ±127).
/// `scale == 0` means the region is all-zero so far.
#[inline]
pub fn i8_encode(x: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// Dequantize one code.
#[inline]
pub fn i8_decode(c: i8, scale: f32) -> f32 {
    c as f32 * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(x: f32) -> f32 {
        f16_bits_to_f32(f32_to_f16_bits(x))
    }

    #[test]
    fn f16_exact_values_roundtrip_bitwise() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.103515625e-5] {
            assert_eq!(rt(x).to_bits(), x.to_bits(), "f16-representable {x} must be exact");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(rt(f32::INFINITY), f32::INFINITY);
        assert_eq!(rt(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(rt(f32::NAN).is_nan());
        assert!(rt(f32::from_bits(0x7f80_0001)).is_nan(), "sig NaN stays NaN");
        assert_eq!(rt(-0.0).to_bits(), (-0.0f32).to_bits(), "signed zero kept");
        // overflow → inf, underflow → 0 (sign kept)
        assert_eq!(rt(1e9), f32::INFINITY);
        assert_eq!(rt(-1e9), f32::NEG_INFINITY);
        assert_eq!(rt(65520.0), f32::INFINITY, "≥65520 rounds to inf");
        assert_eq!(rt(65519.0), 65504.0, "<65520 rounds to max finite");
        assert_eq!(rt(1e-30).to_bits(), 0.0f32.to_bits());
        assert_eq!(rt(-1e-30).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_subnormal_range() {
        let smallest = 5.960464477539063e-8; // 2⁻²⁴
        assert_eq!(rt(smallest), smallest);
        assert_eq!(rt(smallest * 3.0), smallest * 3.0);
        // exactly half the smallest subnormal ties-to-even down to zero
        assert_eq!(rt(smallest / 2.0), 0.0);
        // just above half rounds up to the smallest subnormal
        assert_eq!(rt(smallest * 0.6), smallest);
    }

    #[test]
    fn f16_relative_error_bound_on_normals() {
        // |x - rt(x)| ≤ 2⁻¹¹ · |x| over the f16 normal range
        let mut x = 7.0e-5f32;
        while x < 6.0e4 {
            for s in [1.0f32, -1.0] {
                let v = x * s * 1.2345;
                let err = (rt(v) - v).abs();
                assert!(err <= v.abs() * 4.8829e-4, "x={v} err={err}");
            }
            x *= 1.7;
        }
    }

    #[test]
    fn bulk_widen_bit_identical_to_scalar_for_every_f16() {
        // all 65536 bit patterns, in one bulk call crossing chunk bounds
        let src: Vec<u16> = (0..=u16::MAX).collect();
        let mut dst = vec![0.0f32; src.len()];
        f16_bits_widen(&src, &mut dst);
        for (&h, &f) in src.iter().zip(&dst) {
            assert_eq!(
                f.to_bits(),
                f16_bits_to_f32(h).to_bits(),
                "lane {h:#06x} diverged from the scalar widen"
            );
        }
    }

    #[test]
    fn bulk_widen_remainder_lanes() {
        // lengths around the chunk width exercise the remainder path
        for n in [0usize, 1, 15, 16, 17, 31, 33] {
            let src: Vec<u16> = (0..n as u16).map(|i| 0x3c00 + i).collect();
            let mut dst = vec![0.0f32; n];
            f16_bits_widen(&src, &mut dst);
            for (&h, &f) in src.iter().zip(&dst) {
                assert_eq!(f.to_bits(), f16_bits_to_f32(h).to_bits());
            }
        }
    }

    #[test]
    fn i8_roundtrip_error_bound_at_any_magnitude() {
        for &mag in &[1e-30f32, 1e-3, 1.0, 47.0, 1e12, 1e30] {
            let scale = i8_scale_for(mag);
            for i in -10..=10 {
                let x = mag * (i as f32) / 10.0;
                let err = (i8_decode(i8_encode(x, scale), scale) - x).abs();
                assert!(err <= scale * 0.5 + mag * 1e-6, "mag={mag} x={x} err={err}");
            }
        }
    }

    #[test]
    fn i8_zero_scale_is_all_zero() {
        assert_eq!(i8_encode(0.0, 0.0), 0);
        assert_eq!(i8_decode(0, 0.0), 0.0);
        // clamp guards against values above the scale's max
        assert_eq!(i8_encode(1e10, 1.0), 127);
        assert_eq!(i8_encode(-1e10, 1.0), -127);
    }

    #[test]
    fn dtype_parse_and_sizes() {
        for d in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            assert_eq!(KvDtype::parse(d.name()), Some(d));
        }
        assert_eq!(KvDtype::parse("fp8"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(
            (KvDtype::F32.elem_bytes(), KvDtype::F16.elem_bytes(), KvDtype::Int8.elem_bytes()),
            (4, 2, 1)
        );
        assert_eq!(KvDtype::Int8.scale_bytes(), 4);
        assert_eq!(KvDtype::F16.scale_bytes(), 0);
    }
}
