//! KV-cache substrate: paged block allocation, per-request block tables,
//! the block-paged arena backing the live attention workers, and the
//! head-/request-level partitioning strategies of paper §5/Fig. 9.

pub mod arena;
pub mod block;
pub mod partition;
pub mod table;

pub use arena::{ArenaCfg, PagedKvArena, TableView, PAD_SLOT};
pub use block::{AllocError, BlockAllocator, BlockId};
pub use partition::{head_level, kv_blocks_needed, request_level, Partition};
pub use table::{BlockTable, KvRegistry};
