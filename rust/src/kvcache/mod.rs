//! KV-cache substrate: paged block allocation, per-request block tables,
//! the block-paged arena backing the live attention workers (with
//! f32/f16/int8 block storage — see [`quant`]), and the head-/request-level
//! partitioning strategies of paper §5/Fig. 9.

pub mod arena;
pub mod block;
pub mod partition;
pub mod quant;
pub mod table;

pub use arena::{ArenaCfg, KvBlockRef, PagedKvArena, TableView, PAD_SLOT};
pub use block::{AllocError, BlockAllocator, BlockId};
pub use partition::{head_level, kv_blocks_needed, kv_bytes_needed, request_level, Partition};
pub use quant::KvDtype;
pub use table::{BlockTable, KvRegistry};
