//! KV-cache substrate: paged block allocation, per-request block tables,
//! and the head-/request-level partitioning strategies of paper §5/Fig. 9.

pub mod block;
pub mod partition;
pub mod table;

pub use block::{AllocError, BlockAllocator, BlockId};
pub use partition::{head_level, request_level, Partition};
pub use table::{BlockTable, KvRegistry};
