//! KV-cache substrate: paged block allocation, per-request block tables,
//! the block-paged arena backing the live attention workers (with
//! f32/f16/int8 block storage — see [`quant`]), and the head-/request-level
//! partitioning strategies of paper §5/Fig. 9.
//!
//! Physical blocks are **refcounted and sharable** ([`block`]): several
//! requests' tables may map the same block read-only, which is what makes
//! prompt-prefix dedup possible on the memory-bound attention tier — the
//! capacity lever Lamina's economics turn on (a worker's achievable batch
//! is whatever its arena can hold). The moving parts:
//!
//! * [`block`] — free-list allocator with per-block refcounts: `retain`
//!   adds a mapping, `release` decrements and frees on the last drop.
//! * [`table`] — per-request chains; `map_shared` mirrors a donor's prefix
//!   chain, `replace_block` swaps in a private clone on first write.
//! * [`arena`] — owns the payloads: `map_prefix` wires a shared prefix
//!   slot-to-slot, appends **copy-on-write** into shared tails, and
//!   `stats()` reports logical vs physical occupancy so dedup is
//!   observable end to end.
//! * [`prefix`] — the leader-side trie keyed on prompt tokens at block
//!   granularity that *finds* reusable prefixes at admission.
//!
//! Sharing is always block-aligned and capped below the full prompt, so a
//! cache hit still prefills ≥ 1 token; a cache miss is bit-identical to a
//! run with the index disabled.

pub mod arena;
pub mod block;
pub mod partition;
pub mod prefix;
pub mod quant;
pub mod table;

pub use arena::{ArenaCfg, KvBlockRef, PagedKvArena, TableView, PAD_SLOT};
pub use block::{AllocError, BlockAllocator, BlockId};
pub use partition::{
    head_level, head_ranges, kv_blocks_needed, kv_bytes_needed, request_level, Partition,
    ShardRange,
};
pub use prefix::{PrefixHit, PrefixIndex};
pub use quant::KvDtype;
pub use table::{BlockTable, KvRegistry};
