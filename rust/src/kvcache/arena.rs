//! Block-paged KV arena: the attention worker's resident KV store.
//!
//! Replaces the seed's dense per-slot `[KH_shard, max_seq, hd]` shards
//! (O(slots × max_seq) resident memory regardless of live context) with the
//! PagedAttention-style layout the paper's §8 names as the composable
//! optimisation to adopt: per layer, one contiguous K and one V buffer of
//! `[total_blocks, KH_shard, block_size, hd]`, carved into fixed-size
//! blocks of `block_size` token slots handed out by
//! [`super::block::BlockAllocator`] and mapped per request slot by
//! [`super::table::BlockTable`].
//!
//! Key properties:
//! * **Resident memory scales with allocated blocks.** The arena starts
//!   small and grows geometrically on demand (`BlockAllocator::grow` +
//!   buffer resize); retired requests return their blocks to the pool, so
//!   steady-state footprint tracks live context, not
//!   `slots × max_waves × max_seq`.
//! * **Dtype-generic block storage** ([`KvDtype`], the `--kv-dtype` flag).
//!   Blocks are stored `f32` (bit-exact, the default), `f16` (bit-cast
//!   `u16` lanes, software round-to-nearest-even — 2× fewer bytes), or
//!   `int8` with one f32 scale per (block, head) K/V region maintained at
//!   append time (≈4× fewer bytes; a later token that raises a region's
//!   running max requantizes its codes in place — worst-case per-element
//!   error is `(block_size/2)·maxabs/127` over a full chain of raises,
//!   see [`super::quant`] for the derivation). Appends quantize **in
//!   place**; nothing upstream changes: the wire protocol and codec stay
//!   f32 (quantization is a worker-local storage decision) and the same
//!   `--kv-budget` block budget now holds 2×/4× more tokens of context.
//! * **Two read paths.** The *native* attention backend
//!   (`kernels::paged_attn`) reads blocks **in place** through the
//!   read-only view API — [`PagedKvArena::table_view`] exposes a slot's
//!   block list and [`PagedKvArena::block_slices`] borrows one
//!   `(layer, block, head)` region as a dtype-tagged [`KvBlockRef`]
//!   (`block_size × hd` contiguous lanes of the storage dtype, plus the
//!   int8 scales) — the kernel dequantizes in-register inside its dot/axpy
//!   loops, so the steady-state decode path performs **zero** per-step KV
//!   copies *and* reads 2×/≈4× fewer bytes at f16/int8. The *engine*
//!   (PJRT) backend still needs contiguous f32 inputs and uses
//!   [`PagedKvArena::gather`], which **widens on read**: one decode per
//!   (row, head, block) region into a `[bucket, KH_shard, seq_bucket, hd]`
//!   f32 staging pair (charged to [`copies`]); gather output buffers are
//!   recycled across steps.
//! * **Blocks are zeroed when (re)assigned** to a slot (codes and int8
//!   scales), so gathers are bit-identical to a dense zero-initialised
//!   reference cache (asserted by the `kv_paged` property test) and
//!   recycled blocks can never leak KV across requests.
//! * **Blocks are refcounted and sharable** (prefix caching).
//!   [`PagedKvArena::map_prefix`] maps the blocks covering the first
//!   `tokens` positions of one slot into another slot read-only — no
//!   payload moves, each block just gains a reference — and retirement
//!   decrements, so a shared prompt's KV stays resident until the last
//!   holder leaves. Writes are **copy-on-write**: the first append into a
//!   shared block clones its payload (all layers, K+V, int8 scales) into
//!   a private block first, so sharers never observe each other's
//!   appends and every gather stays bit-identical to an unshared arena.
//!
//! Accounting is reported in **blocks and bytes**: [`PagedKvArena::stats`]
//! fills `KvCacheStats::{bytes_in_use, total_bytes}` from the storage
//! dtype (including int8 scale overhead), so admission control and
//! `ServeMetrics` see the capacity gain of quantized storage, not just a
//! block count. Under sharing the *logical* view (`blocks_in_use`, summed
//! per table) and the *physical* view (`physical_blocks_in_use`, distinct
//! resident blocks) diverge — their ratio is the prefix-cache dedup
//! factor.
//!
//! Layer handling mirrors the wire protocol: one block table per slot is
//! shared by all layers (every layer's buffer has capacity at the same
//! block id), and the table grows exactly once per token — at `layer == 0`,
//! where a write at position 0 also retires any stale table left by a
//! previous occupant of the slot.

use std::sync::Arc;

use super::block::{BlockAllocator, BlockId};
use super::quant::{
    f16_bits_to_f32, f32_to_f16_bits, i8_decode, i8_encode, i8_scale_for, KvDtype,
};
use super::table::BlockTable;
use crate::metrics::KvCacheStats;
use crate::runtime::host::{copies, HostTensor};

/// Sentinel slot id marking a padded batch row (no backing request).
pub const PAD_SLOT: u32 = u32::MAX;

/// Read-only snapshot of one slot's block table (see
/// [`PagedKvArena::table_view`]).
#[derive(Debug, Clone, Copy)]
pub struct TableView<'a> {
    blocks: &'a [BlockId],
    len_tokens: usize,
}

impl<'a> TableView<'a> {
    /// Physical block ids in logical-token order.
    pub fn blocks(&self) -> &'a [BlockId] {
        self.blocks
    }

    /// Cached tokens the table currently maps.
    pub fn len_tokens(&self) -> usize {
        self.len_tokens
    }
}

/// Borrowed K and V regions of one `(layer, block, head)` in the arena's
/// **storage dtype** — `block_size × hd` contiguous lanes each, covering
/// token positions `[i·block_size, (i+1)·block_size)` of whichever table
/// slot owns the block at position `i`. This is the native kernel's
/// zero-copy read: nothing moves, nothing is charged to [`copies`]; the
/// kernel widens lanes in-register (f16 bit convert, int8 `code × scale`)
/// inside its dot/axpy loops.
#[derive(Debug, Clone, Copy)]
pub enum KvBlockRef<'a> {
    F32 { k: &'a [f32], v: &'a [f32] },
    /// Bit-cast IEEE binary16 lanes.
    F16 { k: &'a [u16], v: &'a [u16] },
    /// Symmetric int8 codes with this region's per-(block, head) scales.
    Int8 { k: &'a [i8], v: &'a [i8], k_scale: f32, v_scale: f32 },
}

/// Arena geometry and sizing.
#[derive(Debug, Clone, Copy)]
pub struct ArenaCfg {
    /// Model layers (each holds its own K/V buffer pair).
    pub layers: usize,
    /// KV heads *of this shard* (`kv_heads / n_shards`).
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Hard per-request context ceiling (protocol invariant).
    pub max_seq: usize,
    /// Request slots addressable by the wire protocol.
    pub slots: usize,
    /// Token slots per block (vLLM-style, typically 16).
    pub block_size: usize,
    /// Blocks to preallocate (the arena grows past this on demand).
    pub initial_blocks: usize,
    /// Storage dtype of the block buffers (`--kv-dtype`, default f32).
    pub dtype: KvDtype,
}

/// Per-layer K/V block buffers in the arena's storage dtype. Int8 carries
/// one f32 scale per (block, head) region for K and V separately
/// (`[total_blocks × kv_heads]` per layer).
#[derive(Debug)]
enum Store {
    F32 { k: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
    F16 { k: Vec<Vec<u16>>, v: Vec<Vec<u16>> },
    Int8 { k: Vec<Vec<i8>>, v: Vec<Vec<i8>>, ks: Vec<Vec<f32>>, vs: Vec<Vec<f32>> },
}

/// Paged KV store for one attention worker (one head shard, all layers).
#[derive(Debug)]
pub struct PagedKvArena {
    cfg: ArenaCfg,
    alloc: BlockAllocator,
    store: Store,
    /// Per slot: logical-token → physical-block mapping.
    tables: Vec<BlockTable>,
    /// Reusable gather output buffers (K, V). A gather hands the caller an
    /// `Arc` view of these; once the caller drops it (after the attention
    /// kernel consumed the input) the allocation is unique again and the
    /// next gather rewrites it in place instead of allocating fresh
    /// `[bucket, KH_s, seq, hd]` vectors every step.
    scratch: Option<(Arc<[f32]>, Arc<[f32]>)>,
    /// Scratch reuse toggle (on by default; benches flip it to measure the
    /// allocation cost it removes).
    reuse_scratch: bool,
}

impl PagedKvArena {
    pub fn new(cfg: ArenaCfg) -> Self {
        assert!(cfg.layers > 0 && cfg.kv_heads > 0 && cfg.head_dim > 0);
        assert!(cfg.block_size > 0, "block_size must be positive");
        let initial = cfg.initial_blocks.max(1);
        let elems = initial * cfg.kv_heads * cfg.block_size * cfg.head_dim;
        let scales = initial * cfg.kv_heads;
        let store = match cfg.dtype {
            KvDtype::F32 => Store::F32 {
                k: (0..cfg.layers).map(|_| vec![0.0; elems]).collect(),
                v: (0..cfg.layers).map(|_| vec![0.0; elems]).collect(),
            },
            KvDtype::F16 => Store::F16 {
                k: (0..cfg.layers).map(|_| vec![0u16; elems]).collect(),
                v: (0..cfg.layers).map(|_| vec![0u16; elems]).collect(),
            },
            KvDtype::Int8 => Store::Int8 {
                k: (0..cfg.layers).map(|_| vec![0i8; elems]).collect(),
                v: (0..cfg.layers).map(|_| vec![0i8; elems]).collect(),
                ks: (0..cfg.layers).map(|_| vec![0.0; scales]).collect(),
                vs: (0..cfg.layers).map(|_| vec![0.0; scales]).collect(),
            },
        };
        PagedKvArena {
            alloc: BlockAllocator::new(initial, cfg.block_size),
            store,
            tables: vec![BlockTable::default(); cfg.slots],
            scratch: None,
            reuse_scratch: true,
            cfg,
        }
    }

    /// Enable/disable gather-scratch reuse (on by default). Disabling also
    /// drops any cached buffer; used by benches to measure the effect.
    pub fn set_scratch_reuse(&mut self, on: bool) {
        self.reuse_scratch = on;
        if !on {
            self.scratch = None;
        }
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    /// KV heads of this shard (one worker's share of the model's KV heads).
    pub fn kv_heads(&self) -> usize {
        self.cfg.kv_heads
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    /// Storage dtype of the block buffers.
    pub fn dtype(&self) -> KvDtype {
        self.cfg.dtype
    }

    /// Request slots this arena addresses (the wire protocol's slot space).
    pub fn slots(&self) -> usize {
        self.tables.len()
    }

    /// Cached tokens currently held for `slot`.
    pub fn len_tokens(&self, slot: u32) -> usize {
        self.tables[slot as usize].len_tokens()
    }

    /// Bytes of one `(block, head)` K *or* V region as stored — lanes plus
    /// the int8 scale. The unit of the native kernel's per-step read
    /// traffic.
    pub fn region_bytes(&self) -> usize {
        self.cfg.block_size * self.cfg.head_dim * self.cfg.dtype.elem_bytes()
            + self.cfg.dtype.scale_bytes()
    }

    /// Bytes one block occupies across all shard heads, K and V (the
    /// per-block unit `KvCacheStats` bytes accounting is built from).
    pub fn block_bytes(&self) -> usize {
        2 * self.cfg.kv_heads * self.region_bytes()
    }

    /// Bytes the native kernel must read to cover `tokens` cached tokens of
    /// one slot: every allocated block's K and V regions across all shard
    /// heads, including int8 scales. This is the per-row unique working set
    /// — group queries revisit the same bytes through cache, and one layer
    /// step reads it exactly once.
    pub fn kv_read_bytes(&self, tokens: usize) -> usize {
        if tokens == 0 {
            return 0;
        }
        tokens.div_ceil(self.cfg.block_size) * self.block_bytes()
    }

    /// Bytes of K+V buffer currently resident across all layers (scales
    /// included for int8).
    pub fn resident_bytes(&self) -> usize {
        self.cfg.layers * self.alloc.total_blocks() * self.block_bytes()
    }

    /// Accounting snapshot: blocks in use/capacity, internal waste, and the
    /// same occupancy in **bytes** (dtype-aware, per layer × per block) so
    /// admission control and `ServeMetrics` see quantized storage's
    /// capacity gain. `blocks_in_use`/`bytes_in_use` are the **logical**
    /// view (blocks mapped by tables, counting a shared block once per
    /// mapper); `physical_blocks_in_use`/`physical_bytes_in_use` count
    /// distinct resident blocks — equal without sharing, and their ratio
    /// is the prefix-cache dedup factor.
    pub fn stats(&self) -> KvCacheStats {
        let lens: Vec<usize> = self
            .tables
            .iter()
            .map(|t| t.len_tokens())
            .filter(|&l| l > 0)
            .collect();
        let logical: usize = self.tables.iter().map(|t| t.blocks().len()).sum();
        let per_block = self.cfg.layers * self.block_bytes();
        KvCacheStats {
            blocks_in_use: logical,
            total_blocks: self.alloc.total_blocks(),
            block_size: self.cfg.block_size,
            internal_waste_tokens: self.alloc.internal_waste(&lens),
            bytes_in_use: logical * per_block,
            total_bytes: self.alloc.total_blocks() * per_block,
            physical_blocks_in_use: self.alloc.used_blocks(),
            physical_bytes_in_use: self.alloc.used_blocks() * per_block,
        }
    }

    /// Free every block owned by `slot` (request retirement): one reference
    /// is dropped per block — a block shared with other slots stays
    /// resident for them. Idempotent.
    pub fn retire(&mut self, slot: u32) {
        let table = &mut self.tables[slot as usize];
        table.free(&mut self.alloc);
    }

    /// Map the blocks covering the first `tokens` positions of `src_slot`'s
    /// cache into `dst_slot` as a shared read-only prefix (a prefix-cache
    /// hit): each covering block gains one reference and **no payload
    /// moves**. Any stale table on `dst_slot` is retired first. Later
    /// appends into a shared block (either slot's) are copy-on-write, so
    /// the two slots can never observe each other's writes.
    pub fn map_prefix(&mut self, dst_slot: u32, src_slot: u32, tokens: usize) {
        assert_ne!(dst_slot, src_slot, "cannot map a slot's prefix onto itself");
        assert!(tokens <= self.cfg.max_seq, "map_prefix beyond max_seq");
        let src = &self.tables[src_slot as usize];
        assert!(
            tokens <= src.len_tokens(),
            "map_prefix of {tokens} tokens from slot {src_slot} holding only {}",
            src.len_tokens()
        );
        let n = self.alloc.blocks_for_tokens(tokens);
        let blocks: Vec<BlockId> = src.blocks()[..n].to_vec();
        self.retire(dst_slot);
        self.tables[dst_slot as usize].map_shared(&blocks, tokens, &mut self.alloc);
    }

    /// Append one decode step's K/V `[bucket, KH_shard, hd]` at position
    /// `lens[b]` for each non-pad row, quantizing into the storage dtype in
    /// place. At `layer == 0` the slot's table grows (and a write at
    /// position 0 first retires any stale table).
    pub fn append_step(
        &mut self,
        slots: &[u32],
        layer: usize,
        k: &HostTensor,
        v: &HostTensor,
        lens: &[i32],
    ) {
        let kd = k.as_f32();
        let vd = v.as_f32();
        let (khs, hd) = (self.cfg.kv_heads, self.cfg.head_dim);
        for (b, &slot) in slots.iter().enumerate() {
            if slot == PAD_SLOT {
                continue;
            }
            let pos = lens[b] as usize;
            assert!(pos < self.cfg.max_seq, "KV overflow: pos {pos} ≥ {}", self.cfg.max_seq);
            if layer == 0 {
                if pos == 0 {
                    self.retire(slot);
                }
                self.grow_slot(slot as usize, pos + 1);
                self.make_exclusive(slot as usize, pos, pos + 1);
            }
            let (blk, off) = self.tables[slot as usize]
                .locate(pos, self.cfg.block_size)
                .expect("append beyond table: StepKv without layer-0 growth");
            for h in 0..khs {
                let src = (b * khs + h) * hd;
                self.write_row(layer, blk, h, off, &kd[src..src + hd], &vd[src..src + hd]);
            }
        }
    }

    /// Scatter a prefill chunk's K/V `[T, KH_shard, hd]` rows `0..valid`
    /// into `slot` at positions `cached..cached+valid`. A chunk starting at
    /// `cached == 0` (on `layer == 0`) resets the slot first.
    pub fn append_chunk(
        &mut self,
        slot: u32,
        layer: usize,
        k: &HostTensor,
        v: &HostTensor,
        cached: usize,
        valid: usize,
    ) {
        let kd = k.as_f32();
        let vd = v.as_f32();
        let (khs, hd) = (self.cfg.kv_heads, self.cfg.head_dim);
        assert!(cached + valid <= self.cfg.max_seq, "prefill KV overflow");
        if layer == 0 {
            if cached == 0 {
                self.retire(slot);
            }
            self.grow_slot(slot as usize, cached + valid);
            self.make_exclusive(slot as usize, cached, cached + valid);
        }
        for i in 0..valid {
            let (blk, off) = self.tables[slot as usize]
                .locate(cached + i, self.cfg.block_size)
                .expect("chunk beyond table: PrefillChunk without layer-0 growth");
            for h in 0..khs {
                let src = (i * khs + h) * hd;
                self.write_row(layer, blk, h, off, &kd[src..src + hd], &vd[src..src + hd]);
            }
        }
    }

    /// Assemble a contiguous `[bucket, KH_shard, seq_bucket, hd]` **f32**
    /// K/V input pair — the **engine backend's** staging path (the native
    /// kernel reads blocks in place via [`PagedKvArena::block_slices`]
    /// instead). Decodes whole per-head block regions (widening f16/int8
    /// storage back to f32 — the engine path always sees f32 regardless of
    /// the storage dtype); positions past a slot's allocated blocks stay
    /// zero, as do pad rows. Copied (written) bytes are charged to
    /// [`copies`].
    ///
    /// The output buffers come from a reusable scratch pair: when the
    /// previous gather's tensors have been dropped, their allocation is
    /// recycled in place (no per-step `vec![0.0; bucket*row]`); if the
    /// caller still holds them (or reuse is disabled) fresh buffers are
    /// allocated, so returned tensors are never aliased while live.
    pub fn gather(
        &mut self,
        slots: &[u32],
        layer: usize,
        bucket: usize,
        seq_bucket: usize,
    ) -> (HostTensor, HostTensor) {
        let (khs, hd, bs) = (self.cfg.kv_heads, self.cfg.head_dim, self.cfg.block_size);
        let row = khs * seq_bucket * hd;
        let needed = bucket * row;
        let (mut ka, mut va) = self.take_scratch(needed);
        let mut copied_elems = 0usize;
        {
            let k = &mut Arc::get_mut(&mut ka).expect("gather scratch uniquely owned")[..needed];
            let v = &mut Arc::get_mut(&mut va).expect("gather scratch uniquely owned")[..needed];
            k.fill(0.0);
            v.fill(0.0);
            for (b, &slot) in slots.iter().enumerate() {
                if slot == PAD_SLOT {
                    continue;
                }
                for h in 0..khs {
                    for (bi, &blk) in self.tables[slot as usize].blocks().iter().enumerate() {
                        let tok0 = bi * bs;
                        if tok0 >= seq_bucket {
                            break;
                        }
                        let n = bs.min(seq_bucket - tok0) * hd;
                        let dst = b * row + h * seq_bucket * hd + tok0 * hd;
                        match self.block_slices(layer, blk, h) {
                            KvBlockRef::F32 { k: kb, v: vb } => {
                                k[dst..dst + n].copy_from_slice(&kb[..n]);
                                v[dst..dst + n].copy_from_slice(&vb[..n]);
                            }
                            KvBlockRef::F16 { k: kb, v: vb } => {
                                for (o, &b16) in k[dst..dst + n].iter_mut().zip(&kb[..n]) {
                                    *o = f16_bits_to_f32(b16);
                                }
                                for (o, &b16) in v[dst..dst + n].iter_mut().zip(&vb[..n]) {
                                    *o = f16_bits_to_f32(b16);
                                }
                            }
                            KvBlockRef::Int8 { k: kb, v: vb, k_scale, v_scale } => {
                                for (o, &c) in k[dst..dst + n].iter_mut().zip(&kb[..n]) {
                                    *o = i8_decode(c, k_scale);
                                }
                                for (o, &c) in v[dst..dst + n].iter_mut().zip(&vb[..n]) {
                                    *o = i8_decode(c, v_scale);
                                }
                            }
                        }
                        copied_elems += 2 * n;
                    }
                }
            }
        }
        copies::add(copied_elems * 4);
        let shape = vec![bucket, khs, seq_bucket, hd];
        let kt = HostTensor::f32_arc(shape.clone(), Arc::clone(&ka));
        let vt = HostTensor::f32_arc(shape, Arc::clone(&va));
        if self.reuse_scratch {
            self.scratch = Some((ka, va));
        }
        (kt, vt)
    }

    // ---- read-only block views (the native kernel's zero-copy path) ------

    /// Read-only view of `slot`'s logical-token → physical-block mapping
    /// (shared by all layers). The native attention kernel iterates this in
    /// order to visit the slot's KV in logical-token order without any
    /// gather.
    pub fn table_view(&self, slot: u32) -> TableView<'_> {
        let t = &self.tables[slot as usize];
        TableView { blocks: t.blocks(), len_tokens: t.len_tokens() }
    }

    /// Borrow the K and V regions of one `(layer, block, head)` in the
    /// storage dtype — see [`KvBlockRef`]. No bytes move; nothing is
    /// charged to [`copies`].
    pub fn block_slices(&self, layer: usize, blk: BlockId, head: usize) -> KvBlockRef<'_> {
        let start = self.elem_offset(blk, head, 0);
        let n = self.cfg.block_size * self.cfg.head_dim;
        match &self.store {
            Store::F32 { k, v } => KvBlockRef::F32 {
                k: &k[layer][start..start + n],
                v: &v[layer][start..start + n],
            },
            Store::F16 { k, v } => KvBlockRef::F16 {
                k: &k[layer][start..start + n],
                v: &v[layer][start..start + n],
            },
            Store::Int8 { k, v, ks, vs } => {
                let si = self.scale_index(blk, head);
                KvBlockRef::Int8 {
                    k: &k[layer][start..start + n],
                    v: &v[layer][start..start + n],
                    k_scale: ks[layer][si],
                    v_scale: vs[layer][si],
                }
            }
        }
    }

    /// Hand back the cached scratch pair when it is big enough and no
    /// outstanding tensor still references it; otherwise allocate fresh.
    fn take_scratch(&mut self, elems: usize) -> (Arc<[f32]>, Arc<[f32]>) {
        if let Some((k, v)) = self.scratch.take() {
            if Arc::strong_count(&k) == 1 && Arc::strong_count(&v) == 1 && k.len() >= elems {
                return (k, v);
            }
        }
        let fresh = || std::iter::repeat(0.0f32).take(elems).collect::<Arc<[f32]>>();
        (fresh(), fresh())
    }

    // ---- internals --------------------------------------------------------

    fn block_elems(&self) -> usize {
        self.cfg.kv_heads * self.cfg.block_size * self.cfg.head_dim
    }

    /// Element offset of (block, head, token-within-block) in a layer buffer.
    fn elem_offset(&self, blk: BlockId, head: usize, tok: usize) -> usize {
        blk as usize * self.block_elems()
            + head * self.cfg.block_size * self.cfg.head_dim
            + tok * self.cfg.head_dim
    }

    /// Index of (block, head) in a per-layer int8 scale vector.
    fn scale_index(&self, blk: BlockId, head: usize) -> usize {
        blk as usize * self.cfg.kv_heads + head
    }

    /// Quantize one token row's K and V (`hd` f32 values each) into the
    /// storage at `(layer, blk, head, off)`. For int8, a row whose max
    /// |value| exceeds the region's running scale first requantizes the
    /// region's existing codes at the new scale (total per-element error
    /// stays ≤ `(block_size/2)·maxabs/127` over a full chain of raises;
    /// see [`super::quant`] for the derivation).
    fn write_row(
        &mut self,
        layer: usize,
        blk: BlockId,
        head: usize,
        off: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let dst = self.elem_offset(blk, head, off);
        let hd = self.cfg.head_dim;
        let region = self.elem_offset(blk, head, 0);
        let region_n = self.cfg.block_size * hd;
        let si = self.scale_index(blk, head);
        match &mut self.store {
            Store::F32 { k, v } => {
                k[layer][dst..dst + hd].copy_from_slice(k_row);
                v[layer][dst..dst + hd].copy_from_slice(v_row);
            }
            Store::F16 { k, v } => {
                for (o, &x) in k[layer][dst..dst + hd].iter_mut().zip(k_row) {
                    *o = f32_to_f16_bits(x);
                }
                for (o, &x) in v[layer][dst..dst + hd].iter_mut().zip(v_row) {
                    *o = f32_to_f16_bits(x);
                }
            }
            Store::Int8 { k, v, ks, vs } => {
                let kl = &mut k[layer];
                let sl = &mut ks[layer];
                write_row_i8(kl, sl, si, region, region_n, dst, k_row);
                let vl = &mut v[layer];
                let sl = &mut vs[layer];
                write_row_i8(vl, sl, si, region, region_n, dst, v_row);
            }
        }
    }

    /// Grow `slot`'s table to cover `tokens` positions, allocating (and
    /// zeroing) blocks as needed; grows the arena itself when the pool runs
    /// dry.
    fn grow_slot(&mut self, slot: usize, tokens: usize) {
        let need = self.alloc.blocks_for_tokens(tokens);
        let have = self.tables[slot].blocks().len();
        if need > have {
            self.ensure_free(need - have);
        }
        let table = &mut self.tables[slot];
        table
            .grow_to(tokens, &mut self.alloc)
            .expect("arena invariant: ensure_free preceded grow_to");
        if need > have {
            // recycled blocks carry a previous request's KV — zero them so
            // gathers beyond the written prefix read zeros, bit-identical
            // to a dense zero-initialised cache
            let fresh: Vec<BlockId> = self.tables[slot].blocks()[have..].to_vec();
            for blk in fresh {
                self.zero_block(blk);
            }
        }
    }

    /// Make every block covering positions `[from, to)` of `slot`
    /// exclusively owned before a write lands there: a block still shared
    /// with another table is cloned (payload of **all** layers, K and V,
    /// plus int8 scales) into a private block first — the copy-on-write
    /// step. Blocks already exclusive are untouched, so the unshared fast
    /// path costs one refcount load per written block.
    fn make_exclusive(&mut self, slot: usize, from: usize, to: usize) {
        if from >= to {
            return;
        }
        let bs = self.cfg.block_size;
        for bi in from / bs..=(to - 1) / bs {
            let blk = self.tables[slot].blocks()[bi];
            if !self.alloc.is_shared(blk) {
                continue;
            }
            self.ensure_free(1);
            let fresh = self.alloc.alloc().expect("arena invariant: ensure_free preceded alloc");
            self.clone_block(blk, fresh);
            let old = self.tables[slot].replace_block(bi, fresh);
            debug_assert_eq!(old, blk);
            self.alloc.release(blk);
        }
    }

    /// Copy `src`'s payload into `dst`: every layer's K and V region across
    /// all shard heads, plus the int8 per-(block, head) scales.
    fn clone_block(&mut self, src: BlockId, dst: BlockId) {
        let n = self.block_elems();
        let (s, d) = (src as usize * n, dst as usize * n);
        let heads = self.cfg.kv_heads;
        let (ss, ds) = (src as usize * heads, dst as usize * heads);
        match &mut self.store {
            Store::F32 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l].copy_within(s..s + n, d);
                    v[l].copy_within(s..s + n, d);
                }
            }
            Store::F16 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l].copy_within(s..s + n, d);
                    v[l].copy_within(s..s + n, d);
                }
            }
            Store::Int8 { k, v, ks, vs } => {
                for l in 0..self.cfg.layers {
                    k[l].copy_within(s..s + n, d);
                    v[l].copy_within(s..s + n, d);
                    ks[l].copy_within(ss..ss + heads, ds);
                    vs[l].copy_within(ss..ss + heads, ds);
                }
            }
        }
    }

    /// Guarantee `n` free blocks, growing the pool + buffers geometrically.
    fn ensure_free(&mut self, n: usize) {
        if self.alloc.can_alloc(n) {
            return;
        }
        let extra = n.max(self.alloc.total_blocks() / 2).max(4);
        self.alloc.grow(extra);
        let elems = self.alloc.total_blocks() * self.block_elems();
        let scales = self.alloc.total_blocks() * self.cfg.kv_heads;
        match &mut self.store {
            Store::F32 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l].resize(elems, 0.0);
                    v[l].resize(elems, 0.0);
                }
            }
            Store::F16 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l].resize(elems, 0);
                    v[l].resize(elems, 0);
                }
            }
            Store::Int8 { k, v, ks, vs } => {
                for l in 0..self.cfg.layers {
                    k[l].resize(elems, 0);
                    v[l].resize(elems, 0);
                    ks[l].resize(scales, 0.0);
                    vs[l].resize(scales, 0.0);
                }
            }
        }
    }

    fn zero_block(&mut self, blk: BlockId) {
        let n = self.block_elems();
        let start = blk as usize * n;
        let s0 = blk as usize * self.cfg.kv_heads;
        let s1 = s0 + self.cfg.kv_heads;
        match &mut self.store {
            Store::F32 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l][start..start + n].fill(0.0);
                    v[l][start..start + n].fill(0.0);
                }
            }
            Store::F16 { k, v } => {
                for l in 0..self.cfg.layers {
                    k[l][start..start + n].fill(0);
                    v[l][start..start + n].fill(0);
                }
            }
            Store::Int8 { k, v, ks, vs } => {
                for l in 0..self.cfg.layers {
                    k[l][start..start + n].fill(0);
                    v[l][start..start + n].fill(0);
                    ks[l][s0..s1].fill(0.0);
                    vs[l][s0..s1].fill(0.0);
                }
            }
        }
    }
}

/// Int8 row write into one (block, head) region of a layer buffer:
/// maintains the region's running scale, requantizing existing codes in
/// place when the incoming row raises the max |value|.
fn write_row_i8(
    codes: &mut [i8],
    scales: &mut [f32],
    si: usize,
    region: usize,
    region_n: usize,
    dst: usize,
    row: &[f32],
) {
    let maxabs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let s_old = scales[si];
    if maxabs > s_old * 127.0 {
        let s_new = i8_scale_for(maxabs);
        if s_old > 0.0 {
            // re-code the whole region (zeros stay zero) at the new scale
            let ratio = s_old / s_new;
            for c in codes[region..region + region_n].iter_mut() {
                *c = (*c as f32 * ratio).round().clamp(-127.0, 127.0) as i8;
            }
        }
        scales[si] = s_new;
    }
    let s = scales[si];
    for (o, &x) in codes[dst..dst + row.len()].iter_mut().zip(row) {
        *o = i8_encode(x, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PagedKvArena {
        tiny_with(KvDtype::F32)
    }

    fn tiny_with(dtype: KvDtype) -> PagedKvArena {
        PagedKvArena::new(ArenaCfg {
            layers: 2,
            kv_heads: 2,
            head_dim: 4,
            max_seq: 64,
            slots: 3,
            block_size: 4,
            initial_blocks: 2,
            dtype,
        })
    }

    fn step_kv(bucket: usize, khs: usize, hd: usize, base: f32) -> HostTensor {
        let data: Vec<f32> = (0..bucket * khs * hd).map(|i| base + i as f32).collect();
        HostTensor::f32(vec![bucket, khs, hd], data)
    }

    #[test]
    fn append_then_gather_roundtrips() {
        let mut a = tiny();
        let slots = [0u32, 1];
        for t in 0..6 {
            let lens = [t as i32, t as i32];
            for layer in 0..2 {
                let k = step_kv(2, 2, 4, (100 * layer + t) as f32);
                let v = step_kv(2, 2, 4, (1000 * layer + t) as f32);
                a.append_step(&slots, layer, &k, &v, &lens);
            }
        }
        assert_eq!(a.len_tokens(0), 6);
        let (k, v) = a.gather(&slots, 1, 2, 8);
        assert_eq!(k.shape(), &[2, 2, 8, 4]);
        // slot 0, head 0, token 3, layer 1 was written from step_kv base
        // 100*1+3 = 103 at src offset (b=0,h=0) → values 103..107
        let kd = k.as_f32();
        let tok3 = &kd[3 * 4..3 * 4 + 4];
        assert_eq!(tok3, &[103., 104., 105., 106.]);
        // positions past len are zero
        assert_eq!(&kd[6 * 4..8 * 4], &[0.0; 8]);
        // v buffer is independent
        assert_eq!(&v.as_f32()[3 * 4..3 * 4 + 4], &[1003., 1004., 1005., 1006.]);
    }

    #[test]
    fn pad_rows_stay_zero() {
        let mut a = tiny();
        let k = step_kv(2, 2, 4, 5.0);
        a.append_step(&[0, PAD_SLOT], 0, &k, &k, &[0, 0]);
        let (g, _) = a.gather(&[PAD_SLOT, 0], 0, 2, 4);
        let gd = g.as_f32();
        assert!(gd[..2 * 4 * 4].iter().all(|&x| x == 0.0), "pad row must be zero");
        assert_eq!(gd[2 * 4 * 4], 5.0); // slot 0 row follows
    }

    #[test]
    fn grows_on_demand_and_reuses_after_retire() {
        let mut a = tiny(); // 2 initial blocks of 4 tokens
        let slots = [0u32];
        for t in 0..32 {
            let lens = [t as i32];
            for layer in 0..2 {
                let k = step_kv(1, 2, 4, t as f32);
                a.append_step(&slots, layer, &k, &k, &lens);
            }
        }
        let grown = a.stats();
        assert_eq!(grown.blocks_in_use, 8); // ceil(32/4)
        assert!(grown.total_blocks >= 8);
        let resident = a.resident_bytes();

        a.retire(0);
        assert_eq!(a.stats().blocks_in_use, 0);

        // a new occupant reuses the freed pool without further growth
        for t in 0..32 {
            let lens = [t as i32];
            for layer in 0..2 {
                let k = step_kv(1, 2, 4, -(t as f32));
                a.append_step(&slots, layer, &k, &k, &lens);
            }
        }
        assert_eq!(a.resident_bytes(), resident, "churn must not grow the arena");
    }

    #[test]
    fn position_zero_write_resets_stale_slot() {
        let mut a = tiny();
        let k = step_kv(1, 2, 4, 7.0);
        for t in 0..5 {
            a.append_step(&[0], 0, &k, &k, &[t]);
        }
        assert_eq!(a.len_tokens(0), 5);
        // new request lands on the recycled slot at position 0
        let k2 = step_kv(1, 2, 4, 9.0);
        a.append_step(&[0], 0, &k2, &k2, &[0]);
        assert_eq!(a.len_tokens(0), 1);
        let (g, _) = a.gather(&[0], 0, 1, 8);
        let gd = g.as_f32();
        assert_eq!(gd[0], 9.0);
        // stale tokens 1..5 from the previous occupant must be zeroed
        assert!(gd[4..8 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn chunk_append_matches_positions() {
        let mut a = tiny();
        let chunk: Vec<f32> = (0..3 * 2 * 4).map(|i| i as f32).collect();
        let t = HostTensor::f32(vec![3, 2, 4], chunk);
        a.append_chunk(0, 0, &t, &t, 0, 3);
        a.append_chunk(0, 0, &t, &t, 3, 2); // only rows 0..2 valid
        assert_eq!(a.len_tokens(0), 5);
        let (g, _) = a.gather(&[0], 0, 1, 8);
        let gd = g.as_f32();
        // head 0: tokens 0..3 from chunk rows 0..3 (src stride khs*hd = 8)
        assert_eq!(&gd[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&gd[2 * 4..2 * 4 + 4], &[16., 17., 18., 19.]);
        // tokens 3..5 re-use chunk rows 0..2
        assert_eq!(&gd[3 * 4..3 * 4 + 4], &[0., 1., 2., 3.]);
        // head 1 of token 0 lands at [h=1, tok=0]
        assert_eq!(&gd[8 * 4..8 * 4 + 4], &[4., 5., 6., 7.]);
    }

    #[test]
    fn gather_scratch_reused_after_drop_and_safe_while_held() {
        let mut a = tiny();
        let k = step_kv(2, 2, 4, 3.0);
        a.append_step(&[0, 1], 0, &k, &k, &[0, 0]);

        let (g1, _) = a.gather(&[0, 1], 0, 2, 8);
        let snapshot = g1.as_f32().to_vec();

        // a second gather while g1 is live must NOT clobber it (the cached
        // scratch is still referenced, so a fresh buffer is allocated —
        // and that fresh buffer becomes the new cached scratch)
        let (g2, _) = a.gather(&[0, 1], 0, 2, 8);
        let ptr2 = g2.as_f32().as_ptr();
        assert!(!g2.shares_buffer(&g1), "live gather results must not alias");
        assert_eq!(g1.as_f32(), &snapshot[..], "held result untouched");
        assert_eq!(g2.as_f32(), g1.as_f32());

        // once both are dropped, the cached allocation is recycled in place
        drop(g1);
        drop(g2);
        let (g3, _) = a.gather(&[0, 1], 0, 2, 8);
        let reused = std::ptr::eq(g3.as_f32().as_ptr(), ptr2);
        assert!(reused, "dropped scratch must be reused");
        assert_eq!(g3.as_f32(), &snapshot[..]);

        // disabling reuse goes back to fresh allocations (still correct)
        drop(g3);
        a.set_scratch_reuse(false);
        let (g4, _) = a.gather(&[0, 1], 0, 2, 8);
        assert_eq!(g4.as_f32(), &snapshot[..]);
    }

    #[test]
    fn gather_scratch_grows_with_request() {
        let mut a = tiny();
        let k = step_kv(1, 2, 4, 1.0);
        a.append_step(&[0], 0, &k, &k, &[0]);
        let (small, _) = a.gather(&[0], 0, 1, 4);
        drop(small);
        // bigger gather than the cached scratch: must grow, stay correct
        let (big, _) = a.gather(&[0, PAD_SLOT, 0], 0, 3, 16);
        assert_eq!(big.shape(), &[3, 2, 16, 4]);
        assert_eq!(big.as_f32()[0], 1.0);
        assert!(big.as_f32()[2 * 16 * 4..4 * 16 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_views_borrow_written_kv_in_place() {
        let mut a = tiny(); // block_size 4, kv_heads 2, hd 4, f32 storage
        for t in 0..6 {
            let k = step_kv(1, 2, 4, (10 * t) as f32);
            let v = step_kv(1, 2, 4, (100 * t) as f32);
            a.append_step(&[0], 0, &k, &v, &[t]);
            a.append_step(&[0], 1, &k, &k, &[t]);
        }
        let view = a.table_view(0);
        assert_eq!(view.len_tokens(), 6);
        assert_eq!(view.blocks().len(), 2); // ceil(6/4)
        // token 5 lives in block 1 at offset 1; head 1 of its K was written
        // from step_kv(base 50) at src offset h*hd = 4 → values 54..58
        let blk = view.blocks()[1];
        let KvBlockRef::F32 { k: kb, v: vb } = a.block_slices(0, blk, 1) else {
            panic!("f32 arena must expose f32 block refs");
        };
        assert_eq!(kb.len(), 4 * 4);
        assert_eq!(&kb[4..8], &[54., 55., 56., 57.]);
        // V buffer is independent (base 500 at the same offset)
        assert_eq!(&vb[4..8], &[504., 505., 506., 507.]);
        // the view must alias the arena buffer, not copy: no `copies` charge
        let before = copies::total();
        let _ = a.block_slices(0, blk, 0);
        let _ = a.table_view(0);
        assert_eq!(copies::total(), before);
    }

    #[test]
    fn quantized_block_views_expose_storage_lanes() {
        // f16 arena: stored lanes are the bit-converted values
        let mut a = tiny_with(KvDtype::F16);
        let vals = HostTensor::f32(vec![1, 2, 4], vec![0.5, -1.25, 3.0, 0.0, 2.0, -0.75, 8.0, 1.5]);
        a.append_step(&[0], 0, &vals, &vals, &[0]);
        let blk = a.table_view(0).blocks()[0];
        let KvBlockRef::F16 { k, .. } = a.block_slices(0, blk, 0) else {
            panic!("f16 arena must expose f16 block refs");
        };
        assert_eq!(f16_bits_to_f32(k[0]), 0.5);
        assert_eq!(f16_bits_to_f32(k[1]), -1.25);

        // int8 arena: codes + per-region scale decode back within scale/2
        let mut a = tiny_with(KvDtype::Int8);
        a.append_step(&[0], 0, &vals, &vals, &[0]);
        let blk = a.table_view(0).blocks()[0];
        let KvBlockRef::Int8 { k, k_scale, .. } = a.block_slices(0, blk, 0) else {
            panic!("int8 arena must expose int8 block refs");
        };
        assert!((k_scale - 3.0 / 127.0).abs() < 1e-7, "scale from row maxabs");
        assert!((i8_decode(k[0], k_scale) - 0.5).abs() <= k_scale * 0.5);
        assert!((i8_decode(k[2], k_scale) - 3.0).abs() <= k_scale * 0.5);
    }

    #[test]
    fn int8_scale_grows_and_requantizes_in_place() {
        let mut a = tiny_with(KvDtype::Int8);
        // token 0: small values set a small scale
        let small = HostTensor::f32(vec![1, 2, 4], vec![0.1; 8]);
        a.append_step(&[0], 0, &small, &small, &[0]);
        // token 1 (same block): 100× larger values must raise the scale and
        // keep token 0 decodable within the NEW scale's error bound
        let big = HostTensor::f32(vec![1, 2, 4], vec![10.0; 8]);
        a.append_step(&[0], 0, &big, &big, &[1]);
        let blk = a.table_view(0).blocks()[0];
        let KvBlockRef::Int8 { k, k_scale, .. } = a.block_slices(0, blk, 0) else {
            panic!()
        };
        assert!((k_scale - 10.0 / 127.0).abs() < 1e-6);
        // one raise: old rounding (≤ s_old/2) + re-rounding (≤ s_new/2)
        // ≤ s_new = maxabs/127 (a full chain would scale with block_size)
        let bound = 10.0 / 127.0;
        assert!((i8_decode(k[0], k_scale) - 0.1).abs() <= bound, "old token survives");
        assert!((i8_decode(k[4], k_scale) - 10.0).abs() <= bound * 0.5, "new token fresh");
    }

    #[test]
    fn stats_report_bytes_per_dtype() {
        for (dtype, region) in [
            (KvDtype::F32, 4 * 4 * 4),
            (KvDtype::F16, 4 * 4 * 2),
            (KvDtype::Int8, 4 * 4 + 4),
        ] {
            let mut a = tiny_with(dtype);
            assert_eq!(a.region_bytes(), region, "{dtype:?}");
            assert_eq!(a.block_bytes(), 2 * 2 * region);
            let k = step_kv(1, 2, 4, 1.0);
            for t in 0..5 {
                a.append_step(&[0], 0, &k, &k, &[t]);
            }
            let s = a.stats();
            assert_eq!(s.blocks_in_use, 2); // ceil(5/4)
            // bytes = blocks × layers × block_bytes
            assert_eq!(s.bytes_in_use, 2 * 2 * a.block_bytes());
            assert_eq!(s.total_bytes, s.total_blocks * 2 * a.block_bytes());
            assert_eq!(a.resident_bytes(), s.total_bytes);
            // kernel working set for 5 tokens: 2 blocks × K+V × heads
            assert_eq!(a.kv_read_bytes(5), 2 * a.block_bytes());
            assert_eq!(a.kv_read_bytes(0), 0);
        }
    }

    #[test]
    fn internal_waste_reported() {
        let mut a = tiny(); // block_size 4
        let k = step_kv(1, 2, 4, 0.0);
        for t in 0..5 {
            a.append_step(&[0], 0, &k, &k, &[t]);
        }
        // 5 tokens over 2 blocks → 3 wasted tail slots
        assert_eq!(a.stats().internal_waste_tokens, 3);
        assert_eq!(a.stats().blocks_in_use, 2);
    }

    #[test]
    fn map_prefix_shares_blocks_and_stats_split_logical_physical() {
        let mut a = tiny(); // block_size 4, 2 layers
        for t in 0..8 {
            let k = step_kv(1, 2, 4, t as f32);
            for layer in 0..2 {
                a.append_step(&[0], layer, &k, &k, &[t]);
            }
        }
        a.map_prefix(1, 0, 8); // share both blocks
        a.map_prefix(2, 0, 8);
        let s = a.stats();
        assert_eq!(s.blocks_in_use, 6, "logical: 2 blocks × 3 tables");
        assert_eq!(s.physical_blocks_in_use, 2, "physical: one copy");
        assert_eq!(s.bytes_in_use, 6 * 2 * a.block_bytes());
        assert_eq!(s.physical_bytes_in_use, 2 * 2 * a.block_bytes());
        // both sharers gather the donor's KV bit-identically
        let (g0, _) = a.gather(&[0], 0, 1, 8);
        let (g1, _) = a.gather(&[1], 0, 1, 8);
        assert_eq!(g0.as_f32(), g1.as_f32());
        // donor retires first: blocks stay resident for the sharers
        a.retire(0);
        assert_eq!(a.stats().physical_blocks_in_use, 2);
        let (g2, _) = a.gather(&[2], 0, 1, 8);
        assert_eq!(g2.as_f32(), g0.as_f32());
        a.retire(1);
        a.retire(2);
        assert_eq!(a.stats().physical_blocks_in_use, 0, "last holder frees");
    }

    #[test]
    fn cow_append_into_shared_tail_clones_not_clobbers() {
        let mut a = tiny(); // block_size 4
        for t in 0..6 {
            let k = step_kv(1, 2, 4, (10 * t) as f32);
            for layer in 0..2 {
                a.append_step(&[0], layer, &k, &k, &[t]);
            }
        }
        // share a partial tail: 6 tokens = block 0 full + block 1 half
        a.map_prefix(1, 0, 6);
        let donor_before: Vec<f32> = a.gather(&[0], 1, 1, 8).0.as_f32().to_vec();

        // sharer appends token 6 → lands in the shared tail block → CoW
        let k6 = step_kv(1, 2, 4, 777.0);
        for layer in 0..2 {
            a.append_step(&[1], layer, &k6, &k6, &[6]);
        }
        assert_eq!(a.stats().physical_blocks_in_use, 3, "tail block cloned");
        // the donor's KV (every layer) is untouched by the sharer's append
        assert_eq!(a.gather(&[0], 1, 1, 8).0.as_f32(), &donor_before[..]);
        // the sharer sees the inherited prefix plus its own token
        let (g, _) = a.gather(&[1], 0, 1, 8);
        let gd = g.as_f32();
        assert_eq!(&gd[5 * 4..5 * 4 + 4], &[50., 51., 52., 53.], "inherited");
        assert_eq!(&gd[6 * 4..6 * 4 + 4], &[777., 778., 779., 780.], "own");

        // and the donor appending its own token 6 now needs no further CoW
        // (its tail went exclusive again when the sharer left it)
        for layer in 0..2 {
            a.append_step(&[0], layer, &k6, &k6, &[6]);
        }
        assert_eq!(a.stats().physical_blocks_in_use, 3, "no second clone");
        a.retire(0);
        a.retire(1);
        assert_eq!(a.stats().physical_blocks_in_use, 0, "no leaked blocks");
    }

    #[test]
    fn cow_clones_int8_scales_with_codes() {
        let mut a = tiny_with(KvDtype::Int8);
        let small = HostTensor::f32(vec![1, 2, 4], vec![0.1; 8]);
        a.append_step(&[0], 0, &small, &small, &[0]);
        a.append_step(&[0], 1, &small, &small, &[0]);
        a.map_prefix(1, 0, 1);
        // the sharer's append raises the scale in ITS clone only
        let big = HostTensor::f32(vec![1, 2, 4], vec![10.0; 8]);
        a.append_step(&[1], 0, &big, &big, &[1]);
        a.append_step(&[1], 1, &big, &big, &[1]);
        let donor_blk = a.table_view(0).blocks()[0];
        let sharer_blk = a.table_view(1).blocks()[0];
        assert_ne!(donor_blk, sharer_blk);
        let KvBlockRef::Int8 { k_scale: donor_scale, .. } = a.block_slices(0, donor_blk, 0) else {
            panic!()
        };
        let KvBlockRef::Int8 { k, k_scale, .. } = a.block_slices(0, sharer_blk, 0) else {
            panic!()
        };
        assert!((donor_scale - 0.1 / 127.0).abs() < 1e-9, "donor scale untouched");
        assert!((k_scale - 10.0 / 127.0).abs() < 1e-6, "clone requantized");
        // the inherited token survived the clone + requantize
        assert!((i8_decode(k[0], k_scale) - 0.1).abs() <= 10.0 / 127.0);
    }

    #[test]
    fn map_prefix_resets_stale_destination() {
        let mut a = tiny();
        let k = step_kv(1, 2, 4, 1.0);
        for t in 0..5 {
            a.append_step(&[0], 0, &k, &k, &[t]);
            a.append_step(&[1], 0, &k, &k, &[t]);
        }
        assert_eq!(a.stats().physical_blocks_in_use, 4);
        // mapping over slot 1 retires its private blocks first
        a.map_prefix(1, 0, 4);
        assert_eq!(a.stats().physical_blocks_in_use, 2);
        assert_eq!(a.len_tokens(1), 4);
    }
}
