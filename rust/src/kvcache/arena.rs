//! Block-paged KV arena: the attention worker's resident KV store.
//!
//! Replaces the seed's dense per-slot `[KH_shard, max_seq, hd]` shards
//! (O(slots × max_seq) resident memory regardless of live context) with the
//! PagedAttention-style layout the paper's §8 names as the composable
//! optimisation to adopt: per layer, one contiguous K and one V buffer of
//! `[total_blocks, KH_shard, block_size, hd]`, carved into fixed-size
//! blocks of `block_size` token slots handed out by
//! [`super::block::BlockAllocator`] and mapped per request slot by
//! [`super::table::BlockTable`].
//!
//! Key properties:
//! * **Resident memory scales with allocated blocks.** The arena starts
//!   small and grows geometrically on demand (`BlockAllocator::grow` +
//!   buffer resize); retired requests return their blocks to the pool, so
//!   steady-state footprint tracks live context, not
//!   `slots × max_waves × max_seq`.
//! * **Two read paths.** The *native* attention backend
//!   (`kernels::paged_attn`) reads blocks **in place** through the
//!   read-only view API — [`PagedKvArena::table_view`] exposes a slot's
//!   block list and [`PagedKvArena::block_slices`] borrows one
//!   `(layer, block, head)` region (`block_size × hd` contiguous floats) —
//!   so the steady-state decode path performs **zero** per-step KV copies.
//!   The *engine* (PJRT) backend still needs contiguous inputs and uses
//!   [`PagedKvArena::gather`]: one `copy_from_slice` per
//!   (row, head, block) into a `[bucket, KH_shard, seq_bucket, hd]` staging
//!   pair (charged to [`copies`]); gather output buffers are recycled
//!   across steps — the arena keeps the last pair and rewrites it in place
//!   once the caller has dropped the previous result.
//! * **Blocks are zeroed when (re)assigned** to a slot, so gathers are
//!   bit-identical to a dense zero-initialised reference cache (asserted by
//!   the `kv_paged` property test) and recycled blocks can never leak KV
//!   across requests.
//!
//! Layer handling mirrors the wire protocol: one block table per slot is
//! shared by all layers (every layer's buffer has capacity at the same
//! block id), and the table grows exactly once per token — at `layer == 0`,
//! where a write at position 0 also retires any stale table left by a
//! previous occupant of the slot.

use std::sync::Arc;

use super::block::{BlockAllocator, BlockId};
use super::table::BlockTable;
use crate::metrics::KvCacheStats;
use crate::runtime::host::{copies, HostTensor};

/// Sentinel slot id marking a padded batch row (no backing request).
pub const PAD_SLOT: u32 = u32::MAX;

/// Read-only snapshot of one slot's block table (see
/// [`PagedKvArena::table_view`]).
#[derive(Debug, Clone, Copy)]
pub struct TableView<'a> {
    blocks: &'a [BlockId],
    len_tokens: usize,
}

impl<'a> TableView<'a> {
    /// Physical block ids in logical-token order.
    pub fn blocks(&self) -> &'a [BlockId] {
        self.blocks
    }

    /// Cached tokens the table currently maps.
    pub fn len_tokens(&self) -> usize {
        self.len_tokens
    }
}

/// Arena geometry and sizing.
#[derive(Debug, Clone, Copy)]
pub struct ArenaCfg {
    /// Model layers (each holds its own K/V buffer pair).
    pub layers: usize,
    /// KV heads *of this shard* (`kv_heads / n_shards`).
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Hard per-request context ceiling (protocol invariant).
    pub max_seq: usize,
    /// Request slots addressable by the wire protocol.
    pub slots: usize,
    /// Token slots per block (vLLM-style, typically 16).
    pub block_size: usize,
    /// Blocks to preallocate (the arena grows past this on demand).
    pub initial_blocks: usize,
}

/// Paged KV store for one attention worker (one head shard, all layers).
#[derive(Debug)]
pub struct PagedKvArena {
    cfg: ArenaCfg,
    alloc: BlockAllocator,
    /// Per layer: K buffer `[total_blocks, kv_heads, block_size, head_dim]`.
    k: Vec<Vec<f32>>,
    /// Per layer: V buffer, same layout as `k`.
    v: Vec<Vec<f32>>,
    /// Per slot: logical-token → physical-block mapping.
    tables: Vec<BlockTable>,
    /// Reusable gather output buffers (K, V). A gather hands the caller an
    /// `Arc` view of these; once the caller drops it (after the attention
    /// kernel consumed the input) the allocation is unique again and the
    /// next gather rewrites it in place instead of allocating fresh
    /// `[bucket, KH_s, seq, hd]` vectors every step.
    scratch: Option<(Arc<[f32]>, Arc<[f32]>)>,
    /// Scratch reuse toggle (on by default; benches flip it to measure the
    /// allocation cost it removes).
    reuse_scratch: bool,
}

impl PagedKvArena {
    pub fn new(cfg: ArenaCfg) -> Self {
        assert!(cfg.layers > 0 && cfg.kv_heads > 0 && cfg.head_dim > 0);
        assert!(cfg.block_size > 0, "block_size must be positive");
        let initial = cfg.initial_blocks.max(1);
        let elems = initial * cfg.kv_heads * cfg.block_size * cfg.head_dim;
        PagedKvArena {
            alloc: BlockAllocator::new(initial, cfg.block_size),
            k: (0..cfg.layers).map(|_| vec![0.0; elems]).collect(),
            v: (0..cfg.layers).map(|_| vec![0.0; elems]).collect(),
            tables: vec![BlockTable::default(); cfg.slots],
            scratch: None,
            reuse_scratch: true,
            cfg,
        }
    }

    /// Enable/disable gather-scratch reuse (on by default). Disabling also
    /// drops any cached buffer; used by benches to measure the effect.
    pub fn set_scratch_reuse(&mut self, on: bool) {
        self.reuse_scratch = on;
        if !on {
            self.scratch = None;
        }
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    /// KV heads of this shard (one worker's share of the model's KV heads).
    pub fn kv_heads(&self) -> usize {
        self.cfg.kv_heads
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    /// Request slots this arena addresses (the wire protocol's slot space).
    pub fn slots(&self) -> usize {
        self.tables.len()
    }

    /// Cached tokens currently held for `slot`.
    pub fn len_tokens(&self, slot: u32) -> usize {
        self.tables[slot as usize].len_tokens()
    }

    /// Bytes of K+V buffer currently resident across all layers.
    pub fn resident_bytes(&self) -> usize {
        2 * self.cfg.layers * self.alloc.total_blocks() * self.block_elems() * 4
    }

    /// Accounting snapshot (blocks in use, capacity, internal waste).
    pub fn stats(&self) -> KvCacheStats {
        let lens: Vec<usize> = self
            .tables
            .iter()
            .map(|t| t.len_tokens())
            .filter(|&l| l > 0)
            .collect();
        KvCacheStats {
            blocks_in_use: self.alloc.used_blocks(),
            total_blocks: self.alloc.total_blocks(),
            block_size: self.cfg.block_size,
            internal_waste_tokens: self.alloc.internal_waste(&lens),
        }
    }

    /// Free every block owned by `slot` (request retirement). Idempotent.
    pub fn retire(&mut self, slot: u32) {
        let table = &mut self.tables[slot as usize];
        table.free(&mut self.alloc);
    }

    /// Append one decode step's K/V `[bucket, KH_shard, hd]` at position
    /// `lens[b]` for each non-pad row. At `layer == 0` the slot's table
    /// grows (and a write at position 0 first retires any stale table).
    pub fn append_step(
        &mut self,
        slots: &[u32],
        layer: usize,
        k: &HostTensor,
        v: &HostTensor,
        lens: &[i32],
    ) {
        let kd = k.as_f32();
        let vd = v.as_f32();
        let (khs, hd) = (self.cfg.kv_heads, self.cfg.head_dim);
        for (b, &slot) in slots.iter().enumerate() {
            if slot == PAD_SLOT {
                continue;
            }
            let pos = lens[b] as usize;
            assert!(pos < self.cfg.max_seq, "KV overflow: pos {pos} ≥ {}", self.cfg.max_seq);
            if layer == 0 {
                if pos == 0 {
                    self.retire(slot);
                }
                self.grow_slot(slot as usize, pos + 1);
            }
            let (blk, off) = self.tables[slot as usize]
                .locate(pos, self.cfg.block_size)
                .expect("append beyond table: StepKv without layer-0 growth");
            for h in 0..khs {
                let dst = self.elem_offset(blk, h, off);
                let src = (b * khs + h) * hd;
                self.k[layer][dst..dst + hd].copy_from_slice(&kd[src..src + hd]);
                self.v[layer][dst..dst + hd].copy_from_slice(&vd[src..src + hd]);
            }
        }
    }

    /// Scatter a prefill chunk's K/V `[T, KH_shard, hd]` rows `0..valid`
    /// into `slot` at positions `cached..cached+valid`. A chunk starting at
    /// `cached == 0` (on `layer == 0`) resets the slot first.
    pub fn append_chunk(
        &mut self,
        slot: u32,
        layer: usize,
        k: &HostTensor,
        v: &HostTensor,
        cached: usize,
        valid: usize,
    ) {
        let kd = k.as_f32();
        let vd = v.as_f32();
        let (khs, hd) = (self.cfg.kv_heads, self.cfg.head_dim);
        assert!(cached + valid <= self.cfg.max_seq, "prefill KV overflow");
        if layer == 0 {
            if cached == 0 {
                self.retire(slot);
            }
            self.grow_slot(slot as usize, cached + valid);
        }
        for i in 0..valid {
            let (blk, off) = self.tables[slot as usize]
                .locate(cached + i, self.cfg.block_size)
                .expect("chunk beyond table: PrefillChunk without layer-0 growth");
            for h in 0..khs {
                let dst = self.elem_offset(blk, h, off);
                let src = (i * khs + h) * hd;
                self.k[layer][dst..dst + hd].copy_from_slice(&kd[src..src + hd]);
                self.v[layer][dst..dst + hd].copy_from_slice(&vd[src..src + hd]);
            }
        }
    }

    /// Assemble a contiguous `[bucket, KH_shard, seq_bucket, hd]` K/V input
    /// pair — the **engine backend's** staging path (the native kernel
    /// reads blocks in place via [`PagedKvArena::block_slices`] instead).
    /// Copies whole per-head block regions (`block_size × hd` floats each);
    /// positions past a slot's allocated blocks stay zero, as do pad rows.
    /// Copied bytes are charged to [`copies`].
    ///
    /// The output buffers come from a reusable scratch pair: when the
    /// previous gather's tensors have been dropped, their allocation is
    /// recycled in place (no per-step `vec![0.0; bucket*row]`); if the
    /// caller still holds them (or reuse is disabled) fresh buffers are
    /// allocated, so returned tensors are never aliased while live.
    pub fn gather(
        &mut self,
        slots: &[u32],
        layer: usize,
        bucket: usize,
        seq_bucket: usize,
    ) -> (HostTensor, HostTensor) {
        let (khs, hd, bs) = (self.cfg.kv_heads, self.cfg.head_dim, self.cfg.block_size);
        let row = khs * seq_bucket * hd;
        let needed = bucket * row;
        let (mut ka, mut va) = self.take_scratch(needed);
        let mut copied_elems = 0usize;
        {
            let k = &mut Arc::get_mut(&mut ka).expect("gather scratch uniquely owned")[..needed];
            let v = &mut Arc::get_mut(&mut va).expect("gather scratch uniquely owned")[..needed];
            k.fill(0.0);
            v.fill(0.0);
            for (b, &slot) in slots.iter().enumerate() {
                if slot == PAD_SLOT {
                    continue;
                }
                let table = &self.tables[slot as usize];
                for h in 0..khs {
                    for (bi, &blk) in table.blocks().iter().enumerate() {
                        let tok0 = bi * bs;
                        if tok0 >= seq_bucket {
                            break;
                        }
                        let n = bs.min(seq_bucket - tok0) * hd;
                        let src = self.elem_offset(blk, h, 0);
                        let dst = b * row + h * seq_bucket * hd + tok0 * hd;
                        k[dst..dst + n].copy_from_slice(&self.k[layer][src..src + n]);
                        v[dst..dst + n].copy_from_slice(&self.v[layer][src..src + n]);
                        copied_elems += 2 * n;
                    }
                }
            }
        }
        copies::add(copied_elems * 4);
        let shape = vec![bucket, khs, seq_bucket, hd];
        let kt = HostTensor::f32_arc(shape.clone(), Arc::clone(&ka));
        let vt = HostTensor::f32_arc(shape, Arc::clone(&va));
        if self.reuse_scratch {
            self.scratch = Some((ka, va));
        }
        (kt, vt)
    }

    // ---- read-only block views (the native kernel's zero-copy path) ------

    /// Read-only view of `slot`'s logical-token → physical-block mapping
    /// (shared by all layers). The native attention kernel iterates this in
    /// order to visit the slot's KV in logical-token order without any
    /// gather.
    pub fn table_view(&self, slot: u32) -> TableView<'_> {
        let t = &self.tables[slot as usize];
        TableView { blocks: t.blocks(), len_tokens: t.len_tokens() }
    }

    /// Borrow the contiguous K and V regions of one `(layer, block, head)`:
    /// `block_size × hd` floats each, covering token positions
    /// `[i·block_size, (i+1)·block_size)` of whichever table slot owns
    /// block `blk` at position `i`. This is the in-place read the native
    /// kernel runs on — no bytes move, nothing is charged to [`copies`].
    pub fn block_slices(&self, layer: usize, blk: BlockId, head: usize) -> (&[f32], &[f32]) {
        let start = self.elem_offset(blk, head, 0);
        let n = self.cfg.block_size * self.cfg.head_dim;
        (&self.k[layer][start..start + n], &self.v[layer][start..start + n])
    }

    /// Hand back the cached scratch pair when it is big enough and no
    /// outstanding tensor still references it; otherwise allocate fresh.
    fn take_scratch(&mut self, elems: usize) -> (Arc<[f32]>, Arc<[f32]>) {
        if let Some((k, v)) = self.scratch.take() {
            if Arc::strong_count(&k) == 1 && Arc::strong_count(&v) == 1 && k.len() >= elems {
                return (k, v);
            }
        }
        let fresh = || std::iter::repeat(0.0f32).take(elems).collect::<Arc<[f32]>>();
        (fresh(), fresh())
    }

    // ---- internals --------------------------------------------------------

    fn block_elems(&self) -> usize {
        self.cfg.kv_heads * self.cfg.block_size * self.cfg.head_dim
    }

    /// Element offset of (block, head, token-within-block) in a layer buffer.
    fn elem_offset(&self, blk: BlockId, head: usize, tok: usize) -> usize {
        blk as usize * self.block_elems()
            + head * self.cfg.block_size * self.cfg.head_dim
            + tok * self.cfg.head_dim
    }

    /// Grow `slot`'s table to cover `tokens` positions, allocating (and
    /// zeroing) blocks as needed; grows the arena itself when the pool runs
    /// dry.
    fn grow_slot(&mut self, slot: usize, tokens: usize) {
        let need = self.alloc.blocks_for_tokens(tokens);
        let have = self.tables[slot].blocks().len();
        if need > have {
            self.ensure_free(need - have);
        }
        let table = &mut self.tables[slot];
        table
            .grow_to(tokens, &mut self.alloc)
            .expect("arena invariant: ensure_free preceded grow_to");
        if need > have {
            // recycled blocks carry a previous request's KV — zero them so
            // gathers beyond the written prefix read zeros, bit-identical
            // to a dense zero-initialised cache
            let fresh: Vec<BlockId> = self.tables[slot].blocks()[have..].to_vec();
            for blk in fresh {
                self.zero_block(blk);
            }
        }
    }

    /// Guarantee `n` free blocks, growing the pool + buffers geometrically.
    fn ensure_free(&mut self, n: usize) {
        if self.alloc.can_alloc(n) {
            return;
        }
        let extra = n.max(self.alloc.total_blocks() / 2).max(4);
        self.alloc.grow(extra);
        let elems = self.alloc.total_blocks() * self.block_elems();
        for l in 0..self.cfg.layers {
            self.k[l].resize(elems, 0.0);
            self.v[l].resize(elems, 0.0);
        }
    }

    fn zero_block(&mut self, blk: BlockId) {
        let n = self.block_elems();
        let start = blk as usize * n;
        for l in 0..self.cfg.layers {
            self.k[l][start..start + n].fill(0.0);
            self.v[l][start..start + n].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PagedKvArena {
        PagedKvArena::new(ArenaCfg {
            layers: 2,
            kv_heads: 2,
            head_dim: 4,
            max_seq: 64,
            slots: 3,
            block_size: 4,
            initial_blocks: 2,
        })
    }

    fn step_kv(bucket: usize, khs: usize, hd: usize, base: f32) -> HostTensor {
        let data: Vec<f32> = (0..bucket * khs * hd).map(|i| base + i as f32).collect();
        HostTensor::f32(vec![bucket, khs, hd], data)
    }

    #[test]
    fn append_then_gather_roundtrips() {
        let mut a = tiny();
        let slots = [0u32, 1];
        for t in 0..6 {
            let lens = [t as i32, t as i32];
            for layer in 0..2 {
                let k = step_kv(2, 2, 4, (100 * layer + t) as f32);
                let v = step_kv(2, 2, 4, (1000 * layer + t) as f32);
                a.append_step(&slots, layer, &k, &v, &lens);
            }
        }
        assert_eq!(a.len_tokens(0), 6);
        let (k, v) = a.gather(&slots, 1, 2, 8);
        assert_eq!(k.shape(), &[2, 2, 8, 4]);
        // slot 0, head 0, token 3, layer 1 was written from step_kv base
        // 100*1+3 = 103 at src offset (b=0,h=0) → values 103..107
        let kd = k.as_f32();
        let tok3 = &kd[3 * 4..3 * 4 + 4];
        assert_eq!(tok3, &[103., 104., 105., 106.]);
        // positions past len are zero
        assert_eq!(&kd[6 * 4..8 * 4], &[0.0; 8]);
        // v buffer is independent
        assert_eq!(&v.as_f32()[3 * 4..3 * 4 + 4], &[1003., 1004., 1005., 1006.]);
    }

    #[test]
    fn pad_rows_stay_zero() {
        let mut a = tiny();
        let k = step_kv(2, 2, 4, 5.0);
        a.append_step(&[0, PAD_SLOT], 0, &k, &k, &[0, 0]);
        let (g, _) = a.gather(&[PAD_SLOT, 0], 0, 2, 4);
        let gd = g.as_f32();
        assert!(gd[..2 * 4 * 4].iter().all(|&x| x == 0.0), "pad row must be zero");
        assert_eq!(gd[2 * 4 * 4], 5.0); // slot 0 row follows
    }

    #[test]
    fn grows_on_demand_and_reuses_after_retire() {
        let mut a = tiny(); // 2 initial blocks of 4 tokens
        let slots = [0u32];
        for t in 0..32 {
            let lens = [t as i32];
            for layer in 0..2 {
                let k = step_kv(1, 2, 4, t as f32);
                a.append_step(&slots, layer, &k, &k, &lens);
            }
        }
        let grown = a.stats();
        assert_eq!(grown.blocks_in_use, 8); // ceil(32/4)
        assert!(grown.total_blocks >= 8);
        let resident = a.resident_bytes();

        a.retire(0);
        assert_eq!(a.stats().blocks_in_use, 0);

        // a new occupant reuses the freed pool without further growth
        for t in 0..32 {
            let lens = [t as i32];
            for layer in 0..2 {
                let k = step_kv(1, 2, 4, -(t as f32));
                a.append_step(&slots, layer, &k, &k, &lens);
            }
        }
        assert_eq!(a.resident_bytes(), resident, "churn must not grow the arena");
    }

    #[test]
    fn position_zero_write_resets_stale_slot() {
        let mut a = tiny();
        let k = step_kv(1, 2, 4, 7.0);
        for t in 0..5 {
            a.append_step(&[0], 0, &k, &k, &[t]);
        }
        assert_eq!(a.len_tokens(0), 5);
        // new request lands on the recycled slot at position 0
        let k2 = step_kv(1, 2, 4, 9.0);
        a.append_step(&[0], 0, &k2, &k2, &[0]);
        assert_eq!(a.len_tokens(0), 1);
        let (g, _) = a.gather(&[0], 0, 1, 8);
        let gd = g.as_f32();
        assert_eq!(gd[0], 9.0);
        // stale tokens 1..5 from the previous occupant must be zeroed
        assert!(gd[4..8 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn chunk_append_matches_positions() {
        let mut a = tiny();
        let chunk: Vec<f32> = (0..3 * 2 * 4).map(|i| i as f32).collect();
        let t = HostTensor::f32(vec![3, 2, 4], chunk);
        a.append_chunk(0, 0, &t, &t, 0, 3);
        a.append_chunk(0, 0, &t, &t, 3, 2); // only rows 0..2 valid
        assert_eq!(a.len_tokens(0), 5);
        let (g, _) = a.gather(&[0], 0, 1, 8);
        let gd = g.as_f32();
        // head 0: tokens 0..3 from chunk rows 0..3 (src stride khs*hd = 8)
        assert_eq!(&gd[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&gd[2 * 4..2 * 4 + 4], &[16., 17., 18., 19.]);
        // tokens 3..5 re-use chunk rows 0..2
        assert_eq!(&gd[3 * 4..3 * 4 + 4], &[0., 1., 2., 3.]);
        // head 1 of token 0 lands at [h=1, tok=0]
        assert_eq!(&gd[8 * 4..8 * 4 + 4], &[4., 5., 6., 7.]);
    }

    #[test]
    fn gather_scratch_reused_after_drop_and_safe_while_held() {
        let mut a = tiny();
        let k = step_kv(2, 2, 4, 3.0);
        a.append_step(&[0, 1], 0, &k, &k, &[0, 0]);

        let (g1, _) = a.gather(&[0, 1], 0, 2, 8);
        let snapshot = g1.as_f32().to_vec();

        // a second gather while g1 is live must NOT clobber it (the cached
        // scratch is still referenced, so a fresh buffer is allocated —
        // and that fresh buffer becomes the new cached scratch)
        let (g2, _) = a.gather(&[0, 1], 0, 2, 8);
        let ptr2 = g2.as_f32().as_ptr();
        assert!(!g2.shares_buffer(&g1), "live gather results must not alias");
        assert_eq!(g1.as_f32(), &snapshot[..], "held result untouched");
        assert_eq!(g2.as_f32(), g1.as_f32());

        // once both are dropped, the cached allocation is recycled in place
        drop(g1);
        drop(g2);
        let (g3, _) = a.gather(&[0, 1], 0, 2, 8);
        let reused = std::ptr::eq(g3.as_f32().as_ptr(), ptr2);
        assert!(reused, "dropped scratch must be reused");
        assert_eq!(g3.as_f32(), &snapshot[..]);

        // disabling reuse goes back to fresh allocations (still correct)
        drop(g3);
        a.set_scratch_reuse(false);
        let (g4, _) = a.gather(&[0, 1], 0, 2, 8);
        assert_eq!(g4.as_f32(), &snapshot[..]);
    }

    #[test]
    fn gather_scratch_grows_with_request() {
        let mut a = tiny();
        let k = step_kv(1, 2, 4, 1.0);
        a.append_step(&[0], 0, &k, &k, &[0]);
        let (small, _) = a.gather(&[0], 0, 1, 4);
        drop(small);
        // bigger gather than the cached scratch: must grow, stay correct
        let (big, _) = a.gather(&[0, PAD_SLOT, 0], 0, 3, 16);
        assert_eq!(big.shape(), &[3, 2, 16, 4]);
        assert_eq!(big.as_f32()[0], 1.0);
        assert!(big.as_f32()[2 * 16 * 4..4 * 16 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_views_borrow_written_kv_in_place() {
        let mut a = tiny(); // block_size 4, kv_heads 2, hd 4
        for t in 0..6 {
            let k = step_kv(1, 2, 4, (10 * t) as f32);
            let v = step_kv(1, 2, 4, (100 * t) as f32);
            a.append_step(&[0], 0, &k, &v, &[t]);
            a.append_step(&[0], 1, &k, &k, &[t]);
        }
        let view = a.table_view(0);
        assert_eq!(view.len_tokens(), 6);
        assert_eq!(view.blocks().len(), 2); // ceil(6/4)
        // token 5 lives in block 1 at offset 1; head 1 of its K was written
        // from step_kv(base 50) at src offset h*hd = 4 → values 54..58
        let blk = view.blocks()[1];
        let (kb, vb) = a.block_slices(0, blk, 1);
        assert_eq!(kb.len(), 4 * 4);
        assert_eq!(&kb[4..8], &[54., 55., 56., 57.]);
        // V buffer is independent (base 500 at the same offset)
        assert_eq!(&vb[4..8], &[504., 505., 506., 507.]);
        // the view must alias the arena buffer, not copy: no `copies` charge
        let before = copies::total();
        let _ = a.block_slices(0, blk, 0);
        let _ = a.table_view(0);
        assert_eq!(copies::total(), before);
    }

    #[test]
    fn internal_waste_reported() {
        let mut a = tiny(); // block_size 4
        let k = step_kv(1, 2, 4, 0.0);
        for t in 0..5 {
            a.append_step(&[0], 0, &k, &k, &[t]);
        }
        // 5 tokens over 2 blocks → 3 wasted tail slots
        assert_eq!(a.stats().internal_waste_tokens, 3);
        assert_eq!(a.stats().blocks_in_use, 2);
    }
}
