//! Per-request block tables mapping logical token positions to physical KV
//! blocks, plus the request-level cache registry an attention worker keeps.
//!
//! With refcounted blocks (see [`super::block`]) a table may *share* a
//! prefix of another table's blocks read-only ([`BlockTable::map_shared`]);
//! [`BlockTable::free`] drops one reference per block, and a writer that
//! must mutate a shared block swaps in a private clone via
//! [`BlockTable::replace_block`] (the copy-on-write step lives in
//! `super::arena`, which owns the block payloads).

use super::block::{AllocError, BlockAllocator, BlockId};

/// Logical→physical mapping for one request's KV cache on one worker.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    len_tokens: usize,
}

impl BlockTable {
    pub fn len_tokens(&self) -> usize {
        self.len_tokens
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Physical (block, offset) of token position `pos`.
    pub fn locate(&self, pos: usize, block_size: usize) -> Option<(BlockId, usize)> {
        if pos >= self.len_tokens {
            return None;
        }
        Some((self.blocks[pos / block_size], pos % block_size))
    }

    /// Append one token slot, allocating a new block when the tail is full.
    pub fn append(&mut self, alloc: &mut BlockAllocator) -> Result<(), AllocError> {
        let bs = alloc.block_size();
        if self.len_tokens == self.blocks.len() * bs {
            self.blocks.push(alloc.alloc()?);
        }
        self.len_tokens += 1;
        Ok(())
    }

    /// Grow to hold `tokens` total token slots (prefill handoff).
    pub fn grow_to(&mut self, tokens: usize, alloc: &mut BlockAllocator) -> Result<(), AllocError> {
        let need = alloc.blocks_for_tokens(tokens);
        if need > self.blocks.len() {
            let extra = alloc.alloc_n(need - self.blocks.len())?;
            self.blocks.extend(extra);
        }
        self.len_tokens = self.len_tokens.max(tokens);
        Ok(())
    }

    /// Map an existing chain of physical blocks into this (empty) table as
    /// a shared read-only prefix of `tokens` token slots. Each block gains
    /// one reference; the donor table keeps its own.
    pub fn map_shared(&mut self, blocks: &[BlockId], tokens: usize, alloc: &mut BlockAllocator) {
        debug_assert!(self.blocks.is_empty() && self.len_tokens == 0, "map into non-empty table");
        debug_assert!(tokens <= blocks.len() * alloc.block_size());
        for &b in blocks {
            alloc.retain(b);
        }
        self.blocks.extend_from_slice(blocks);
        self.len_tokens = tokens;
    }

    /// Swap the block at chain index `idx` for a private copy (the
    /// copy-on-write step). Returns the previously mapped block so the
    /// caller can drop its reference after cloning the payload.
    pub fn replace_block(&mut self, idx: usize, with: BlockId) -> BlockId {
        std::mem::replace(&mut self.blocks[idx], with)
    }

    /// Drop one reference on every mapped block (blocks whose last
    /// reference this was return to the allocator's free list).
    pub fn free(&mut self, alloc: &mut BlockAllocator) {
        alloc.release_all(&self.blocks);
        self.blocks.clear();
        self.len_tokens = 0;
    }
}

/// Registry of live request caches on one attention worker.
#[derive(Debug)]
pub struct KvRegistry {
    pub alloc: BlockAllocator,
    tables: std::collections::BTreeMap<u64, BlockTable>,
}

impl KvRegistry {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        KvRegistry {
            alloc: BlockAllocator::new(total_blocks, block_size),
            tables: Default::default(),
        }
    }

    pub fn live_requests(&self) -> usize {
        self.tables.len()
    }

    /// Admit a request with `prompt_tokens` already cached (decode handoff).
    pub fn admit(&mut self, req: u64, prompt_tokens: usize) -> Result<(), AllocError> {
        debug_assert!(!self.tables.contains_key(&req), "request {req} re-admitted");
        let mut t = BlockTable::default();
        t.grow_to(prompt_tokens, &mut self.alloc)?;
        self.tables.insert(req, t);
        Ok(())
    }

    /// Append one generated token's KV slot for `req`.
    pub fn append(&mut self, req: u64) -> Result<(), AllocError> {
        let t = self.tables.get_mut(&req).expect("unknown request");
        t.append(&mut self.alloc)
    }

    pub fn len_tokens(&self, req: u64) -> Option<usize> {
        self.tables.get(&req).map(|t| t.len_tokens())
    }

    /// Evict (complete/abort) a request, freeing its blocks.
    pub fn evict(&mut self, req: u64) {
        if let Some(mut t) = self.tables.remove(&req) {
            t.free(&mut self.alloc);
        }
    }

    /// Would admitting `prompt_tokens` more tokens fit right now?
    pub fn can_admit(&self, prompt_tokens: usize, headroom_tokens: usize) -> bool {
        self.alloc
            .can_alloc(self.alloc.blocks_for_tokens(prompt_tokens + headroom_tokens))
    }

    pub fn utilization(&self) -> f64 {
        self.alloc.used_blocks() as f64 / self.alloc.total_blocks() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_allocates_blocks_lazily() {
        let mut a = BlockAllocator::new(10, 4);
        let mut t = BlockTable::default();
        for i in 1..=9 {
            t.append(&mut a).unwrap();
            assert_eq!(t.len_tokens(), i);
        }
        assert_eq!(t.blocks().len(), 3); // ceil(9/4)
        assert_eq!(a.used_blocks(), 3);
    }

    #[test]
    fn locate_maps_positions() {
        let mut a = BlockAllocator::new(10, 4);
        let mut t = BlockTable::default();
        t.grow_to(10, &mut a).unwrap();
        let (b0, o0) = t.locate(0, 4).unwrap();
        let (b1, o1) = t.locate(5, 4).unwrap();
        assert_eq!(o0, 0);
        assert_eq!(o1, 1);
        assert_ne!(b0, b1);
        assert!(t.locate(10, 4).is_none());
    }

    #[test]
    fn free_returns_blocks() {
        let mut a = BlockAllocator::new(5, 8);
        let mut t = BlockTable::default();
        t.grow_to(40, &mut a).unwrap();
        assert_eq!(a.free_blocks(), 0);
        t.free(&mut a);
        assert_eq!(a.free_blocks(), 5);
        assert_eq!(t.len_tokens(), 0);
    }

    #[test]
    fn map_shared_refcounts_and_free() {
        let mut a = BlockAllocator::new(4, 4);
        let mut donor = BlockTable::default();
        donor.grow_to(8, &mut a).unwrap(); // 2 blocks
        let mut t = BlockTable::default();
        t.map_shared(&donor.blocks()[..2], 6, &mut a);
        assert_eq!(t.len_tokens(), 6);
        assert_eq!(t.blocks(), donor.blocks());
        assert_eq!(a.used_blocks(), 2, "sharing allocates nothing");
        // donor goes away first: blocks stay live for the sharer
        donor.free(&mut a);
        assert_eq!(a.used_blocks(), 2);
        let (b, o) = t.locate(5, 4).unwrap();
        assert_eq!((b, o), (t.blocks()[1], 1));
        t.free(&mut a);
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    fn replace_block_swaps_chain_entry() {
        let mut a = BlockAllocator::new(4, 4);
        let mut t = BlockTable::default();
        t.grow_to(8, &mut a).unwrap();
        let fresh = a.alloc().unwrap();
        let old = t.replace_block(1, fresh);
        assert_eq!(t.blocks()[1], fresh);
        assert_ne!(old, fresh);
        assert_eq!(t.len_tokens(), 8, "length untouched by the swap");
    }

    #[test]
    fn registry_admit_append_evict() {
        let mut r = KvRegistry::new(8, 4);
        r.admit(1, 10).unwrap(); // 3 blocks
        r.admit(2, 4).unwrap(); // 1 block
        assert_eq!(r.live_requests(), 2);
        assert_eq!(r.len_tokens(1), Some(10));
        for _ in 0..2 {
            r.append(1).unwrap();
        }
        assert_eq!(r.len_tokens(1), Some(12)); // still 3 blocks
        r.evict(1);
        assert_eq!(r.live_requests(), 1);
        assert_eq!(r.alloc.free_blocks(), 7);
    }

    #[test]
    fn admit_over_capacity_fails_cleanly() {
        let mut r = KvRegistry::new(4, 4);
        r.admit(1, 12).unwrap(); // 3 blocks
        assert!(r.admit(2, 8).is_err()); // needs 2, only 1 free
        assert_eq!(r.live_requests(), 1);
        assert_eq!(r.alloc.free_blocks(), 1);
    }

    #[test]
    fn can_admit_respects_headroom() {
        let r = KvRegistry::new(4, 4);
        assert!(r.can_admit(12, 4)); // 4 blocks
        assert!(!r.can_admit(13, 4)); // 5 blocks
    }

    #[test]
    fn utilization_tracks() {
        let mut r = KvRegistry::new(10, 4);
        assert_eq!(r.utilization(), 0.0);
        r.admit(1, 20).unwrap();
        assert_eq!(r.utilization(), 0.5);
    }
}
