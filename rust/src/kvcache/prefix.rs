//! Leader-side prefix index: a trie over prompt tokens at **block
//! granularity** that finds reusable KV prefixes at admission time.
//!
//! Prefix caching is the standard capacity multiplier of modern serving
//! stacks (vLLM/SGLang lineage): fleets share system prompts, so the first
//! N·block_size prompt tokens of a new request often already sit, fully
//! prefilled, in another live request's paged KV. The index maps
//! `prompt tokens → (sharable tokens, donor request)`; the leader then
//! sends one `MapBlocks` message per worker instead of re-prefilling those
//! tokens, and every worker's arena refcounts the donor's blocks into the
//! new slot ([`super::arena::PagedKvArena::map_prefix`]).
//!
//! Design points:
//!
//! * **Block-granular keys.** Trie edges are exact `block_size`-token
//!   chunks — KV can only be shared in whole blocks (a partial tail block
//!   would put donor and sharer writes in the same physical block).
//! * **Holders, not blocks.** Each node records the *live requests* whose
//!   registered prompt passes through it. The leader resolves a donor id
//!   to that request's current slot; no physical block ids live here (they
//!   differ per worker). A request is registered only once its prefill
//!   completed (KV durable) and removed on finish/cancel/preempt, so a
//!   donor's blocks are always resident when a hit is returned.
//! * **Always leave ≥ 1 token to prefill.** A hit is capped at
//!   `floor((prompt_len − 1) / block_size)` blocks: the decode path needs
//!   at least one real prefill token to produce the first logits, and the
//!   cap keeps a full-prompt hit from degenerating into an empty chunk.
//!
//! The index is advisory: a miss (or a disabled index) leaves the
//! admission path bit-identical to a build without it.

use std::collections::{BTreeMap, BTreeSet, HashMap};

/// A successful prefix lookup: `tokens` sharable tokens (a multiple of
/// `block_size`) held by live request `donor`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    pub tokens: usize,
    pub donor: u64,
}

#[derive(Debug, Default)]
struct Node {
    /// Edges are exact block_size-token chunks. BTreeMap for deterministic
    /// iteration (stable donor choice across runs).
    children: BTreeMap<Box<[i32]>, Node>,
    /// Live requests whose registered prefix passes through this node.
    holders: BTreeSet<u64>,
}

impl Node {
    fn is_empty(&self) -> bool {
        self.children.is_empty() && self.holders.is_empty()
    }
}

/// Trie over registered prompt prefixes at block granularity.
#[derive(Debug)]
pub struct PrefixIndex {
    block_size: usize,
    root: Node,
    /// id → the block-aligned token prefix it registered (walked again on
    /// removal).
    paths: HashMap<u64, Vec<i32>>,
}

impl PrefixIndex {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0);
        PrefixIndex { block_size, root: Node::default(), paths: HashMap::new() }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Requests currently registered as potential donors.
    pub fn registered(&self) -> usize {
        self.paths.len()
    }

    /// Longest registered prefix of `prompt`, capped at `max_blocks` blocks
    /// and always at least one token short of the full prompt. Returns the
    /// sharable token count and a deterministic donor (smallest live id
    /// holding the deepest matched node).
    pub fn lookup(&self, prompt: &[i32], max_blocks: usize) -> Option<PrefixHit> {
        let bs = self.block_size;
        let cap = max_blocks.min(prompt.len().saturating_sub(1) / bs);
        let mut node = &self.root;
        let mut best: Option<PrefixHit> = None;
        for (depth, chunk) in prompt.chunks_exact(bs).take(cap).enumerate() {
            match node.children.get(chunk) {
                Some(child) => {
                    if let Some(&donor) = child.holders.first() {
                        best = Some(PrefixHit { tokens: (depth + 1) * bs, donor });
                    }
                    node = child;
                }
                None => break,
            }
        }
        best
    }

    /// Register `id` as holding durable KV for `prompt` (call once its
    /// prefill has completed). Only whole blocks are indexed; prompts
    /// shorter than one block register nothing.
    pub fn insert(&mut self, id: u64, prompt: &[i32]) {
        debug_assert!(!self.paths.contains_key(&id), "request {id} registered twice");
        let bs = self.block_size;
        let aligned = prompt.len() / bs * bs;
        if aligned == 0 {
            return;
        }
        let mut node = &mut self.root;
        for chunk in prompt[..aligned].chunks_exact(bs) {
            node = node.children.entry(chunk.into()).or_default();
            node.holders.insert(id);
        }
        self.paths.insert(id, prompt[..aligned].to_vec());
    }

    /// Drop `id` from every node on its registered path (finish, cancel or
    /// preempt — its KV is no longer guaranteed resident). Unknown ids are
    /// a no-op, so callers can remove unconditionally.
    pub fn remove(&mut self, id: u64) {
        let Some(path) = self.paths.remove(&id) else {
            return;
        };
        fn walk(node: &mut Node, chunks: &mut std::slice::ChunksExact<i32>, id: u64) {
            let Some(chunk) = chunks.next() else {
                return;
            };
            if let Some(child) = node.children.get_mut(chunk) {
                child.holders.remove(&id);
                walk(child, chunks, id);
                if child.is_empty() {
                    node.children.remove(chunk);
                }
            }
        }
        walk(&mut self.root, &mut path.chunks_exact(self.block_size), id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(chunks: &[&[i32]]) -> Vec<i32> {
        chunks.concat()
    }

    #[test]
    fn miss_on_empty_index_and_short_prompts() {
        let mut ix = PrefixIndex::new(4);
        assert_eq!(ix.lookup(&[1, 2, 3, 4, 5], usize::MAX), None);
        // sub-block prompts register nothing
        ix.insert(1, &[1, 2, 3]);
        assert_eq!(ix.registered(), 0);
        assert_eq!(ix.lookup(&[1, 2, 3, 4, 5], usize::MAX), None);
    }

    #[test]
    fn hit_is_block_aligned_and_longest_match() {
        let mut ix = PrefixIndex::new(4);
        let sys: &[i32] = &[9, 9, 9, 9, 8, 8, 8, 8];
        ix.insert(7, &prompt(&[sys, &[1, 2, 3]])); // registers 2 blocks
        // same 2 shared blocks, different suffix
        let q = prompt(&[sys, &[4, 5, 6]]);
        assert_eq!(ix.lookup(&q, usize::MAX), Some(PrefixHit { tokens: 8, donor: 7 }));
        // only the first block matches
        let q = prompt(&[&sys[..4], &[0, 0, 0, 0, 1]]);
        assert_eq!(ix.lookup(&q, usize::MAX), Some(PrefixHit { tokens: 4, donor: 7 }));
        // divergence inside the first block: miss
        assert_eq!(ix.lookup(&[9, 9, 9, 1, 2, 2, 2, 2, 3], usize::MAX), None);
    }

    #[test]
    fn hit_never_covers_the_whole_prompt() {
        let mut ix = PrefixIndex::new(4);
        ix.insert(1, &[5, 5, 5, 5, 6, 6, 6, 6]);
        // identical prompt: cap leaves the last block to prefill
        let hit = ix.lookup(&[5, 5, 5, 5, 6, 6, 6, 6], usize::MAX).unwrap();
        assert_eq!(hit.tokens, 4, "≥1 token must remain for prefill");
        // block-aligned-plus-one can take both blocks
        let hit = ix.lookup(&[5, 5, 5, 5, 6, 6, 6, 6, 7], usize::MAX).unwrap();
        assert_eq!(hit.tokens, 8);
        // caller's block cap also binds
        let hit = ix.lookup(&[5, 5, 5, 5, 6, 6, 6, 6, 7], 1).unwrap();
        assert_eq!(hit.tokens, 4);
    }

    #[test]
    fn donor_is_smallest_live_holder_and_repoints_on_removal() {
        let mut ix = PrefixIndex::new(2);
        let p: &[i32] = &[1, 1, 2, 2, 3];
        ix.insert(20, p);
        ix.insert(10, p);
        assert_eq!(ix.lookup(p, usize::MAX), Some(PrefixHit { tokens: 4, donor: 10 }));
        ix.remove(10);
        assert_eq!(ix.lookup(p, usize::MAX), Some(PrefixHit { tokens: 4, donor: 20 }));
        ix.remove(20);
        assert_eq!(ix.lookup(p, usize::MAX), None);
        assert_eq!(ix.registered(), 0);
        ix.remove(20); // unknown id: no-op
    }

    #[test]
    fn removal_prunes_only_unshared_nodes() {
        let mut ix = PrefixIndex::new(2);
        ix.insert(1, &[7, 7, 1, 1, 0]); // [7,7] → [1,1]
        ix.insert(2, &[7, 7, 2, 2, 0]); // [7,7] → [2,2]
        ix.remove(1);
        // the shared first block survives via request 2
        assert_eq!(ix.lookup(&[7, 7, 9], usize::MAX), Some(PrefixHit { tokens: 2, donor: 2 }));
        // request 1's private branch is gone
        assert_eq!(ix.lookup(&[7, 7, 1, 1, 9], usize::MAX).unwrap().tokens, 2);
        ix.remove(2);
        assert!(ix.root.is_empty(), "empty index leaves no nodes behind");
    }

    #[test]
    fn deep_match_requires_holder_on_the_deep_node() {
        let mut ix = PrefixIndex::new(2);
        ix.insert(1, &[4, 4, 5, 5]); // holders at depth 1 and 2
        ix.remove(1);
        ix.insert(2, &[4, 4]); // holder at depth 1 only
        let hit = ix.lookup(&[4, 4, 5, 5, 6], usize::MAX).unwrap();
        assert_eq!((hit.tokens, hit.donor), (2, 2), "depth-2 node has no live holder");
    }
}
