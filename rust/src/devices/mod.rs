//! Device specifications and the roofline operator cost model — the
//! quantitative substrate behind the paper's §2 analysis and the large-model
//! performance simulation (the real H100/H20 testbed is hardware we do not
//! have; see DESIGN.md §2).

pub mod roofline;
pub mod specs;

pub use roofline::{atime, mtime, OpCost};
pub use specs::{DeviceSpec, LlmSpec, H100, H20, LLAMA3_70B, LLAMA_33B, LLAMA_65B, TPU_V6E};
