//! Accelerator device specifications (paper Table 1) and LLM architecture
//! specs (paper Tables 2 & 3).
//!
//! These parameterise the roofline cost model in [`super::roofline`]; the
//! reproduction's performance figures derive from *these numbers*, exactly
//! as the paper's own §2/§3.1 analysis does.

/// One accelerator model.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Dense BF16 TFLOPs (peak).
    pub bf16_tflops: f64,
    /// HBM capacity in GiB.
    pub mem_gib: f64,
    /// HBM bandwidth in TB/s (decimal).
    pub mem_bw_tbs: f64,
    /// Board power rating in W (0 = unlisted).
    pub power_w: f64,
    /// Inter-chip interconnect bandwidth in GB/s (NVLink/ICI), per device.
    pub ici_gbs: f64,
    /// Data-center network bandwidth in Gbps, per device NIC.
    pub net_gbps: f64,
    /// Cloud price per chip-hour in USD (paper Table 1).
    pub price_hr: f64,
    /// Fraction of peak FLOPs achievable on large GEMMs.
    pub gemm_eff: f64,
    /// Fraction of peak HBM bandwidth achievable on streaming reads.
    pub bw_eff: f64,
}

impl DeviceSpec {
    pub fn peak_flops(&self) -> f64 {
        self.bf16_tflops * 1e12
    }

    pub fn eff_flops(&self) -> f64 {
        self.peak_flops() * self.gemm_eff
    }

    pub fn peak_bw(&self) -> f64 {
        self.mem_bw_tbs * 1e12
    }

    pub fn eff_bw(&self) -> f64 {
        self.peak_bw() * self.bw_eff
    }

    pub fn mem_bytes(&self) -> f64 {
        self.mem_gib * 1024.0 * 1024.0 * 1024.0
    }

    /// Device-level "ops:bytes" balance point (arithmetic intensity at the
    /// roofline ridge). H100 ≈ 295, H20 ≈ 37 — the disparity the paper
    /// exploits.
    pub fn ridge_intensity(&self) -> f64 {
        self.peak_flops() / self.peak_bw()
    }
}

/// NVIDIA H100 SXM (paper Table 1).
pub const H100: DeviceSpec = DeviceSpec {
    name: "H100",
    bf16_tflops: 989.0,
    mem_gib: 80.0,
    mem_bw_tbs: 3.35,
    power_w: 700.0,
    ici_gbs: 450.0,
    net_gbps: 400.0,
    price_hr: 11.06,
    gemm_eff: 0.65,
    bw_eff: 0.88,
};

/// NVIDIA H20 (memory-optimised; paper Table 1).
pub const H20: DeviceSpec = DeviceSpec {
    name: "H20",
    bf16_tflops: 148.0,
    mem_gib: 96.0,
    mem_bw_tbs: 4.0,
    power_w: 400.0,
    ici_gbs: 450.0,
    net_gbps: 400.0,
    price_hr: 4.63,
    gemm_eff: 0.65,
    bw_eff: 0.88,
};

/// Google TPU v6e (compute-optimised comparison point; paper Table 1).
pub const TPU_V6E: DeviceSpec = DeviceSpec {
    name: "TPUv6e",
    bf16_tflops: 918.0,
    mem_gib: 32.0,
    mem_bw_tbs: 1.64,
    power_w: 0.0,
    ici_gbs: 448.0,
    net_gbps: 200.0,
    price_hr: 2.70,
    gemm_eff: 0.65,
    bw_eff: 0.88,
};

pub const ALL_DEVICES: &[&DeviceSpec] = &[&H100, &H20, &TPU_V6E];

pub fn device_by_name(name: &str) -> Option<&'static DeviceSpec> {
    ALL_DEVICES
        .iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
        .copied()
}

/// Analytical LLM architecture (paper Tables 2 & 3 notation).
#[derive(Debug, Clone, PartialEq)]
pub struct LlmSpec {
    pub name: &'static str,
    /// Total parameter count N.
    pub n_params: f64,
    /// Hidden dimension d.
    pub d: usize,
    /// Layer count L.
    pub layers: usize,
    /// GQA group size G (1 = plain MHA).
    pub gqa_group: usize,
    /// Bytes per element e (2 = FP16).
    pub elem_bytes: f64,
}

impl LlmSpec {
    /// Model weight footprint in bytes.
    pub fn param_bytes(&self) -> f64 {
        self.n_params * self.elem_bytes
    }

    /// KV-cache bytes per token across all layers: 2·e·d·L/G.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.elem_bytes * self.d as f64 * self.layers as f64 / self.gqa_group as f64
    }

    /// Bytes crossing the model↔attention boundary per token per layer:
    /// q (e·d) + k,v (2·e·d/G) out, attention output (e·d) back —
    /// the paper's (2 + 2/G)·e·d term (§3.1).
    pub fn boundary_bytes_per_token_layer(&self) -> f64 {
        (2.0 + 2.0 / self.gqa_group as f64) * self.elem_bytes * self.d as f64
    }
}

/// LLaMA-33B (Table 3: 64.7 GB FP16, L=60, d=6656, G=1).
pub const LLAMA_33B: LlmSpec = LlmSpec {
    name: "LLaMA-33B",
    n_params: 32.35e9,
    d: 6656,
    layers: 60,
    gqa_group: 1,
    elem_bytes: 2.0,
};

/// LLaMA-65B (Table 3: 130.1 GB FP16, L=80, d=8192, G=1).
pub const LLAMA_65B: LlmSpec = LlmSpec {
    name: "LLaMA-65B",
    n_params: 65.05e9,
    d: 8192,
    layers: 80,
    gqa_group: 1,
    elem_bytes: 2.0,
};

/// LLaMA3-70B (Table 3: 137.5 GB FP16, L=80, d=8192, G=8).
pub const LLAMA3_70B: LlmSpec = LlmSpec {
    name: "LLaMA3-70B",
    n_params: 68.75e9,
    d: 8192,
    layers: 80,
    gqa_group: 8,
    elem_bytes: 2.0,
};

pub const ALL_MODELS: &[&LlmSpec] = &[&LLAMA_33B, &LLAMA_65B, &LLAMA3_70B];

pub fn model_by_name(name: &str) -> Option<&'static LlmSpec> {
    ALL_MODELS
        .iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_numbers() {
        assert_eq!(H100.bf16_tflops, 989.0);
        assert_eq!(H20.mem_bw_tbs, 4.0);
        assert_eq!(TPU_V6E.price_hr, 2.70);
    }

    #[test]
    fn ridge_disparity() {
        // H100 is compute-rich (high ridge), H20 is bandwidth-rich (low).
        assert!(H100.ridge_intensity() > 250.0);
        assert!(H20.ridge_intensity() < 50.0);
        assert!(H100.ridge_intensity() / H20.ridge_intensity() > 5.0);
    }

    #[test]
    fn table3_param_bytes() {
        // Table 3 gives FP16 footprints: 64.7, 130.1, 137.5 GB.
        assert!((LLAMA_33B.param_bytes() / 1e9 - 64.7).abs() < 0.5);
        assert!((LLAMA_65B.param_bytes() / 1e9 - 130.1).abs() < 0.5);
        assert!((LLAMA3_70B.param_bytes() / 1e9 - 137.5).abs() < 0.5);
    }

    #[test]
    fn kv_bytes_gqa_factor() {
        // GQA (G=8) shrinks per-token KV 8× vs MHA at same d, L.
        let kv_mha = LLAMA_65B.kv_bytes_per_token();
        let kv_gqa = LLAMA3_70B.kv_bytes_per_token();
        assert!((kv_mha / kv_gqa - 8.0).abs() < 1e-9);
        // LLaMA3-70B: 2·2·8192·80/8 = 327 680 bytes/token.
        assert!((kv_gqa - 327_680.0).abs() < 1.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(device_by_name("h100").unwrap().name, "H100");
        assert_eq!(model_by_name("llama3-70b").unwrap().layers, 80);
        assert!(device_by_name("B200").is_none());
    }

    #[test]
    fn boundary_bytes() {
        // G=1 → 4·e·d; G=8 → 2.25·e·d.
        let b1 = LLAMA_65B.boundary_bytes_per_token_layer();
        assert!((b1 - 4.0 * 2.0 * 8192.0).abs() < 1e-9);
        let b8 = LLAMA3_70B.boundary_bytes_per_token_layer();
        assert!((b8 - 2.25 * 2.0 * 8192.0).abs() < 1e-9);
    }
}
