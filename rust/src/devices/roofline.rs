//! Roofline operator cost model (paper §2, Figs. 2–4).
//!
//! Decode-phase iteration time decomposes into the two operator families the
//! paper analyses:
//!
//! * **non-attention** (QKVO projections + FFN): GEMMs over shared weights —
//!   `MTIME(B) = max(2NB / F_eff, eN / BW_eff) + overheads`, compute-bound
//!   for large B, bandwidth-bound (parameter loads) for small B;
//! * **attention**: batched GEMV over per-request KV caches —
//!   `ATIME(B, l) = max(4Bld·L / F, 2eBldL/G / BW)`, memory-bound at every
//!   batch size (arithmetic intensity is constant ≈ 2G/e).
//!
//! Tensor-parallel execution divides both FLOPs and bytes across `tp` ranks
//! and adds two ring all-reduces per layer over the ICI.

use super::specs::{DeviceSpec, LlmSpec};

/// Fixed per-kernel launch/dispatch overhead folded into each measured
/// operator family (one fused region per layer in practice).
pub const KERNEL_OVERHEAD_S: f64 = 4e-6;

/// Cost-model outputs for one operator family at one operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Wall-clock seconds for one decode iteration.
    pub time_s: f64,
    /// Model FLOPs utilisation (fraction of peak).
    pub mfu: f64,
    /// Model bandwidth utilisation (fraction of peak).
    pub mbu: f64,
    /// FLOPs performed.
    pub flops: f64,
    /// HBM bytes moved.
    pub bytes: f64,
}

/// Time for one ring all-reduce of `bytes` over `tp` ranks via ICI.
pub fn allreduce_time(dev: &DeviceSpec, tp: usize, bytes: f64) -> f64 {
    if tp <= 1 {
        return 0.0;
    }
    // Ring all-reduce moves 2·(tp-1)/tp · bytes per rank over the ICI link.
    let wire = 2.0 * (tp as f64 - 1.0) / tp as f64 * bytes / (dev.ici_gbs * 1e9);
    wire + 5e-6 // launch + sync latency per collective
}

/// Non-attention (model-part) cost for one decode iteration of the full
/// model at batch size `B` on `tp`-way tensor parallelism.
pub fn mtime(model: &LlmSpec, dev: &DeviceSpec, batch: usize, tp: usize) -> OpCost {
    assert!(batch > 0 && tp > 0);
    let b = batch as f64;
    let n = model.n_params;
    let e = model.elem_bytes;
    let d = model.d as f64;
    let l = model.layers as f64;

    let flops = 2.0 * n * b;
    // weight loads + activation read/write per layer
    let bytes = e * n + 2.0 * e * b * d * l;
    let t_compute = flops / (tp as f64 * dev.eff_flops());
    let t_memory = bytes / (tp as f64 * dev.eff_bw());
    // Two all-reduces per layer (attention out-proj + FFN down-proj).
    let t_coll = 2.0 * l * allreduce_time(dev, tp, e * b * d);
    let time = t_compute.max(t_memory) + t_coll + l * KERNEL_OVERHEAD_S;

    OpCost {
        time_s: time,
        mfu: flops / (time * tp as f64 * dev.peak_flops()),
        mbu: bytes / (time * tp as f64 * dev.peak_bw()),
        flops,
        bytes,
    }
}

/// Attention cost for one decode iteration of the full model at batch `B`,
/// uniform context length `l_ctx`, sharded over `workers` devices
/// (head-level partitioning → perfectly balanced, paper §5).
pub fn atime(
    model: &LlmSpec,
    dev: &DeviceSpec,
    batch: usize,
    l_ctx: usize,
    workers: usize,
) -> OpCost {
    assert!(batch > 0 && workers > 0);
    let b = batch as f64;
    let lc = l_ctx as f64;
    let e = model.elem_bytes;
    let d = model.d as f64;
    let nl = model.layers as f64;
    let g = model.gqa_group as f64;

    // Per layer: QK^T + PV over H heads of dim hd: 4·B·l·d FLOPs.
    let flops = 4.0 * b * lc * d * nl;
    // KV reads dominate: 2·e·B·l·d/G per layer (+ q/out negligible).
    let bytes = 2.0 * e * b * lc * d / g * nl;
    let w = workers as f64;
    let t_compute = flops / (w * dev.eff_flops());
    let t_memory = bytes / (w * dev.eff_bw());
    let time = t_compute.max(t_memory) + nl * KERNEL_OVERHEAD_S;

    OpCost {
        time_s: time,
        mfu: flops / (time * w * dev.peak_flops()),
        mbu: bytes / (time * w * dev.peak_bw()),
        flops,
        bytes,
    }
}

/// Attention cost from the *aggregate* context-token count of a continuous
/// batch (ragged lengths): equivalent to [`atime`] with `B·l = total_tokens`.
/// This is what the serving simulators use, since contexts differ per
/// request.
pub fn atime_tokens(
    model: &LlmSpec,
    dev: &DeviceSpec,
    total_ctx_tokens: f64,
    workers: usize,
) -> OpCost {
    assert!(workers > 0);
    let e = model.elem_bytes;
    let d = model.d as f64;
    let nl = model.layers as f64;
    let g = model.gqa_group as f64;

    let flops = 4.0 * total_ctx_tokens * d * nl;
    let bytes = 2.0 * e * total_ctx_tokens * d / g * nl;
    let w = workers as f64;
    let t_compute = flops / (w * dev.eff_flops());
    let t_memory = bytes / (w * dev.eff_bw());
    let time = t_compute.max(t_memory) + nl * KERNEL_OVERHEAD_S;

    OpCost {
        time_s: time,
        mfu: flops / (time * w * dev.peak_flops()),
        mbu: bytes / (time * w * dev.peak_bw()),
        flops,
        bytes,
    }
}

/// Pure roofline projection (no overheads/collectives) — the dotted lines in
/// Fig. 2.
pub fn mtime_roofline(model: &LlmSpec, dev: &DeviceSpec, batch: usize, tp: usize) -> f64 {
    let b = batch as f64;
    let flops = 2.0 * model.n_params * b;
    let bytes = model.elem_bytes * model.n_params;
    (flops / (tp as f64 * dev.eff_flops())).max(bytes / (tp as f64 * dev.eff_bw()))
}

/// Batch size at which non-attention work transitions bandwidth→compute
/// bound (the roofline ridge of Fig. 2).
pub fn mtime_crossover_batch(model: &LlmSpec, dev: &DeviceSpec) -> f64 {
    model.elem_bytes * dev.eff_flops() / (2.0 * dev.eff_bw())
}

/// Maximum decode batch size on a homogeneous pool: KV caches must fit in
/// what the weights leave free (paper §2.2.2). `mem_util` discounts for
/// activations/fragmentation (vLLM defaults to 0.9).
pub fn max_batch_homogeneous(
    model: &LlmSpec,
    dev: &DeviceSpec,
    devices: usize,
    ctx_len: usize,
    mem_util: f64,
) -> usize {
    let total = dev.mem_bytes() * devices as f64 * mem_util;
    let free = total - model.param_bytes();
    if free <= 0.0 {
        return 0;
    }
    (free / (model.kv_bytes_per_token() * ctx_len as f64)).floor() as usize
}

/// Maximum decode batch size for the disaggregated setup: all attention-pool
/// memory is KV (weights live on the model pool).
pub fn max_batch_disaggregated(
    model: &LlmSpec,
    attn_dev: &DeviceSpec,
    attn_devices: usize,
    ctx_len: usize,
    mem_util: f64,
) -> usize {
    let total = attn_dev.mem_bytes() * attn_devices as f64 * mem_util;
    (total / (model.kv_bytes_per_token() * ctx_len as f64)).floor() as usize
}

/// Fig. 4: minimum interconnect bandwidth (bytes/s) so that network overhead
/// stays within `alpha` of compute time:
/// `(2 + 2/G)·e·d·B·L / (alpha · (MTIME + ATIME))`.
pub fn min_interconnect_bw(
    model: &LlmSpec,
    model_dev: &DeviceSpec,
    attn_dev: &DeviceSpec,
    batch: usize,
    l_ctx: usize,
    alpha: f64,
    dop: (usize, usize),
) -> f64 {
    let bytes = model.boundary_bytes_per_token_layer() * batch as f64 * model.layers as f64;
    let mt = mtime(model, model_dev, batch, dop.0).time_s;
    let at = atime(model, attn_dev, batch, l_ctx, dop.1).time_s;
    bytes / (alpha * (mt + at))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::specs::{H100, H20, LLAMA3_70B, LLAMA_65B};

    #[test]
    fn mtime_bandwidth_bound_small_batch() {
        // Fig. 2: small batches are bandwidth-bound with MFU < 20 %.
        let c = mtime(&LLAMA3_70B, &H100, 8, 4);
        assert!(c.mfu < 0.20, "mfu={}", c.mfu);
        assert!(c.mbu > 0.5, "mbu={}", c.mbu);
    }

    #[test]
    fn mtime_compute_bound_large_batch() {
        let c = mtime(&LLAMA3_70B, &H100, 1024, 4);
        assert!(c.mfu > 0.4, "mfu={}", c.mfu);
    }

    #[test]
    fn mtime_monotone_in_batch() {
        let mut prev = 0.0;
        for b in [1, 16, 64, 256, 1024] {
            let t = mtime(&LLAMA3_70B, &H100, b, 4).time_s;
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn mtime_flat_then_linear() {
        // Bandwidth-bound region: latency ~constant vs batch.
        let t1 = mtime(&LLAMA3_70B, &H100, 1, 4).time_s;
        let t64 = mtime(&LLAMA3_70B, &H100, 64, 4).time_s;
        assert!(t64 / t1 < 1.3, "bandwidth-bound region should be flat");
        // Compute-bound region: ~linear.
        let t512 = mtime(&LLAMA3_70B, &H100, 512, 4).time_s;
        let t1024 = mtime(&LLAMA3_70B, &H100, 1024, 4).time_s;
        assert!(t1024 / t512 > 1.7, "compute-bound region should scale");
    }

    #[test]
    fn crossover_near_200() {
        let x = mtime_crossover_batch(&LLAMA3_70B, &H100);
        assert!(x > 100.0 && x < 350.0, "crossover={x}");
    }

    #[test]
    fn atime_memory_bound_high_mbu() {
        // Fig. 3: MBU > 70 % already at batch 20, on both devices. MFU stays
        // low — H20 reaches a few × higher MFU than H100 only because its
        // compute peak is 6.7× smaller (the paper's cost argument).
        for dev in [&H100, &H20] {
            let c = atime(&LLAMA3_70B, dev, 20, 8192, 1);
            assert!(c.mbu > 0.70, "{}: mbu={}", dev.name, c.mbu);
            assert!(c.mfu < 0.25, "{}: mfu={}", dev.name, c.mfu);
        }
        assert!(atime(&LLAMA3_70B, &H100, 20, 8192, 1).mfu < 0.05);
    }

    #[test]
    fn atime_linear_in_batch_and_ctx() {
        let a = atime(&LLAMA_65B, &H20, 10, 4096, 1).time_s;
        let b = atime(&LLAMA_65B, &H20, 20, 4096, 1).time_s;
        let c = atime(&LLAMA_65B, &H20, 10, 8192, 1).time_s;
        assert!((b / a - 2.0).abs() < 0.1);
        assert!((c / a - 2.0).abs() < 0.1);
    }

    #[test]
    fn atime_scales_with_workers() {
        let one = atime(&LLAMA3_70B, &H20, 100, 8192, 1).time_s;
        let four = atime(&LLAMA3_70B, &H20, 100, 8192, 4).time_s;
        assert!(one / four > 3.0);
    }

    #[test]
    fn h20_beats_h100_at_attention_per_dollar() {
        // The whole premise: attention throughput/$ favours H20.
        let t100 = atime(&LLAMA3_70B, &H100, 64, 8192, 1).time_s;
        let t20 = atime(&LLAMA3_70B, &H20, 64, 8192, 1).time_s;
        let perf_per_dollar_100 = 1.0 / (t100 * H100.price_hr);
        let perf_per_dollar_20 = 1.0 / (t20 * H20.price_hr);
        assert!(perf_per_dollar_20 > 1.5 * perf_per_dollar_100);
    }

    #[test]
    fn max_batch_h100_8k_ctx_about_30() {
        // Paper §2.2.2: one H100's memory holds KV for ~30 requests at 8192
        // ctx (ignoring weights). Use weights-free capacity to match text.
        let b = max_batch_disaggregated(&LLAMA3_70B, &H100, 1, 8192, 1.0);
        assert!((25..=35).contains(&b), "b={b}");
    }

    #[test]
    fn disaggregation_unlocks_batch() {
        // Table 5 config: vLLM 4×H100 vs Lamina DOP=(2,4) H100+H20.
        let homo = max_batch_homogeneous(&LLAMA3_70B, &H100, 4, 4096, 0.9);
        let dis = max_batch_disaggregated(&LLAMA3_70B, &H20, 4, 4096, 0.9);
        assert!(dis as f64 / homo as f64 > 1.8, "homo={homo} dis={dis}");
    }

    #[test]
    fn fig4_bandwidth_under_30gbs() {
        // Fig. 4: required bandwidth stays < 30 GB/s up to B=300 (α=0.2).
        // The paper's figure is a per-device feasibility analysis (one H100
        // against one H20), matching each GPU's dedicated 400 Gbps NIC.
        for b in [10, 50, 100, 200, 300] {
            let bw = min_interconnect_bw(&LLAMA3_70B, &H100, &H20, b, 4096, 0.2, (1, 1));
            assert!(bw < 30e9, "B={b}: bw={:.1} GB/s", bw / 1e9);
        }
    }

    #[test]
    fn fig4_within_400gbe() {
        let bw = min_interconnect_bw(&LLAMA_65B, &H100, &H20, 200, 4096, 0.2, (2, 4));
        assert!(bw < 50e9, "400GbE = 50 GB/s must suffice, got {}", bw / 1e9);
    }

    #[test]
    fn allreduce_zero_for_tp1() {
        assert_eq!(allreduce_time(&H100, 1, 1e6), 0.0);
        assert!(allreduce_time(&H100, 4, 1e6) > 0.0);
    }
}
