//! Request-lifecycle scheduler: the control plane of the continuous-
//! batching engine (Orca-style iteration-level scheduling over the paged
//! KV arena, replacing the wave-bound `serve` surface).
//!
//! The scheduler owns everything the *caller* used to own under the old
//! API — physical cache slots, admission, and step composition — and
//! nothing the *workers* own (KV blocks live in the arenas; the leader
//! relays `Retire` messages when the scheduler retires a request). It is
//! pure bookkeeping: no engine, no transport, no tensors. The leader's
//! `step()` asks it what to run (admissions, one prefill chunk, or the
//! decode batch), executes that against the model, and feeds the results
//! back through `note_*` calls. That split keeps the whole lifecycle —
//! admission order, teacher forcing, slot recycling, KV reservations,
//! starvation behavior — property-testable without PJRT artifacts
//! (`tests/scheduler.rs`).
//!
//! Lifecycle (see [`state`] for the state machine):
//!
//! * `submit` validates per request (typed [`SubmitError`]) and queues it.
//! * `admit` pulls from the waiting queue in [`AdmissionPolicy`] order,
//!   assigns a physical slot from the free pool, and reserves the
//!   request's KV footprint against the budget ([`KvBudget`] in blocks or
//!   **bytes** — bytes are the right unit when workers store quantized
//!   blocks). The old escape hatch survives: with no live request,
//!   admission proceeds regardless of the budget (deferring could never
//!   free blocks).
//! * `decode_plan` composes the iteration's batch groups:
//!   [`GroupMode::Packed`] repacks the running set at iteration
//!   granularity (continuous batching); [`GroupMode::ByWave`] reproduces
//!   the legacy wave partitioning (slot-range groups) and survives only
//!   for the wave driver loop and its comparison benches.
//! * `note_decode` / `note_prefill_chunk` apply results; a finished
//!   request releases its slot and reservation immediately and lands in
//!   the retirement queue the leader drains into `Retire` wire messages.
//!
//! # Overcommit (`SchedCfg::overcommit`)
//!
//! The default reservation is **full context** (prompt + generation
//! target): admission can never over-subscribe the arena, but short-lived
//! requests strand headroom they will never touch. With `overcommit` on,
//! admission reserves only the *prompt* footprint and the reservation then
//! grows **block by block** as the context actually grows (`note_*`
//! feedback). The budget can now be exceeded transiently; the relief valve
//! is [`Scheduler::pressure_preempt`]: when live reservations (or the
//! measured arena occupancy) cross the budget, a victim picked by
//! [`AdmissionPolicy::pick_victim`] (default: last admitted) is preempted —
//! its KV is retired through the normal `Retire` path, its generated
//! tokens ride along as a *replay* suffix, and it re-enters the waiting
//! queue at the **front**. On re-admission it re-prefills prompt + replay
//! and keeps decoding; greedy decode is deterministic, so the final output
//! is bit-identical to an unpreempted run. The last live request is never
//! preempted (forward progress), mirroring the admission escape hatch.

pub mod policy;
pub mod state;

pub use policy::{AdmissionKind, AdmissionPolicy, Candidate, Fifo, Sjf};
pub use state::{FinishReason, RequestId, RequestState, RequestStatus, StepOutcome, SubmitError};

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::kvcache::kv_blocks_needed;

/// How the running set is composed into decode batch groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupMode {
    /// Continuous batching: the running requests are packed into groups of
    /// at most `group_slots` in admission order, repacking every iteration
    /// as requests retire. The default.
    Packed,
    /// Legacy staggered-wave partitioning: a request decodes with the wave
    /// its physical slot belongs to (`slot / group_slots`), so half-empty
    /// waves step alone. Kept for the wave driver loop and benches.
    ByWave,
}

/// KV admission budget, per attention worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvBudget {
    Unlimited,
    /// Legacy block-denominated budget (`--kv-budget-blocks`).
    Blocks(usize),
    /// Byte-denominated budget (`--kv-budget`): correct under mixed
    /// `--kv-dtype` pools, where a block's byte size differs per worker.
    Bytes(usize),
}

/// Per-worker arena occupancy the admission check consults (derived from
/// the latest merged `KvStats` snapshot by the caller).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvOccupancy {
    /// Blocks in use on one worker (pool total / workers, rounded up).
    pub blocks_in_use: usize,
    /// Bytes in use on one worker.
    pub bytes_in_use: usize,
}

/// Scheduler configuration (fixed per session).
#[derive(Debug, Clone, Copy)]
pub struct SchedCfg {
    /// Per-request context ceiling (prompt + generation target).
    pub max_context: usize,
    /// Physical cache slots this session may occupy.
    pub total_slots: usize,
    /// Decode batch-group cap (the engine's largest practical batch).
    pub group_slots: usize,
    pub grouping: GroupMode,
    /// Default path for multi-token prompts: chunked prefill (`true`) or
    /// teacher-forced decode (`false`). Overridable per request.
    pub use_prefill: bool,
    /// Token slots per KV block (the reservation quantum).
    pub kv_block_size: usize,
    /// Bytes one block occupies on ONE worker, all layers, K+V (the
    /// blocks→bytes conversion for budget accounting and reporting).
    pub block_bytes: usize,
    pub budget: KvBudget,
    /// Reserve prompt-only KV at admission and grow block-by-block, with
    /// preempt-and-requeue as the pressure valve (see module docs). Off:
    /// conservative full-context reservations, no preemption.
    pub overcommit: bool,
}

/// One decode-batch row the leader must execute.
#[derive(Debug, Clone, Copy)]
pub struct DecodeRow {
    pub id: RequestId,
    /// Physical cache slot on the attention workers.
    pub slot: u32,
    /// Cached tokens before this step.
    pub len: i32,
    /// Input token for this step.
    pub input: i32,
    /// Whether this step's output is a *generated* token (false while the
    /// row is still teacher-forcing prompt tokens) — the decode-phase
    /// token count `ServeMetrics` records.
    pub emits: bool,
}

/// The next prefill chunk to run (one per engine iteration).
#[derive(Debug, Clone, Copy)]
pub struct PrefillStep {
    pub id: RequestId,
    pub slot: u32,
    /// Prompt tokens already in the KV cache.
    pub cached: usize,
}

struct Entry {
    id: RequestId,
    prompt: Vec<i32>,
    gen_target: usize,
    use_prefill: bool,
    state: RequestState,
    slot: u32,
    /// Prompt tokens already consumed as decode inputs (teacher forcing).
    fed: usize,
    /// Cached tokens (context length so far).
    len: i32,
    next_input: i32,
    generated: Vec<i32>,
    /// Leading `generated` tokens that survived a preemption: on
    /// re-admission they are *replayed* (re-prefilled / re-teacher-forced)
    /// after the prompt, so the effective prompt is
    /// `prompt ⧺ generated[..promoted]`. Zero for never-preempted requests.
    promoted: usize,
    /// Effective-prompt tokens already prefilled into the KV cache.
    prefill_cached: usize,
    /// Current KV reservation, per worker: full context by default,
    /// prompt-only-then-grown under overcommit.
    needed_blocks: usize,
    needed_bytes: usize,
    waited_rounds: u32,
    submitted_at: Instant,
    admitted_at: Option<Instant>,
    first_token_at: Option<Instant>,
}

impl Entry {
    /// Prompt plus replayed-generation length: everything that must be in
    /// the KV cache before the request free-runs.
    fn eff_prompt_len(&self) -> usize {
        self.prompt.len() + self.promoted
    }

    /// Token at position `i` of the effective prompt.
    fn eff_prompt_at(&self, i: usize) -> i32 {
        if i < self.prompt.len() {
            self.prompt[i]
        } else {
            self.generated[i - self.prompt.len()]
        }
    }

    fn decode_row(&self) -> DecodeRow {
        DecodeRow {
            id: self.id,
            slot: self.slot,
            len: self.len,
            input: self.next_input,
            emits: self.fed >= self.eff_prompt_len(),
        }
    }
}

/// The request-lifecycle scheduler (see module docs).
pub struct Scheduler {
    cfg: SchedCfg,
    policy: Box<dyn AdmissionPolicy>,
    next_id: RequestId,
    entries: BTreeMap<RequestId, Entry>,
    /// Submission order (FIFO view handed to the policy).
    waiting: VecDeque<RequestId>,
    /// Admission order; stable while requests retire around each other.
    running: Vec<RequestId>,
    /// LIFO free pool, initialized descending so slots hand out as 0,1,2…
    free_slots: Vec<u32>,
    /// Full-context KV reservation of all live requests, per worker.
    reserved_blocks: usize,
    reserved_bytes: usize,
    /// Finished requests whose `Retire` the leader has not sent yet (only
    /// requests that materialized KV on the workers).
    retire_queue: Vec<(RequestId, u32)>,
    /// ALL finish events not yet reported to the driver — including
    /// requests that never wrote KV and therefore queue no Retire.
    finished_events: Vec<RequestId>,
    /// Admissions not yet observed by the leader (it probes these for
    /// prefix-cache hits before their first prefill chunk).
    admitted_events: Vec<RequestId>,
    deferred_total: u64,
    preempted_total: u64,
}

impl Scheduler {
    pub fn new(cfg: SchedCfg, policy: Box<dyn AdmissionPolicy>) -> Self {
        assert!(cfg.total_slots > 0, "need at least one slot");
        assert!(cfg.group_slots > 0, "need a positive group size");
        assert!(cfg.kv_block_size > 0, "need a positive block size");
        Scheduler {
            free_slots: (0..cfg.total_slots as u32).rev().collect(),
            cfg,
            policy,
            next_id: 0,
            entries: BTreeMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            reserved_blocks: 0,
            reserved_bytes: 0,
            retire_queue: Vec::new(),
            finished_events: Vec::new(),
            admitted_events: Vec::new(),
            deferred_total: 0,
            preempted_total: 0,
        }
    }

    pub fn cfg(&self) -> &SchedCfg {
        &self.cfg
    }

    /// Reshard capacity re-derivation: the per-worker byte size of one KV
    /// block changed (the pool degraded to fewer workers or adopted a new
    /// one, so each worker now holds a different KV-head range). Rebases
    /// every live reservation and the running byte totals onto the new
    /// conversion so byte-denominated budget accounting keeps matching the
    /// workers' arenas. Block counts are geometry-invariant (every worker
    /// caches a head shard of every request) and stay untouched.
    pub fn set_block_bytes(&mut self, block_bytes: usize) {
        assert!(block_bytes > 0, "need a positive block size");
        self.cfg.block_bytes = block_bytes;
        self.reserved_bytes = 0;
        for e in self.entries.values_mut() {
            e.needed_bytes = e.needed_blocks * block_bytes;
            if e.state.is_live() {
                self.reserved_bytes += e.needed_bytes;
            }
        }
    }

    /// The id the next `submit` will be assigned.
    pub fn next_request_id(&self) -> RequestId {
        self.next_id
    }

    /// Start assigning ids at `next` (monotone). Session resets use this to
    /// keep ids unique across a pipeline's lifetime, so a stale id from an
    /// earlier session polls as unknown instead of aliasing a new request.
    pub fn resume_ids_at(&mut self, next: RequestId) {
        debug_assert!(next >= self.next_id, "request ids must stay monotone");
        self.next_id = next;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    // ---- submission -------------------------------------------------------

    /// Validate and queue a request (prefill mode from [`SchedCfg`]).
    pub fn submit(&mut self, prompt: Vec<i32>, gen_target: usize) -> Result<RequestId, SubmitError> {
        let mode = self.cfg.use_prefill;
        self.submit_with_mode(prompt, gen_target, mode)
    }

    /// Validate and queue a request with an explicit prompt-processing mode
    /// (`use_prefill = false` forces teacher-forced decode — the golden
    /// `decode` semantics).
    pub fn submit_with_mode(
        &mut self,
        prompt: Vec<i32>,
        gen_target: usize,
        use_prefill: bool,
    ) -> Result<RequestId, SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        let ctx = prompt.len() + gen_target;
        if ctx > self.cfg.max_context {
            return Err(SubmitError::ContextTooLong { requested: ctx, max: self.cfg.max_context });
        }
        let id = self.next_id;
        self.next_id += 1;
        // overcommit: reserve only what prefill will certainly write; the
        // reservation grows with the context (see grow_reservation)
        let reserve_tokens = if self.cfg.overcommit { prompt.len() } else { ctx };
        let needed_blocks = kv_blocks_needed(&[reserve_tokens], self.cfg.kv_block_size);
        self.entries.insert(
            id,
            Entry {
                id,
                gen_target,
                use_prefill,
                state: RequestState::Queued,
                slot: 0,
                fed: 0,
                len: 0,
                next_input: 0,
                generated: Vec::new(),
                promoted: 0,
                prefill_cached: 0,
                needed_blocks,
                needed_bytes: needed_blocks * self.cfg.block_bytes,
                waited_rounds: 0,
                submitted_at: Instant::now(),
                admitted_at: None,
                first_token_at: None,
                prompt,
            },
        );
        self.waiting.push_back(id);
        Ok(id)
    }

    // ---- admission --------------------------------------------------------

    /// Run one admission round against the latest per-worker occupancy.
    /// Returns `(admitted, deferred)` — `deferred` is true when the KV
    /// budget blocked the policy's pick (counted once per round, as the
    /// wave loop did).
    pub fn admit(&mut self, occ: KvOccupancy) -> (usize, bool) {
        let mut admitted = 0usize;
        let mut deferred = false;
        // one candidate snapshot serves every pick of the round: costs and
        // ages are static within a round, and admissions are mirrored by
        // removing the picked entry (FIFO order preserved)
        let mut candidates: Vec<Candidate> = self
            .waiting
            .iter()
            .map(|&id| {
                let e = &self.entries[&id];
                Candidate {
                    id,
                    cost_tokens: e.prompt.len() + e.gen_target,
                    waited_rounds: e.waited_rounds,
                }
            })
            .collect();
        while !self.free_slots.is_empty() && !candidates.is_empty() {
            let Some(pick) = self.policy.pick(&candidates) else { break };
            let id = candidates[pick].id;
            let (needed_blocks, needed_bytes) = {
                let e = &self.entries[&id];
                (e.needed_blocks, e.needed_bytes)
            };
            // worst-case residency if this request joins: live full-context
            // reservations or the measured snapshot, whichever is larger
            let fits = match self.cfg.budget {
                KvBudget::Unlimited => true,
                KvBudget::Blocks(b) => {
                    self.reserved_blocks.max(occ.blocks_in_use) + needed_blocks <= b
                }
                KvBudget::Bytes(b) => {
                    self.reserved_bytes.max(occ.bytes_in_use) + needed_bytes <= b
                }
            };
            // escape hatch: with nothing live, deferring could never free
            // blocks — the budget is a back-pressure valve, not a hard cap
            if !fits && !self.running.is_empty() {
                deferred = true;
                self.deferred_total += 1;
                break;
            }
            candidates.remove(pick);
            let idx = self.waiting.iter().position(|&w| w == id).expect("picked id is waiting");
            self.waiting.remove(idx);
            let slot = self.free_slots.pop().expect("checked non-empty");
            self.reserved_blocks += needed_blocks;
            self.reserved_bytes += needed_bytes;
            let e = self.entries.get_mut(&id).expect("picked id exists");
            e.slot = slot;
            e.admitted_at = Some(Instant::now());
            let mut done_at_admission = false;
            if e.use_prefill && e.eff_prompt_len() > 1 {
                e.state = RequestState::Prefilling;
            } else {
                e.state = RequestState::Decoding;
                e.next_input = e.prompt[0];
                e.fed = 1;
                // a zero-target single-token request has nothing to run
                done_at_admission = e.fed >= e.eff_prompt_len() && e.gen_target == 0;
            }
            self.running.push(id);
            self.admitted_events.push(id);
            admitted += 1;
            if done_at_admission {
                self.finish(id, FinishReason::Completed);
            }
        }
        // age whoever is still waiting (the SJF anti-starvation clock) —
        // but only on rounds where the policy actually passed them over
        // (someone else was admitted, or the budget deferred the pick).
        // Slot-bound rounds age nobody: under sustained full-slot load the
        // whole queue would otherwise age past the bound and force SJF
        // into permanent FIFO order.
        if admitted > 0 || deferred {
            for &id in &self.waiting {
                if let Some(e) = self.entries.get_mut(&id) {
                    e.waited_rounds += 1;
                }
            }
        }
        (admitted, deferred)
    }

    // ---- step composition -------------------------------------------------

    /// The next prefill chunk to run, if any request is mid-prefill
    /// (admission order; one chunk per engine iteration).
    pub fn next_prefill(&self) -> Option<PrefillStep> {
        self.running.iter().find_map(|&id| {
            let e = &self.entries[&id];
            if e.state == RequestState::Prefilling {
                Some(PrefillStep { id, slot: e.slot, cached: e.prefill_cached })
            } else {
                None
            }
        })
    }

    /// Up to `cap` effective-prompt tokens (prompt, then any post-preempt
    /// replay suffix) starting at the request's prefill cursor.
    pub fn prompt_chunk(&self, id: RequestId, cap: usize) -> Vec<i32> {
        let e = &self.entries[&id];
        let end = (e.prefill_cached + cap.max(1)).min(e.eff_prompt_len());
        (e.prefill_cached..end).map(|i| e.eff_prompt_at(i)).collect()
    }

    /// Compose this iteration's decode batch groups (see [`GroupMode`]).
    pub fn decode_plan(&self) -> Vec<Vec<DecodeRow>> {
        let cap = self.cfg.group_slots;
        let mut groups: Vec<Vec<DecodeRow>> = Vec::new();
        match self.cfg.grouping {
            GroupMode::Packed => {
                for &id in &self.running {
                    let e = &self.entries[&id];
                    if e.state != RequestState::Decoding {
                        continue;
                    }
                    if groups.last().map_or(true, |g| g.len() >= cap) {
                        groups.push(Vec::new());
                    }
                    groups.last_mut().expect("pushed above").push(e.decode_row());
                }
            }
            GroupMode::ByWave => {
                let waves = self.cfg.total_slots.div_ceil(cap).max(1);
                let mut by_wave: Vec<Vec<DecodeRow>> = vec![Vec::new(); waves];
                for &id in &self.running {
                    let e = &self.entries[&id];
                    if e.state != RequestState::Decoding {
                        continue;
                    }
                    let w = (e.slot as usize / cap).min(waves - 1);
                    by_wave[w].push(e.decode_row());
                }
                by_wave.retain(|g| !g.is_empty());
                groups = by_wave;
            }
        }
        groups
    }

    // ---- execution feedback -----------------------------------------------

    /// Apply one executed prefill chunk: `consumed` prompt tokens landed in
    /// the KV cache; `next_token` is the model's prediction after the
    /// chunk's last row (meaningful on the final chunk — the request's
    /// first generated token).
    pub fn note_prefill_chunk(&mut self, id: RequestId, consumed: usize, next_token: i32) {
        let finished = {
            let e = self.entries.get_mut(&id).expect("note_prefill_chunk: unknown request");
            debug_assert_eq!(e.state, RequestState::Prefilling);
            e.prefill_cached += consumed;
            if e.prefill_cached >= e.eff_prompt_len() {
                e.state = RequestState::Decoding;
                e.len = e.eff_prompt_len() as i32;
                e.fed = e.eff_prompt_len();
                e.next_input = next_token;
                if e.generated.len() < e.gen_target {
                    e.generated.push(next_token);
                    e.first_token_at.get_or_insert_with(Instant::now);
                }
                e.generated.len() >= e.gen_target
            } else {
                false
            }
        };
        if finished {
            self.finish(id, FinishReason::Completed);
        } else {
            self.grow_reservation(id);
        }
    }

    /// Apply one decode-step result for one row: advance teacher forcing or
    /// collect the generated token, retiring the request when it reaches
    /// its target.
    pub fn note_decode(&mut self, id: RequestId, produced: i32) {
        let finished = {
            let e = self.entries.get_mut(&id).expect("note_decode: unknown request");
            debug_assert_eq!(e.state, RequestState::Decoding);
            e.len += 1;
            if e.fed < e.eff_prompt_len() {
                // teacher forcing: prompt tokens, then (after a preemption)
                // the replay suffix — those outputs were already collected
                e.next_input = e.eff_prompt_at(e.fed);
                e.fed += 1;
            } else {
                if e.generated.len() < e.gen_target {
                    e.generated.push(produced);
                    e.first_token_at.get_or_insert_with(Instant::now);
                }
                e.next_input = produced;
            }
            e.fed >= e.eff_prompt_len() && e.generated.len() >= e.gen_target
        };
        if finished {
            self.finish(id, FinishReason::Completed);
        } else {
            self.grow_reservation(id);
        }
    }

    fn finish(&mut self, id: RequestId, reason: FinishReason) {
        let (slot, blocks, bytes, wrote_kv) = {
            let e = self.entries.get_mut(&id).expect("finish: unknown request");
            debug_assert!(e.state.is_live());
            e.state = RequestState::Finished(reason);
            (e.slot, e.needed_blocks, e.needed_bytes, e.len > 0 || e.prefill_cached > 0)
        };
        self.running.retain(|&r| r != id);
        self.free_slots.push(slot);
        self.reserved_blocks -= blocks;
        self.reserved_bytes -= bytes;
        // only requests that materialized KV owe the workers a Retire. A
        // freed-but-never-written slot must NOT queue one: the slot can be
        // re-assigned before the leader sends the pending Retire, and the
        // stale Retire would wipe the next occupant's first appends.
        if wrote_kv {
            self.retire_queue.push((id, slot));
        }
        // the finish EVENT is reported regardless, so the driver's
        // outcome/metrics see every finish, not just the KV-writing ones
        self.finished_events.push(id);
    }

    /// Overcommit only: keep the reservation one block ahead of the tokens
    /// actually cached, so `reserved_*` tracks real occupancy instead of
    /// the full-context worst case. Capped by the submit-time context
    /// validation (len never exceeds prompt + target ≤ max_context).
    fn grow_reservation(&mut self, id: RequestId) {
        if !self.cfg.overcommit {
            return;
        }
        let bb = self.cfg.block_bytes;
        let e = self.entries.get_mut(&id).expect("grow_reservation: unknown request");
        debug_assert!(e.state.is_live());
        let held = (e.len as usize).max(e.prefill_cached);
        let need = kv_blocks_needed(&[held + 1], self.cfg.kv_block_size);
        if need > e.needed_blocks {
            let extra = need - e.needed_blocks;
            e.needed_blocks = need;
            e.needed_bytes += extra * bb;
            self.reserved_blocks += extra;
            self.reserved_bytes += extra * bb;
        }
    }

    // ---- prefix cache & preemption ----------------------------------------

    /// Admissions since the last call, in admission order. The leader
    /// probes these against its prefix index before their first prefill
    /// chunk runs.
    pub fn take_admitted(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.admitted_events)
    }

    /// The token sequence whose KV the request's slot holds once its
    /// prefill completes: the prompt plus any replay suffix from a
    /// preemption. This is the key the leader's prefix index operates on.
    pub fn effective_prompt(&self, id: RequestId) -> Option<Vec<i32>> {
        let e = self.entries.get(&id)?;
        let mut p = e.prompt.clone();
        p.extend_from_slice(&e.generated[..e.promoted]);
        Some(p)
    }

    /// Physical slot of a live request.
    pub fn slot_of(&self, id: RequestId) -> Option<u32> {
        let e = self.entries.get(&id)?;
        e.state.is_live().then_some(e.slot)
    }

    /// Record that the first `tokens` effective-prompt tokens are already
    /// resident in the slot's KV (the leader mapped a donor's blocks via
    /// `MapBlocks`); prefill resumes after them. Must precede the first
    /// prefill chunk and leave at least one token to prefill.
    pub fn set_prefix_cached(&mut self, id: RequestId, tokens: usize) {
        let e = self.entries.get_mut(&id).expect("set_prefix_cached: unknown request");
        debug_assert_eq!(e.state, RequestState::Prefilling);
        debug_assert_eq!(e.prefill_cached, 0, "prefix mapping must precede prefill");
        debug_assert!(tokens < e.eff_prompt_len(), "a hit must leave ≥ 1 token to prefill");
        e.prefill_cached = tokens;
    }

    /// Preempt a live request: release its slot and reservation, queue a
    /// `Retire` for any KV it materialized, and push it back to the FRONT
    /// of the waiting queue (a victim re-admits before new arrivals, so
    /// preemption cannot starve it). Generated tokens are preserved as a
    /// replay suffix and re-prefilled on re-admission; see module docs.
    /// Returns false for queued, finished, or unknown ids.
    pub fn preempt(&mut self, id: RequestId) -> bool {
        match self.entries.get(&id).map(|e| e.state) {
            Some(s) if s.is_live() => {}
            _ => return false,
        }
        let (slot, blocks, bytes, wrote_kv) = {
            let e = &self.entries[&id];
            (e.slot, e.needed_blocks, e.needed_bytes, e.len > 0 || e.prefill_cached > 0)
        };
        self.running.retain(|&r| r != id);
        self.free_slots.push(slot);
        self.reserved_blocks -= blocks;
        self.reserved_bytes -= bytes;
        if wrote_kv {
            self.retire_queue.push((id, slot));
        }
        let e = self.entries.get_mut(&id).expect("checked above");
        // The newest generated token (if any) was emitted but never fed
        // back through attention — its KV does not exist. Drop it; the
        // resumed prefill re-predicts it from the same context, and greedy
        // decode is deterministic, so the final output is unchanged.
        if e.generated.len() > e.promoted {
            e.generated.pop();
        }
        e.promoted = e.generated.len();
        e.state = RequestState::Queued;
        e.fed = 0;
        e.len = 0;
        e.next_input = 0;
        e.prefill_cached = 0;
        let reserve_tokens = if self.cfg.overcommit {
            e.eff_prompt_len()
        } else {
            e.prompt.len() + e.gen_target
        };
        e.needed_blocks = kv_blocks_needed(&[reserve_tokens], self.cfg.kv_block_size);
        e.needed_bytes = e.needed_blocks * self.cfg.block_bytes;
        self.waiting.push_front(id);
        self.preempted_total += 1;
        true
    }

    /// Overcommit pressure valve: while live reservations (or the measured
    /// occupancy snapshot) exceed the budget and more than one request is
    /// live, preempt victims picked by [`AdmissionPolicy::pick_victim`].
    /// Returns the preempted ids in eviction order. The snapshot cannot
    /// observe the releases mid-loop, so each victim's reservation is
    /// discounted from it — one stale reading must not cascade into
    /// evicting everything.
    pub fn pressure_preempt(&mut self, occ: KvOccupancy) -> Vec<RequestId> {
        if !self.cfg.overcommit {
            return Vec::new();
        }
        let mut out = Vec::new();
        let (mut occ_blocks, mut occ_bytes) = (occ.blocks_in_use, occ.bytes_in_use);
        loop {
            let over = match self.cfg.budget {
                KvBudget::Unlimited => false,
                KvBudget::Blocks(b) => self.reserved_blocks.max(occ_blocks) > b,
                KvBudget::Bytes(b) => self.reserved_bytes.max(occ_bytes) > b,
            };
            if !over || self.running.len() <= 1 {
                break;
            }
            let candidates: Vec<Candidate> = self
                .running
                .iter()
                .map(|&id| {
                    let e = &self.entries[&id];
                    Candidate {
                        id,
                        cost_tokens: e.prompt.len() + e.gen_target,
                        waited_rounds: e.waited_rounds,
                    }
                })
                .collect();
            let Some(pick) = self.policy.pick_victim(&candidates) else { break };
            let vid = candidates[pick].id;
            let (vb, vby) = {
                let e = &self.entries[&vid];
                (e.needed_blocks, e.needed_bytes)
            };
            if !self.preempt(vid) {
                break;
            }
            occ_blocks = occ_blocks.saturating_sub(vb);
            occ_bytes = occ_bytes.saturating_sub(vby);
            out.push(vid);
        }
        out
    }

    /// Requests preempted by KV pressure so far.
    pub fn preempted_total(&self) -> u64 {
        self.preempted_total
    }

    /// Requests retired since the last call, with the physical slot whose
    /// KV blocks the leader must free on every worker (`WireMsg::Retire`).
    pub fn take_retirements(&mut self) -> Vec<(RequestId, u32)> {
        std::mem::take(&mut self.retire_queue)
    }

    /// Re-queue a retirement whose wire send failed; the leader retries on
    /// the next step and surfaces the transport error there.
    pub fn push_retirement(&mut self, id: RequestId, slot: u32) {
        self.retire_queue.push((id, slot));
    }

    /// ALL finish events since the last call (superset of the retirement
    /// ids: includes finishes that never wrote KV).
    pub fn take_finished(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.finished_events)
    }

    /// Cancel a request. Queued → dropped before admission; live → retired
    /// as `Finished(Cancelled)` (its `Retire` reaches the workers on the
    /// next step). Returns false for unknown or already-finished ids.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        match self.entries.get(&id).map(|e| e.state) {
            Some(RequestState::Queued) => {
                self.waiting.retain(|&w| w != id);
                self.entries.get_mut(&id).expect("checked").state =
                    RequestState::Finished(FinishReason::Cancelled);
                true
            }
            Some(s) if s.is_live() => {
                self.finish(id, FinishReason::Cancelled);
                true
            }
            _ => false,
        }
    }

    // ---- observation ------------------------------------------------------

    pub fn poll(&self, id: RequestId) -> Option<RequestStatus> {
        let e = self.entries.get(&id)?;
        Some(RequestStatus {
            id,
            state: e.state,
            tokens: e.generated.clone(),
            queue_s: e
                .admitted_at
                .map(|t| t.saturating_duration_since(e.submitted_at).as_secs_f64()),
            ttft_s: e
                .first_token_at
                .map(|t| t.saturating_duration_since(e.submitted_at).as_secs_f64()),
        })
    }

    /// `(queue_s, ttft_s, tokens)` of a *completed* request, for
    /// `ServeMetrics` (None for live, cancelled, or unknown ids).
    pub fn lifecycle(&self, id: RequestId) -> Option<(f64, Option<f64>, usize)> {
        let e = self.entries.get(&id)?;
        if e.state != RequestState::Finished(FinishReason::Completed) {
            return None;
        }
        let queue_s = e
            .admitted_at?
            .saturating_duration_since(e.submitted_at)
            .as_secs_f64();
        let ttft_s = e
            .first_token_at
            .map(|t| t.saturating_duration_since(e.submitted_at).as_secs_f64());
        Some((queue_s, ttft_s, e.generated.len()))
    }

    /// No waiting and no live requests (finished entries may remain
    /// pollable until [`Self::clear_finished`]).
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Live (admitted, unfinished) requests.
    pub fn live(&self) -> usize {
        self.running.len()
    }

    /// Ids of the live requests, in running order. Failover recovery
    /// iterates this to preempt every request whose KV shard died with a
    /// worker.
    pub fn live_ids(&self) -> Vec<RequestId> {
        self.running.clone()
    }

    /// Physical slot of a live request (`None` once finished/preempted).
    ///
    /// Failover recovery captures these *before* preempting: a request
    /// whose first prefill chunk was in flight when a worker died has
    /// `wrote_kv == false` here (no `note_prefill_chunk` ran), so
    /// preempt/cancel queue no Retire — yet surviving workers may already
    /// have appended that chunk. The leader retires such slots explicitly
    /// to keep the pool leak-free; a Retire for a never-written slot is a
    /// no-op on the arena.
    pub fn slot_of(&self, id: RequestId) -> Option<u32> {
        let e = self.entries.get(&id)?;
        if e.state.is_live() {
            Some(e.slot)
        } else {
            None
        }
    }

    pub fn free_slot_count(&self) -> usize {
        self.free_slots.len()
    }

    /// Per-worker KV blocks reserved by live requests (full-context by
    /// default; prompt-then-grown under overcommit).
    pub fn reserved_blocks(&self) -> usize {
        self.reserved_blocks
    }

    pub fn reserved_bytes(&self) -> usize {
        self.reserved_bytes
    }

    /// Admissions the KV budget has deferred so far.
    pub fn deferred_total(&self) -> u64 {
        self.deferred_total
    }

    /// Drop finished entries (long-running sessions; polling them ends).
    pub fn clear_finished(&mut self) {
        self.entries.retain(|_, e| !e.state.is_finished());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(slots: usize, group: usize, grouping: GroupMode, budget: KvBudget) -> SchedCfg {
        SchedCfg {
            max_context: 128,
            total_slots: slots,
            group_slots: group,
            grouping,
            use_prefill: true,
            kv_block_size: 4,
            block_bytes: 64,
            budget,
            overcommit: false,
        }
    }

    fn sched(slots: usize, group: usize, grouping: GroupMode, budget: KvBudget) -> Scheduler {
        Scheduler::new(cfg(slots, group, grouping, budget), AdmissionKind::Fifo.build())
    }

    #[test]
    fn submit_validates_per_request() {
        let mut s = sched(2, 2, GroupMode::Packed, KvBudget::Unlimited);
        assert_eq!(s.submit(vec![], 4), Err(SubmitError::EmptyPrompt));
        assert_eq!(
            s.submit(vec![1; 100], 100),
            Err(SubmitError::ContextTooLong { requested: 200, max: 128 })
        );
        // a rejected request does not consume an id or queue space
        assert_eq!(s.waiting_len(), 0);
        let id = s.submit(vec![1, 2, 3], 4).unwrap();
        assert_eq!(s.poll(id).unwrap().state, RequestState::Queued);
        assert_eq!(s.waiting_len(), 1);
    }

    #[test]
    fn admission_assigns_slots_in_order_and_reserves() {
        let mut s = sched(2, 2, GroupMode::Packed, KvBudget::Blocks(100));
        let a = s.submit(vec![1; 4], 4).unwrap(); // ctx 8 → 2 blocks
        let b = s.submit(vec![2; 4], 4).unwrap();
        let c = s.submit(vec![3; 4], 4).unwrap();
        let (admitted, deferred) = s.admit(KvOccupancy::default());
        assert_eq!((admitted, deferred), (2, false)); // slot-bound, not budget
        assert_eq!(s.poll(a).unwrap().state, RequestState::Prefilling);
        assert_eq!(s.poll(b).unwrap().state, RequestState::Prefilling);
        assert_eq!(s.poll(c).unwrap().state, RequestState::Queued);
        assert_eq!(s.reserved_blocks(), 4);
        assert_eq!(s.reserved_bytes(), 4 * 64);
        assert_eq!(s.free_slot_count(), 0);
        // slots hand out as 0, 1, …
        assert_eq!(s.next_prefill().unwrap().slot, 0);
    }

    #[test]
    fn set_block_bytes_rebases_live_reservations() {
        let mut s = sched(2, 2, GroupMode::Packed, KvBudget::Blocks(100));
        let a = s.submit(vec![1; 4], 4).unwrap(); // ctx 8 → 2 blocks
        let _b = s.submit(vec![2; 4], 4).unwrap();
        s.admit(KvOccupancy::default());
        assert_eq!((s.reserved_blocks(), s.reserved_bytes()), (4, 4 * 64));
        // reshard: fewer workers → more heads per worker → bigger blocks
        s.set_block_bytes(96);
        assert_eq!(s.cfg().block_bytes, 96);
        assert_eq!(s.reserved_blocks(), 4, "block counts are geometry-invariant");
        assert_eq!(s.reserved_bytes(), 4 * 96);
        // a finished request's reservation stays released after the rebase
        s.note_prefill_chunk(a, 4, 7);
        for _ in 0..3 {
            s.note_decode(a, 7);
        }
        assert_eq!(s.poll(a).unwrap().state, RequestState::Finished(FinishReason::Completed));
        let (blocks, bytes) = (s.reserved_blocks(), s.reserved_bytes());
        s.set_block_bytes(32);
        assert_eq!(s.reserved_blocks(), blocks);
        assert_eq!(s.reserved_bytes(), bytes / 96 * 32);
    }

    #[test]
    fn budget_defers_with_live_requests_and_escape_hatches_alone() {
        let mut s = sched(4, 4, GroupMode::Packed, KvBudget::Blocks(3));
        // needs 4 blocks > budget 3, but nothing is live → escape hatch
        let big = s.submit(vec![1; 12], 4).unwrap();
        let (admitted, deferred) = s.admit(KvOccupancy::default());
        assert_eq!((admitted, deferred), (1, false));
        assert!(s.poll(big).unwrap().state.is_live());
        // now a second request must defer (4 reserved > 3 already)
        let small = s.submit(vec![1; 2], 1).unwrap();
        let (admitted, deferred) = s.admit(KvOccupancy::default());
        assert_eq!((admitted, deferred), (0, true));
        assert_eq!(s.poll(small).unwrap().state, RequestState::Queued);
        assert_eq!(s.deferred_total(), 1);
    }

    #[test]
    fn teacher_forcing_feeds_prompt_then_emits() {
        let mut s = Scheduler::new(
            SchedCfg { use_prefill: false, ..cfg(1, 1, GroupMode::Packed, KvBudget::Unlimited) },
            AdmissionKind::Fifo.build(),
        );
        let id = s.submit(vec![10, 11, 12], 2).unwrap();
        s.admit(KvOccupancy::default());
        // step 1: input 10 @ len 0, not emitting
        let rows = s.decode_plan();
        assert_eq!(rows.len(), 1);
        let r = rows[0][0];
        assert_eq!((r.input, r.len, r.emits), (10, 0, false));
        s.note_decode(id, 900);
        // step 2: teacher-forced input 11
        let r = s.decode_plan()[0][0];
        assert_eq!((r.input, r.len, r.emits), (11, 1, false));
        s.note_decode(id, 901);
        // step 3: last prompt token fed; output now counts
        let r = s.decode_plan()[0][0];
        assert_eq!((r.input, r.len, r.emits), (12, 2, true));
        s.note_decode(id, 902);
        // step 4: free-running on the generated token
        let r = s.decode_plan()[0][0];
        assert_eq!((r.input, r.len, r.emits), (902, 3, true));
        s.note_decode(id, 903);
        let st = s.poll(id).unwrap();
        assert_eq!(st.state, RequestState::Finished(FinishReason::Completed));
        assert_eq!(st.tokens, vec![902, 903]);
        assert_eq!(s.take_retirements(), vec![(id, 0)]);
        assert!(s.is_idle());
        assert_eq!(s.free_slot_count(), 1);
        assert_eq!(s.reserved_blocks(), 0);
    }

    #[test]
    fn prefill_chunks_then_first_token() {
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let id = s.submit(vec![1, 2, 3, 4, 5], 2).unwrap();
        s.admit(KvOccupancy::default());
        let p = s.next_prefill().unwrap();
        assert_eq!((p.id, p.cached), (id, 0));
        assert_eq!(s.prompt_chunk(id, 3), vec![1, 2, 3]);
        s.note_prefill_chunk(id, 3, 0);
        let p = s.next_prefill().unwrap();
        assert_eq!(p.cached, 3);
        assert_eq!(s.prompt_chunk(id, 3), vec![4, 5]);
        s.note_prefill_chunk(id, 2, 77); // final chunk → first token
        assert!(s.next_prefill().is_none());
        let st = s.poll(id).unwrap();
        assert_eq!(st.state, RequestState::Decoding);
        assert_eq!(st.tokens, vec![77]);
        // decode continues from the prompt's full length
        let r = s.decode_plan()[0][0];
        assert_eq!((r.input, r.len, r.emits), (77, 5, true));
        s.note_decode(id, 78);
        assert_eq!(s.poll(id).unwrap().tokens, vec![77, 78]);
        assert!(s.poll(id).unwrap().state.is_finished());
    }

    #[test]
    fn grouping_packs_vs_waves() {
        let mk = |grouping| {
            let mut s = Scheduler::new(
                SchedCfg { use_prefill: false, ..cfg(4, 2, grouping, KvBudget::Unlimited) },
                AdmissionKind::Fifo.build(),
            );
            for i in 0..3 {
                s.submit(vec![i as i32 + 1], 4).unwrap();
            }
            s.admit(KvOccupancy::default());
            s
        };
        // packed: [2, 1]
        let s = mk(GroupMode::Packed);
        let plan = s.decode_plan();
        assert_eq!(plan.iter().map(|g| g.len()).collect::<Vec<_>>(), vec![2, 1]);
        // by-wave: slots 0,1 → wave 0; slot 2 → wave 1
        let s = mk(GroupMode::ByWave);
        let plan = s.decode_plan();
        assert_eq!(plan.iter().map(|g| g.len()).collect::<Vec<_>>(), vec![2, 1]);
        assert_eq!(plan[1][0].slot, 2);
    }

    #[test]
    fn cancel_in_every_state() {
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let first = s.submit(vec![1, 2], 4).unwrap();
        let second = s.submit(vec![1, 2, 3], 4).unwrap();
        s.admit(KvOccupancy::default()); // admits `first` only (1 slot)
        assert!(s.cancel(second)); // still Queued → dropped from the queue
        assert_eq!(s.poll(second).unwrap().state, RequestState::Finished(FinishReason::Cancelled));
        assert!(s.cancel(first)); // live → retired
        assert_eq!(
            s.poll(first).unwrap().state,
            RequestState::Finished(FinishReason::Cancelled)
        );
        assert!(!s.cancel(first)); // idempotent
        assert!(s.is_idle());
        assert_eq!(s.free_slot_count(), 1);
        assert_eq!(s.reserved_blocks(), 0);
        // neither request ever wrote KV (`first` was cancelled before its
        // first prefill chunk), so neither owes the workers a Retire —
        // a stale Retire could wipe the slot's next occupant
        assert_eq!(s.take_retirements().len(), 0);
    }

    #[test]
    fn cancel_after_kv_writes_queues_a_retire() {
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let id = s.submit(vec![1, 2, 3, 4, 5], 8).unwrap();
        s.admit(KvOccupancy::default());
        s.note_prefill_chunk(id, 3, 0); // KV materialized on the workers
        assert!(s.cancel(id));
        assert_eq!(s.take_retirements(), vec![(id, 0)]);
        assert_eq!(s.free_slot_count(), 1);
    }

    /// Drive a scheduler to idle against a deterministic stand-in model:
    /// with L tokens in the cache, the next prediction is `100 + L`. That
    /// depends only on context *length*, so prefill, teacher forcing, and
    /// post-preemption replay all agree on every token. Optionally preempt
    /// `victim` after its `n`-th decode note.
    fn drive(s: &mut Scheduler, preempt: Option<(RequestId, usize)>, chunk: usize) {
        let mut noted = 0usize;
        for _ in 0..10_000 {
            if s.is_idle() {
                return;
            }
            s.admit(KvOccupancy::default());
            if let Some(p) = s.next_prefill() {
                let n = s.prompt_chunk(p.id, chunk).len();
                s.note_prefill_chunk(p.id, n, 100 + (p.cached + n) as i32);
                continue;
            }
            for g in s.decode_plan() {
                for r in g {
                    s.note_decode(r.id, 100 + r.len + 1);
                    if let Some((vid, at)) = preempt {
                        if r.id == vid {
                            noted += 1;
                            if noted == at {
                                assert!(s.preempt(vid));
                            }
                        }
                    }
                }
            }
        }
        panic!("drive did not converge");
    }

    #[test]
    fn overcommit_reserves_prompt_only_then_grows_per_block() {
        let mut s = Scheduler::new(
            SchedCfg { overcommit: true, ..cfg(1, 1, GroupMode::Packed, KvBudget::Unlimited) },
            AdmissionKind::Fifo.build(),
        );
        // ctx 10 → 3 blocks full-context, but only blocks(4) = 1 up front
        let id = s.submit(vec![1, 2, 3, 4], 6).unwrap();
        s.admit(KvOccupancy::default());
        assert_eq!(s.reserved_blocks(), 1);
        s.note_prefill_chunk(id, 4, 105); // cache holds 4 → next step needs block 2
        assert_eq!(s.reserved_blocks(), 2);
        for _ in 0..3 {
            let r = s.decode_plan()[0][0];
            s.note_decode(id, 100 + r.len + 1);
        }
        // len 7 → one block ahead covers token 8, still 2 blocks
        assert_eq!(s.reserved_blocks(), 2);
        let r = s.decode_plan()[0][0];
        s.note_decode(id, 100 + r.len + 1); // len 8 → block 3
        assert_eq!(s.reserved_blocks(), 3);
        let r = s.decode_plan()[0][0];
        s.note_decode(id, 100 + r.len + 1); // target reached
        assert!(s.poll(id).unwrap().state.is_finished());
        assert_eq!((s.reserved_blocks(), s.reserved_bytes()), (0, 0));
    }

    #[test]
    fn preempt_conserves_slots_reservations_and_retires() {
        let mut s = Scheduler::new(
            SchedCfg { overcommit: true, ..cfg(2, 2, GroupMode::Packed, KvBudget::Unlimited) },
            AdmissionKind::Fifo.build(),
        );
        let a = s.submit(vec![1; 4], 4).unwrap();
        let b = s.submit(vec![2; 4], 4).unwrap();
        s.admit(KvOccupancy::default());
        s.take_admitted();
        let before = s.reserved_blocks();
        s.note_prefill_chunk(a, 2, 0); // A materializes KV mid-prefill
        assert!(s.preempt(a));
        assert_eq!(s.poll(a).unwrap().state, RequestState::Queued);
        assert_eq!(s.free_slot_count(), 1);
        assert_eq!(s.reserved_blocks(), before - 1);
        assert_eq!(s.take_retirements(), vec![(a, 0)]);
        assert_eq!(s.preempted_total(), 1);
        // not live → not preemptable; B is untouched
        assert!(!s.preempt(a));
        assert!(s.poll(b).unwrap().state.is_live());
        // the victim re-admits at the head of the queue and re-prefills
        // from scratch (its retired KV is gone)
        let c = s.submit(vec![3; 4], 4).unwrap();
        s.admit(KvOccupancy::default());
        assert_eq!(s.take_admitted(), vec![a]); // a, not c: front of the queue
        assert_eq!(s.poll(a).unwrap().state, RequestState::Prefilling);
        assert_eq!(s.next_prefill().map(|p| p.cached), Some(0));
        assert_eq!(s.poll(c).unwrap().state, RequestState::Queued);
    }

    #[test]
    fn preempted_request_completes_with_identical_output() {
        for use_prefill in [true, false] {
            for preempt_at in [1, 3] {
                let mk = || {
                    Scheduler::new(
                        SchedCfg {
                            use_prefill,
                            overcommit: true,
                            ..cfg(2, 2, GroupMode::Packed, KvBudget::Unlimited)
                        },
                        AdmissionKind::Fifo.build(),
                    )
                };
                let mut reference = mk();
                let id = reference.submit(vec![1, 2, 3, 4, 5], 5).unwrap();
                drive(&mut reference, None, 2);
                let want = reference.poll(id).unwrap().tokens;
                assert_eq!(want.len(), 5);

                let mut s = mk();
                let id = s.submit(vec![1, 2, 3, 4, 5], 5).unwrap();
                // keep a second request live so the preempted one competes
                s.submit(vec![9, 9], 3).unwrap();
                drive(&mut s, Some((id, preempt_at)), 2);
                assert_eq!(
                    s.poll(id).unwrap().tokens,
                    want,
                    "use_prefill={use_prefill} preempt_at={preempt_at}"
                );
            }
        }
    }

    #[test]
    fn pressure_preempt_evicts_newest_until_under_budget_never_the_last() {
        let mut s = Scheduler::new(
            SchedCfg { overcommit: true, ..cfg(3, 3, GroupMode::Packed, KvBudget::Blocks(3)) },
            AdmissionKind::Fifo.build(),
        );
        let ids: Vec<_> = (0..3).map(|i| s.submit(vec![i; 4], 8).unwrap()).collect();
        s.admit(KvOccupancy::default()); // 3 × 1 prompt block = budget
        assert_eq!(s.live(), 3);
        assert!(s.pressure_preempt(KvOccupancy::default()).is_empty(), "at budget, not over");
        // growth pushes past the budget → newest victim goes back to queued
        s.note_prefill_chunk(ids[0], 4, 0);
        assert_eq!(s.reserved_blocks(), 4);
        assert_eq!(s.pressure_preempt(KvOccupancy::default()), vec![ids[2]]);
        assert_eq!(s.poll(ids[2]).unwrap().state, RequestState::Queued);
        assert_eq!(s.reserved_blocks(), 3);
        // a hopeless budget still never evicts the last live request
        let mut s = Scheduler::new(
            SchedCfg { overcommit: true, ..cfg(1, 1, GroupMode::Packed, KvBudget::Blocks(1)) },
            AdmissionKind::Fifo.build(),
        );
        let id = s.submit(vec![1; 8], 4).unwrap();
        s.admit(KvOccupancy::default()); // escape hatch: 2 blocks > budget 1
        assert!(s.pressure_preempt(KvOccupancy::default()).is_empty());
        assert!(s.poll(id).unwrap().state.is_live());
        // and the valve is inert without overcommit
        let mut s = sched(2, 2, GroupMode::Packed, KvBudget::Blocks(1));
        s.submit(vec![1; 8], 4).unwrap();
        s.admit(KvOccupancy::default());
        assert!(s.pressure_preempt(KvOccupancy { blocks_in_use: 99, bytes_in_use: 0 }).is_empty());
    }

    #[test]
    fn prefix_cached_admission_skips_mapped_tokens() {
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let id = s.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 2).unwrap();
        s.admit(KvOccupancy::default());
        assert_eq!(s.take_admitted(), vec![id]);
        assert!(s.take_admitted().is_empty(), "admission events drain");
        assert_eq!(s.effective_prompt(id).unwrap().len(), 8);
        assert_eq!(s.slot_of(id), Some(0));
        s.set_prefix_cached(id, 4); // leader mapped the first block from a donor
        let p = s.next_prefill().unwrap();
        assert_eq!(p.cached, 4);
        assert_eq!(s.prompt_chunk(id, 16), vec![5, 6, 7, 8]);
        s.note_prefill_chunk(id, 4, 77);
        let st = s.poll(id).unwrap();
        assert_eq!((st.state, st.tokens.as_slice()), (RequestState::Decoding, &[77][..]));
        assert_eq!(s.decode_plan()[0][0].len, 8);
        // mapped-but-never-prefilled KV still owes the workers a Retire
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let id = s.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 2).unwrap();
        s.admit(KvOccupancy::default());
        s.set_prefix_cached(id, 4);
        s.cancel(id);
        assert_eq!(s.take_retirements(), vec![(id, 0)]);
    }

    #[test]
    fn ids_resume_across_sessions() {
        let mut s = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        let a = s.submit(vec![1], 1).unwrap();
        let mut s2 = sched(1, 1, GroupMode::Packed, KvBudget::Unlimited);
        s2.resume_ids_at(s.next_request_id());
        let b = s2.submit(vec![2], 1).unwrap();
        assert!(b > a, "ids must stay unique across sessions");
        assert!(s2.poll(a).is_none(), "stale ids poll as unknown");
    }
}
