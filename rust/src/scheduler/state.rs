//! Request-lifecycle types of the continuous-batching engine.
//!
//! A request moves through a small state machine owned by the
//! [`crate::scheduler::Scheduler`]:
//!
//! ```text
//! submit() ─▶ Queued ─admit─▶ Prefilling ─last chunk─▶ Decoding ─target─▶ Finished{Completed}
//!               ▲ │              (teacher-forced requests skip Prefilling)  │     ▲
//!               │ └──────────────────────── cancel() ───────┼──────────────┼──▶ Finished{Cancelled}
//!               └────────────────────────── preempt() ◀─────┴──────────────┘
//! ```
//!
//! `preempt()` (overcommit pressure relief) sends a live request back to
//! the *front* of the waiting queue with its generated tokens intact; on
//! re-admission it re-prefills prompt + generated and continues, so its
//! final output is identical to an unpreempted run.
//!
//! Validation happens **per request at submit time** ([`SubmitError`]): an
//! invalid request is rejected without touching the rest of the session —
//! the old wave-bound `serve` aborted the whole run on the first oversized
//! request.

pub type RequestId = u64;

/// Why a request left the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full token target.
    Completed,
    /// Cancelled by the caller before completing.
    Cancelled,
}

/// Lifecycle state (see the module diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Submitted, waiting for admission (no slot, no KV reservation).
    Queued,
    /// Admitted; the prompt is streaming into the KV cache chunk by chunk.
    Prefilling,
    /// In the running decode batch (teacher-forcing any unconsumed prompt).
    Decoding,
    /// Retired; its slot and KV reservation are back in the pools.
    Finished(FinishReason),
}

impl RequestState {
    pub fn is_finished(self) -> bool {
        matches!(self, RequestState::Finished(_))
    }

    /// Admitted and holding a slot (prefilling or decoding).
    pub fn is_live(self) -> bool {
        matches!(self, RequestState::Prefilling | RequestState::Decoding)
    }
}

/// Typed per-request rejection at `submit` time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    EmptyPrompt,
    /// prompt + generation target exceeds the model's context window.
    ContextTooLong { requested: usize, max: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EmptyPrompt => write!(f, "empty prompt"),
            SubmitError::ContextTooLong { requested, max } => write!(
                f,
                "request context {requested} (prompt + generation) exceeds the model max {max}"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What one engine iteration ([`step`](crate::workers::DisaggPipeline::step))
/// did.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Requests admitted from the waiting queue this iteration.
    pub admitted: usize,
    /// The KV budget blocked at least one admission this iteration.
    pub deferred: bool,
    /// A prefill chunk ran for this request (prefill preempts decode for
    /// one iteration, exactly like the wave loop's inline prompt pass).
    pub prefilled: Option<RequestId>,
    /// Batch rows decoded (across all groups).
    pub decoded_rows: usize,
    /// Decode groups executed (Packed: ceil(running/group); ByWave: waves).
    pub decode_groups: usize,
    /// Requests that finished (and whose KV was retired) this iteration.
    pub finished: Vec<RequestId>,
    /// Requests preempted back to the waiting queue by KV pressure this
    /// iteration (overcommit mode only).
    pub preempted: Vec<RequestId>,
    /// Attention workers declared dead and replaced this iteration; every
    /// live request was preempted for promoted-token replay (those ids
    /// also appear in `preempted`).
    pub recovered_workers: Vec<usize>,
    /// Nothing left to do: no waiting and no live requests.
    pub idle: bool,
}

/// Snapshot returned by `poll`.
#[derive(Debug, Clone)]
pub struct RequestStatus {
    pub id: RequestId,
    pub state: RequestState,
    /// Tokens generated so far (the full output once finished).
    pub tokens: Vec<i32>,
    /// submit → admission, seconds (`None` until admitted).
    pub queue_s: Option<f64>,
    /// submit → first generated token, seconds (`None` until it exists).
    pub ttft_s: Option<f64>,
}
