//! Pluggable admission policies (`--admission fifo|sjf`).
//!
//! The [`crate::scheduler::Scheduler`] asks the policy which waiting
//! request to *try* next; the scheduler itself owns the fit check (free
//! slot + KV budget). If the pick does not fit, the admission round stops —
//! head-of-line blocking in whatever order the policy chose — and the event
//! counts as a deferred admission. This keeps the budget semantics of the
//! old wave loop (including its no-live-requests escape hatch, which lives
//! in the scheduler, not here) while making the *order* pluggable.
//!
//! * [`Fifo`] — strict arrival order; a blocked head blocks everyone
//!   behind it. The old `serve` behavior.
//! * [`Sjf`] — shortest job (full context = prompt + generation target)
//!   first among the deferred backlog, so short requests flow around a big
//!   one that is waiting for KV headroom. Starvation-proof by aging: once a
//!   request has been passed over [`Sjf::max_wait_rounds`] times it regains
//!   strict FIFO priority, and nothing may be admitted ahead of it until it
//!   fits (`tests/scheduler.rs` property-tests this under a continuous
//!   arrival stream).

use super::state::RequestId;

/// A waiting request as the policy sees it. The slice passed to
/// [`AdmissionPolicy::pick`] preserves FIFO (submission) order.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub id: RequestId,
    /// Full-context cost in tokens (prompt + generation target) — the KV
    /// footprint the request will reserve.
    pub cost_tokens: usize,
    /// Admission rounds this request has already been passed over.
    pub waited_rounds: u32,
}

/// Admission-order strategy. Implementations must be deterministic: the
/// same candidate slice must always produce the same pick (continuous and
/// wave-grouped sessions replay admission identically in tests).
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;

    /// Index (into the FIFO-ordered `waiting` slice) of the request to try
    /// admitting next, or `None` to admit nothing this round.
    fn pick(&mut self, waiting: &[Candidate]) -> Option<usize>;

    /// Index (into the admission-ordered `running` slice) of the request to
    /// preempt when the KV arena is over budget, or `None` to preempt
    /// nothing. The default evicts the most recently admitted request
    /// (LIFO): it has the least KV invested, so re-prefilling it wastes the
    /// fewest tokens, and the oldest requests keep their forward-progress
    /// guarantee. Must be deterministic, like [`Self::pick`].
    fn pick_victim(&mut self, running: &[Candidate]) -> Option<usize> {
        running.len().checked_sub(1)
    }
}

/// First-in-first-out (the legacy order).
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl AdmissionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, waiting: &[Candidate]) -> Option<usize> {
        if waiting.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Shortest-job-first among deferred admissions, with FIFO aging.
#[derive(Debug, Clone, Copy)]
pub struct Sjf {
    /// After this many passed-over rounds a request regains strict FIFO
    /// priority (anti-starvation; see module docs).
    pub max_wait_rounds: u32,
}

impl Default for Sjf {
    fn default() -> Self {
        Sjf { max_wait_rounds: DEFAULT_SJF_MAX_WAIT_ROUNDS }
    }
}

/// Default aging bound: generous enough that SJF gets real reordering room,
/// small enough that a starved request is forced within tens of iterations.
pub const DEFAULT_SJF_MAX_WAIT_ROUNDS: u32 = 32;

impl AdmissionPolicy for Sjf {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn pick(&mut self, waiting: &[Candidate]) -> Option<usize> {
        if waiting.is_empty() {
            return None;
        }
        // aging: the FIFO-oldest request that has waited past the bound is
        // tried first, and (because a failed fit ends the round) nothing
        // can be admitted around it anymore.
        if let Some((i, _)) = waiting
            .iter()
            .enumerate()
            .find(|(_, c)| c.waited_rounds >= self.max_wait_rounds)
        {
            return Some(i);
        }
        waiting
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.cost_tokens, *i)) // tie → FIFO
            .map(|(i, _)| i)
    }
}

/// CLI-selectable policy kind (`--admission`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionKind {
    Fifo,
    Sjf,
}

impl AdmissionKind {
    pub fn parse(s: &str) -> Option<AdmissionKind> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(AdmissionKind::Fifo),
            "sjf" => Some(AdmissionKind::Sjf),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionKind::Fifo => "fifo",
            AdmissionKind::Sjf => "sjf",
        }
    }

    pub fn build(self) -> Box<dyn AdmissionPolicy> {
        match self {
            AdmissionKind::Fifo => Box::new(Fifo),
            AdmissionKind::Sjf => Box::new(Sjf::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: RequestId, cost: usize, waited: u32) -> Candidate {
        Candidate { id, cost_tokens: cost, waited_rounds: waited }
    }

    #[test]
    fn fifo_always_head() {
        let mut p = Fifo;
        assert_eq!(p.pick(&[]), None);
        assert_eq!(p.pick(&[cand(7, 100, 0), cand(8, 1, 50)]), Some(0));
    }

    #[test]
    fn sjf_picks_cheapest_with_fifo_tiebreak() {
        let mut p = Sjf::default();
        assert_eq!(p.pick(&[]), None);
        assert_eq!(p.pick(&[cand(0, 90, 0), cand(1, 10, 0), cand(2, 10, 0)]), Some(1));
    }

    #[test]
    fn sjf_aging_forces_fifo() {
        let mut p = Sjf { max_wait_rounds: 5 };
        // the old expensive head regains priority once it has waited enough
        assert_eq!(p.pick(&[cand(0, 90, 5), cand(1, 10, 0)]), Some(0));
        // below the bound, SJF order applies
        assert_eq!(p.pick(&[cand(0, 90, 4), cand(1, 10, 0)]), Some(1));
    }

    #[test]
    fn default_victim_is_last_admitted() {
        let mut p = Fifo;
        assert_eq!(p.pick_victim(&[]), None);
        assert_eq!(p.pick_victim(&[cand(3, 8, 0), cand(5, 2, 0)]), Some(1));
        let mut p = Sjf::default();
        assert_eq!(p.pick_victim(&[cand(3, 8, 0), cand(5, 2, 0)]), Some(1));
    }

    #[test]
    fn kind_parse_and_build() {
        assert_eq!(AdmissionKind::parse("FIFO"), Some(AdmissionKind::Fifo));
        assert_eq!(AdmissionKind::parse("sjf"), Some(AdmissionKind::Sjf));
        assert_eq!(AdmissionKind::parse("lifo"), None);
        assert_eq!(AdmissionKind::Fifo.build().name(), "fifo");
        assert_eq!(AdmissionKind::Sjf.build().name(), "sjf");
    }
}
