//! # Lamina-RS
//!
//! Reproduction of *"Efficient Heterogeneous Large Language Model Decoding
//! with Model-Attention Disaggregation"* (Lamina) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! * **L3 (this crate)** — the coordinator: heterogeneous device pools,
//!   continuous batching, paged KV-cache management, rotational staggered
//!   pipelining, the FHBN-vs-NCCL network model, and the roofline simulator
//!   that regenerates every figure/table of the paper.
//! * **L2/L1 (`python/compile`)** — the LLaMA-style model slices and the
//!   Pallas GQA decode-attention kernel, AOT-lowered once to HLO text.
//! * **runtime** — loads the AOT artifacts via PJRT (`xla` crate) so the
//!   serving path is pure Rust; Python never runs at request time.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baseline;
pub mod coordinator;
pub mod devices;
pub mod figures;
pub mod kernels;
pub mod kvcache;
pub mod metrics;
pub mod net;
pub mod netsim;
pub mod obs;
pub mod opgraph;
pub mod runtime;
pub mod scheduler;
pub mod trace;
pub mod util;
pub mod workers;
