//! Network-stack latency/bandwidth models (paper §4.1, Fig. 13).
//!
//! The paper's FHBN removes every host-CPU step from the GPU-to-GPU
//! communication critical path. We model each stack as a sum of the
//! components §4.1 enumerates, calibrated against the paper's measured
//! endpoints: FHBN 33.0 µs small-message RTT / 45.7 GB/s peak (91.4 % of a
//! 400 Gbps line), NCCL 66.6 µs / 35.5 GB/s.
//!
//! The real RDMA/BlueFlame hardware is absent in this reproduction (see
//! DESIGN.md §2); these models drive both the ping-pong microbench and the
//! per-layer communication costs in the serving simulator, and pace the
//! in-process byte transport used by the real tiny-model pipeline.

/// One directional transfer's latency decomposition (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct NetStackModel {
    pub name: &'static str,
    /// Step 1: sender CPU waits for prior GPU kernels (host-device sync).
    pub host_sync_s: f64,
    /// Step 2: work-request submission to the RNIC (doorbell/BlueFlame).
    pub submit_s: f64,
    /// Steps 3–4: RNIC processing + wire propagation + switch hops.
    pub wire_s: f64,
    /// Step 5: receiver-side completion detection (CPU poll vs GPU poll).
    pub recv_sync_s: f64,
    /// Step 6: consumer GPU kernel launch (0 if pre-launched polling kernel).
    pub kernel_launch_s: f64,
    /// Achievable fraction of the physical line rate for large messages.
    pub bw_efficiency: f64,
}

/// 400 Gbps RoCE line rate in bytes/s (the paper's testbed NICs).
pub const LINE_RATE_400G: f64 = 50e9;
/// 200 Gbps variant (TPU v6e hosts in Table 1).
pub const LINE_RATE_200G: f64 = 25e9;

impl NetStackModel {
    /// Fixed (size-independent) one-way overhead.
    pub fn fixed_overhead(&self) -> f64 {
        self.host_sync_s + self.submit_s + self.wire_s + self.recv_sync_s + self.kernel_launch_s
    }

    /// One-way latency for a message of `bytes` on a link of `line_rate`.
    pub fn one_way(&self, bytes: f64, line_rate: f64) -> f64 {
        self.fixed_overhead() + bytes / (line_rate * self.bw_efficiency)
    }

    /// Ping-pong round-trip time (the Fig. 13 metric): data out + data back.
    pub fn rtt(&self, bytes: f64, line_rate: f64) -> f64 {
        2.0 * self.one_way(bytes, line_rate)
    }

    /// Effective ping-pong bandwidth at `bytes` (Fig. 13 right panel).
    pub fn effective_bw(&self, bytes: f64, line_rate: f64) -> f64 {
        bytes / self.one_way(bytes, line_rate)
    }
}

/// Fully host-bypassed network stack (the paper's contribution):
/// GPU-driven BlueFlame WR submission, device-side sequence-number polling,
/// pre-launched consumer kernels. No host CPU anywhere on the path.
pub const FHBN: NetStackModel = NetStackModel {
    name: "FHBN",
    host_sync_s: 0.0,        // GPU submits directly; no CPU wait
    submit_s: 1.5e-6,        // BlueFlame mmio write from device code
    wire_s: 9.0e-6,          // RNIC pipeline + switch + propagation
    recv_sync_s: 6.0e-6,     // device-side seqno poll detection
    kernel_launch_s: 0.0,    // polling kernel pre-launched on stream
    bw_efficiency: 0.914,    // paper: 45.7 GB/s of 50 GB/s line
};

/// NCCL with GPUDirect RDMA: data path bypasses host memory but the control
/// path (steps 1–6 in §4.1) still runs on the CPUs.
pub const NCCL: NetStackModel = NetStackModel {
    name: "NCCL",
    host_sync_s: 9.0e-6,     // cudaStreamSynchronize before send
    submit_s: 3.0e-6,        // ibv_post_send + doorbell from host
    wire_s: 9.0e-6,
    recv_sync_s: 5.0e-6,     // CPU polls CQ
    kernel_launch_s: 7.3e-6, // launch of the consumer kernel
    bw_efficiency: 0.71,     // paper: 35.5 GB/s of 50 GB/s line
};

/// NCCL with GPUDirect RDMA disabled: data staged through host memory —
/// extra PCIe copies shrink bandwidth and add latency.
pub const NCCL_NO_GDR: NetStackModel = NetStackModel {
    name: "NCCL-noGDR",
    host_sync_s: 9.0e-6,
    submit_s: 3.0e-6,
    wire_s: 9.0e-6,
    recv_sync_s: 13.0e-6,    // + host-buffer copy in/out windows
    kernel_launch_s: 7.3e-6,
    bw_efficiency: 0.42,     // bounded by PCIe staging pipeline
};

/// Gloo: CPU-orchestrated transport, host-memory staging, no GPU awareness.
pub const GLOO: NetStackModel = NetStackModel {
    name: "Gloo",
    host_sync_s: 12.0e-6,
    submit_s: 6.0e-6,
    wire_s: 14.0e-6,
    recv_sync_s: 20.0e-6,
    kernel_launch_s: 7.3e-6,
    bw_efficiency: 0.30,
};

pub const ALL_STACKS: &[&NetStackModel] = &[&FHBN, &NCCL, &NCCL_NO_GDR, &GLOO];

pub fn stack_by_name(name: &str) -> Option<&'static NetStackModel> {
    ALL_STACKS
        .iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMALL: f64 = 8.0; // bytes — latency-dominated regime

    #[test]
    fn fhbn_small_rtt_33us() {
        let rtt = FHBN.rtt(SMALL, LINE_RATE_400G);
        assert!((rtt - 33.0e-6).abs() < 1.0e-6, "rtt={:.1}µs", rtt * 1e6);
    }

    #[test]
    fn nccl_small_rtt_66us() {
        let rtt = NCCL.rtt(SMALL, LINE_RATE_400G);
        assert!((rtt - 66.6e-6).abs() < 1.5e-6, "rtt={:.1}µs", rtt * 1e6);
    }

    #[test]
    fn fhbn_cuts_nccl_by_half() {
        // Paper: 50.5 % reduction.
        let cut = 1.0 - FHBN.rtt(SMALL, LINE_RATE_400G) / NCCL.rtt(SMALL, LINE_RATE_400G);
        assert!((cut - 0.505).abs() < 0.03, "cut={cut}");
    }

    #[test]
    fn fhbn_peak_bw_45_7() {
        // 1 GiB message: overhead amortised away.
        let bw = FHBN.effective_bw(1e9, LINE_RATE_400G);
        assert!((bw - 45.7e9).abs() / 45.7e9 < 0.02, "bw={:.1} GB/s", bw / 1e9);
    }

    #[test]
    fn nccl_peak_bw_35_5() {
        let bw = NCCL.effective_bw(1e9, LINE_RATE_400G);
        assert!((bw - 35.5e9).abs() / 35.5e9 < 0.02, "bw={:.1} GB/s", bw / 1e9);
    }

    #[test]
    fn stack_ordering_holds_at_all_sizes() {
        // FHBN ≤ NCCL ≤ NCCL-noGDR ≤ Gloo for every message size.
        let mut size = 8.0;
        while size <= 1e9 {
            let times: Vec<f64> = ALL_STACKS
                .iter()
                .map(|s| s.rtt(size, LINE_RATE_400G))
                .collect();
            for w in times.windows(2) {
                assert!(w[0] <= w[1] * 1.0001, "ordering broken at {size}B: {times:?}");
            }
            size *= 4.0;
        }
    }

    #[test]
    fn bandwidth_asymptote_monotone() {
        // Effective bandwidth must increase with message size.
        let mut prev = 0.0;
        for bytes in [1e3, 1e4, 1e5, 1e6, 1e7, 1e8] {
            let bw = FHBN.effective_bw(bytes, LINE_RATE_400G);
            assert!(bw > prev);
            prev = bw;
        }
    }

    #[test]
    fn lookup() {
        assert_eq!(stack_by_name("fhbn").unwrap().name, "FHBN");
        assert_eq!(stack_by_name("NCCL-noGDR").unwrap().bw_efficiency, 0.42);
        assert!(stack_by_name("tcp").is_none());
    }

    #[test]
    fn line_rate_scales_transfer() {
        let t400 = FHBN.one_way(1e8, LINE_RATE_400G);
        let t200 = FHBN.one_way(1e8, LINE_RATE_200G);
        assert!(t200 > 1.8 * t400 && t200 < 2.2 * t400);
    }
}
