//! In-process channel transport with simulated network pacing — one of the
//! two wires behind the [`crate::net::Transport`] API.
//!
//! The serving pipeline's leader↔worker links come in two flavours:
//!
//! * **this module** (via the [`crate::net::inproc`] adapter,
//!   `--transport inproc`): payloads cross threads over an `mpsc` channel,
//!   delivery is paced by the calibrated [`NetStackModel`], and byte
//!   accounting is *logical* — the `bytes` argument to [`Port::send`] is
//!   `WireMsg::wire_bytes()`, never a serialized size. Tensors stay
//!   zero-copy (`HostTensor` views share `Arc` buffers, mirroring RDMA's
//!   no-intermediate-copy property).
//! * **`crate::net::tcp`** (`--transport tcp`): the same messages are
//!   serialized through `net::codec` into length-prefixed checksummed
//!   frames and carried by real loopback sockets, with *measured* frame
//!   bytes recorded next to the logical model. That path validates this
//!   one: the `net_e2e` tests assert bit-identical decode outputs and a
//!   bounded measured/logical overhead ratio.
//!
//! A `time_scale` of 0 disables pacing for pure-functional tests; 1.0
//! reproduces the modelled latencies in wall-clock.
//!
//! Each link serialises its transfers (a 400 Gbps NIC is a shared resource):
//! a send occupies the link for `bytes / effective_bw`, and deliveries are
//! ordered accordingly — the same contention the per-device NIC model in the
//! serving simulator applies analytically.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::stack::NetStackModel;

/// Counters shared by both ports of a link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
    /// Total simulated time the wire was busy (seconds).
    pub busy_s: f64,
}

struct LinkShared {
    stats: Mutex<LinkStats>,
    /// Next instant at which the wire is free (per direction).
    wire_free: [Mutex<Instant>; 2],
}

struct Packet<T> {
    deliver_at: Instant,
    payload: T,
    bytes: usize,
}

/// One endpoint of a bidirectional simulated link.
pub struct Port<T: Send> {
    tx: Sender<Packet<T>>,
    rx: Receiver<Packet<T>>,
    shared: Arc<LinkShared>,
    stack: &'static NetStackModel,
    line_rate: f64,
    time_scale: f64,
    dir: usize,
}

/// Create a bidirectional link; returns the two endpoints.
pub fn link<T: Send>(
    stack: &'static NetStackModel,
    line_rate: f64,
    time_scale: f64,
) -> (Port<T>, Port<T>) {
    let (atx, arx) = channel();
    let (btx, brx) = channel();
    let shared = Arc::new(LinkShared {
        stats: Mutex::new(LinkStats::default()),
        wire_free: [Mutex::new(Instant::now()), Mutex::new(Instant::now())],
    });
    (
        Port {
            tx: atx,
            rx: brx,
            shared: Arc::clone(&shared),
            stack,
            line_rate,
            time_scale,
            dir: 0,
        },
        Port {
            tx: btx,
            rx: arx,
            shared,
            stack,
            line_rate,
            time_scale,
            dir: 1,
        },
    )
}

impl<T: Send> Port<T> {
    /// Send `payload` accounting `bytes` on the wire. Non-blocking: the
    /// latency is charged to the *receiver's* delivery time, as with a real
    /// asynchronous RDMA write.
    pub fn send(&self, payload: T, bytes: usize) -> Result<(), String> {
        let now = Instant::now();
        let serialise = bytes as f64 / (self.line_rate * self.stack.bw_efficiency);
        let oneway = self.stack.fixed_overhead() + serialise;

        // Wire contention: this transfer starts when the wire frees up.
        let deliver_at = {
            let mut free = self.shared.wire_free[self.dir]
                .lock()
                .map_err(|_| "link poisoned")?;
            let start = (*free).max(now);
            let done = start + Duration::from_secs_f64(serialise * self.time_scale);
            *free = done;
            done + Duration::from_secs_f64(
                (oneway - serialise).max(0.0) * self.time_scale,
            )
        };

        {
            let mut st = self.shared.stats.lock().map_err(|_| "stats poisoned")?;
            st.messages += 1;
            st.bytes += bytes as u64;
            st.busy_s += serialise;
        }

        self.tx
            .send(Packet { deliver_at, payload, bytes })
            .map_err(|_| "peer port dropped".to_string())
    }

    /// Blocking receive honouring the simulated delivery time.
    pub fn recv(&self) -> Result<(T, usize), String> {
        let pkt = self.rx.recv().map_err(|_| "peer port dropped")?;
        let now = Instant::now();
        if pkt.deliver_at > now {
            std::thread::sleep(pkt.deliver_at - now);
        }
        Ok((pkt.payload, pkt.bytes))
    }

    /// Receive with timeout (returns Ok(None) on timeout).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<(T, usize)>, String> {
        match self.rx.recv_timeout(timeout) {
            Ok(pkt) => {
                let now = Instant::now();
                if pkt.deliver_at > now {
                    std::thread::sleep(pkt.deliver_at - now);
                }
                Ok(Some((pkt.payload, pkt.bytes)))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err("peer port dropped".into()),
        }
    }

    pub fn stats(&self) -> LinkStats {
        let st = self.shared.stats.lock().expect("stats poisoned");
        LinkStats { messages: st.messages, bytes: st.bytes, busy_s: st.busy_s }
    }

    /// The modelled one-way latency for a message of `bytes` (seconds,
    /// unscaled). Exposed so schedulers can plan around it.
    pub fn model_one_way(&self, bytes: usize) -> f64 {
        self.stack.one_way(bytes as f64, self.line_rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::stack::{FHBN, LINE_RATE_400G, NCCL};

    #[test]
    fn roundtrip_payload_intact() {
        let (a, b) = link::<Vec<u8>>(&FHBN, LINE_RATE_400G, 0.0);
        let data = vec![1u8, 2, 3, 4, 5];
        a.send(data.clone(), 5).unwrap();
        let (got, bytes) = b.recv().unwrap();
        assert_eq!(got, data);
        assert_eq!(bytes, 5);
    }

    #[test]
    fn bidirectional() {
        let (a, b) = link::<u32>(&FHBN, LINE_RATE_400G, 0.0);
        a.send(1, 4).unwrap();
        b.send(2, 4).unwrap();
        assert_eq!(b.recv().unwrap().0, 1);
        assert_eq!(a.recv().unwrap().0, 2);
    }

    #[test]
    fn threaded_echo() {
        let (a, b) = link::<Vec<f32>>(&NCCL, LINE_RATE_400G, 0.0);
        let h = std::thread::spawn(move || {
            let (mut v, n) = b.recv().unwrap();
            v.iter_mut().for_each(|x| *x *= 2.0);
            b.send(v, n).unwrap();
        });
        a.send(vec![1.0, 2.0], 8).unwrap();
        let (out, _) = a.recv().unwrap();
        assert_eq!(out, vec![2.0, 4.0]);
        h.join().unwrap();
    }

    #[test]
    fn pacing_delays_delivery() {
        // Scale up so the modelled 16.5 µs one-way becomes measurable.
        let (a, b) = link::<u8>(&FHBN, LINE_RATE_400G, 500.0);
        let t0 = Instant::now();
        a.send(0, 8).unwrap();
        b.recv().unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let expect = FHBN.one_way(8.0, LINE_RATE_400G) * 500.0;
        assert!(elapsed >= expect * 0.8, "elapsed={elapsed} expect={expect}");
    }

    #[test]
    fn stats_accumulate() {
        let (a, b) = link::<u8>(&FHBN, LINE_RATE_400G, 0.0);
        for i in 0..10 {
            a.send(i, 100).unwrap();
        }
        for _ in 0..10 {
            b.recv().unwrap();
        }
        let st = a.stats();
        assert_eq!(st.messages, 10);
        assert_eq!(st.bytes, 1000);
        assert!(st.busy_s > 0.0);
    }

    #[test]
    fn send_to_dropped_peer_errors() {
        let (a, b) = link::<u8>(&FHBN, LINE_RATE_400G, 0.0);
        drop(b);
        assert!(a.send(1, 1).is_err());
    }

    #[test]
    fn recv_timeout_none() {
        let (a, _b) = link::<u8>(&FHBN, LINE_RATE_400G, 0.0);
        let got = a.recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_none());
    }
}
