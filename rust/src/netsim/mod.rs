//! Network substrate: calibrated stack models (FHBN/NCCL/NCCL-noGDR/Gloo),
//! the Fig. 13 ping-pong microbench, and the paced in-process transport the
//! real serving pipeline moves bytes over.

pub mod pingpong;
pub mod stack;
pub mod transport;

pub use stack::{NetStackModel, FHBN, GLOO, LINE_RATE_400G, NCCL, NCCL_NO_GDR};
