//! Ping-pong microbenchmark (paper §6.3, Fig. 13).
//!
//! Reproduces the two-GPU ping-pong: the initiator sends `bytes`, the remote
//! echoes them back; RTT is measured "from the completion of the kernel that
//! generates the data to the start of the kernel that consumes it". Here the
//! timing comes from the calibrated stack models; the *data path* can also be
//! exercised for real through [`super::transport`] (bytes actually move
//! between threads) to validate the plumbing.

use super::stack::{NetStackModel, ALL_STACKS};

/// One measured point of the Fig. 13 series.
#[derive(Debug, Clone)]
pub struct PingPongPoint {
    pub stack: &'static str,
    pub bytes: f64,
    pub rtt_s: f64,
    /// One-direction effective bandwidth at this size.
    pub bw_bytes_per_s: f64,
}

/// Standard Fig. 13 sweep: 8 B … 1 GiB, powers of 4.
pub fn default_sizes() -> Vec<f64> {
    let mut v = Vec::new();
    let mut s = 8.0;
    while s <= 1.1e9 {
        v.push(s);
        s *= 4.0;
    }
    v
}

/// Run the analytic ping-pong for every stack at the given sizes.
pub fn sweep(sizes: &[f64], line_rate: f64) -> Vec<PingPongPoint> {
    let mut out = Vec::new();
    for stack in ALL_STACKS {
        for &bytes in sizes {
            out.push(point(stack, bytes, line_rate));
        }
    }
    out
}

pub fn point(stack: &NetStackModel, bytes: f64, line_rate: f64) -> PingPongPoint {
    PingPongPoint {
        stack: stack.name,
        bytes,
        rtt_s: stack.rtt(bytes, line_rate),
        bw_bytes_per_s: stack.effective_bw(bytes, line_rate),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::stack::LINE_RATE_400G;

    #[test]
    fn sweep_covers_all_stacks_and_sizes() {
        let sizes = default_sizes();
        let pts = sweep(&sizes, LINE_RATE_400G);
        assert_eq!(pts.len(), sizes.len() * ALL_STACKS.len());
        assert!(sizes.len() >= 10);
    }

    #[test]
    fn rtt_monotone_in_size() {
        let sizes = default_sizes();
        for stack in ALL_STACKS {
            let mut prev = 0.0;
            for &s in &sizes {
                let p = point(stack, s, LINE_RATE_400G);
                assert!(p.rtt_s >= prev);
                prev = p.rtt_s;
            }
        }
    }

    #[test]
    fn small_message_latency_dominated() {
        // Below ~64 KiB the RTT barely moves (latency floor).
        let a = point(&crate::netsim::stack::FHBN, 8.0, LINE_RATE_400G);
        let b = point(&crate::netsim::stack::FHBN, 4096.0, LINE_RATE_400G);
        assert!(b.rtt_s / a.rtt_s < 1.02);
    }
}
