//! Block-table-native decode attention: pure-Rust online-softmax kernels
//! that read the [`PagedKvArena`] **in place** — in whatever dtype the
//! arena stores its blocks.
//!
//! Where the engine path stages `[bucket, KH_s, seq_bucket, hd]` f32 K/V
//! copies per layer per step (widening quantized storage on gather), these
//! kernels take the per-slot block lists as input and walk the arena's
//! per-layer block buffers directly: each live KV byte is read exactly
//! once, copied never, and — with `--kv-dtype f16|int8` — **dequantized
//! in-register** inside the dot/axpy inner loops ([`KvBlockRef`] lanes: an
//! f16 region is bulk-widened one stack tile at a time via the chunked
//! branchless widen in [`crate::kvcache::quant`]; an int8 K region folds
//! its per-(block, head) scale into the softmax scale, and a V region
//! folds it into the accumulation weight), with no heap staging buffer.
//! Per-step KV bytes *read* therefore drop 2×/≈4× with the storage dtype;
//! the per-row working set is charged to [`kv_reads`] so benches can prove
//! it. See the module docs of [`crate::kernels`] for the data path and the
//! recurrence.
//!
//! The scalar inner loops are unrolled into four accumulator lanes
//! (autovectorizer-friendly), fused via `f32::mul_add` **only where the
//! target actually has FMA** (x86-64 with `+fma`, aarch64) — on a
//! baseline x86-64 target `mul_add` lowers to an `fmaf` libcall per lane,
//! which would be slower than the naive loop, so those targets take a
//! plain multiply-then-add unroll instead (see the `fma` helper). Either
//! way the unroll reassociates sums relative to a naive loop — which
//! is fine, because kernel agreement is tolerance-tested against the
//! two-pass reference (`tests/kernel_native.rs`), never bit-pinned: the
//! golden-token tests pin the `engine` backend's semantics precisely so
//! kernel-level reassociation stays a tolerance question.
//!
//! All kernels are deterministic for any parallelism ([`Par`]): batch rows
//! are independent and each row's arithmetic is sequential, so one thread,
//! N scoped threads, and the persistent [`ScopedPool`] produce
//! bit-identical outputs.

use crate::kvcache::arena::{KvBlockRef, PAD_SLOT};
use crate::kvcache::quant::f16_bits_widen;
use crate::kvcache::PagedKvArena;
use crate::obs;
use crate::runtime::host::{kv_reads, HostTensor};
use crate::util::threadpool::{Par, ScopedPool};

use super::{AttnBackend, AttnBackendKind, PartialState};

/// Mask value for invalid positions; finite so softmax stays NaN-free
/// (mirrors the Pallas kernels' `NEG_INF`).
pub const NEG_INF: f32 = -1e30;

/// `a*b + acc`, fused where fusing is free: `f32::mul_add` needs hardware
/// FMA to be one instruction — without it LLVM must preserve the
/// single-rounding semantics through an `fmaf` libcall per element, which
/// would dominate the inner loops on baseline x86-64. Targets without FMA
/// get the plain two-op form (double rounding; covered by the kernels'
/// tolerance contract). The choice is compile-time per build, so outputs
/// stay bit-identical across thread counts and executors.
#[inline(always)]
fn fma(a: f32, b: f32, acc: f32) -> f32 {
    #[cfg(any(target_feature = "fma", target_arch = "aarch64"))]
    {
        a.mul_add(b, acc)
    }
    #[cfg(not(any(target_feature = "fma", target_arch = "aarch64")))]
    {
        a * b + acc
    }
}

/// Dot product with four accumulator lanes (fused via `fma` where the
/// target has FMA) — the kernel's K inner
/// loop (`pub` so the bench suite can pit it against a naive sequential
/// loop; see `kernel/inner-loop` rows in `BENCH_decode.json`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 = fma(x[0], y[0], s0);
        s1 = fma(x[1], y[1], s1);
        s2 = fma(x[2], y[2], s2);
        s3 = fma(x[3], y[3], s3);
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s0 = fma(*x, *y, s0);
    }
    (s0 + s1) + (s2 + s3)
}

/// `acc += e · v`, four lanes — the kernel's V inner loop (`pub`
/// for the same bench comparison as [`dot`]).
#[inline]
pub fn axpy(acc: &mut [f32], e: f32, v: &[f32]) {
    let mut cv = v.chunks_exact(4);
    let mut i = 0;
    for y in &mut cv {
        acc[i] = fma(e, y[0], acc[i]);
        acc[i + 1] = fma(e, y[1], acc[i + 1]);
        acc[i + 2] = fma(e, y[2], acc[i + 2]);
        acc[i + 3] = fma(e, y[3], acc[i + 3]);
        i += 4;
    }
    for y in cv.remainder() {
        acc[i] = fma(e, *y, acc[i]);
        i += 1;
    }
}

/// f16 widen tile: lanes bulk-widened at a time. One cache line of f32 —
/// big enough to amortize the widen, small enough to zero-init for free.
const F16_TILE: usize = 32;

/// [`dot`] against bit-cast f16 lanes. Lanes are widened a [`F16_TILE`] at
/// a time through the chunked bulk widen ([`f16_bits_widen`], the
/// branchless multiply-rebias form) into a stack tile, replacing the old
/// per-lane branchy widen that ROADMAP flagged as the f16 decode
/// bottleneck. The fma quads then consume the tile in exactly the order
/// the per-lane version used (4 accumulator lanes, remainder into `s0`),
/// so results stay bit-identical — the widen itself is exact.
#[inline]
fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
    let mut buf = [0.0f32; F16_TILE];
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i + F16_TILE <= b.len() {
        f16_bits_widen(&b[i..i + F16_TILE], &mut buf);
        let x = &a[i..i + F16_TILE];
        for c in 0..F16_TILE / 4 {
            s0 = fma(x[4 * c], buf[4 * c], s0);
            s1 = fma(x[4 * c + 1], buf[4 * c + 1], s1);
            s2 = fma(x[4 * c + 2], buf[4 * c + 2], s2);
            s3 = fma(x[4 * c + 3], buf[4 * c + 3], s3);
        }
        i += F16_TILE;
    }
    let r = b.len() - i;
    f16_bits_widen(&b[i..], &mut buf[..r]);
    let x = &a[i..];
    let mut j = 0;
    while j + 4 <= r {
        s0 = fma(x[j], buf[j], s0);
        s1 = fma(x[j + 1], buf[j + 1], s1);
        s2 = fma(x[j + 2], buf[j + 2], s2);
        s3 = fma(x[j + 3], buf[j + 3], s3);
        j += 4;
    }
    while j < r {
        s0 = fma(x[j], buf[j], s0);
        j += 1;
    }
    (s0 + s1) + (s2 + s3)
}

/// `acc += e · widen(v)` over f16 lanes: bulk-widen per [`F16_TILE`] like
/// [`dot_f16`], then the same 4-lane [`axpy`] unroll over the tile
/// (bit-identical op order to the per-lane version).
#[inline]
fn axpy_f16(acc: &mut [f32], e: f32, v: &[u16]) {
    let mut buf = [0.0f32; F16_TILE];
    let mut i = 0;
    while i + F16_TILE <= v.len() {
        f16_bits_widen(&v[i..i + F16_TILE], &mut buf);
        for c in 0..F16_TILE / 4 {
            acc[i + 4 * c] = fma(e, buf[4 * c], acc[i + 4 * c]);
            acc[i + 4 * c + 1] = fma(e, buf[4 * c + 1], acc[i + 4 * c + 1]);
            acc[i + 4 * c + 2] = fma(e, buf[4 * c + 2], acc[i + 4 * c + 2]);
            acc[i + 4 * c + 3] = fma(e, buf[4 * c + 3], acc[i + 4 * c + 3]);
        }
        i += F16_TILE;
    }
    let r = v.len() - i;
    f16_bits_widen(&v[i..], &mut buf[..r]);
    let mut j = 0;
    while j + 4 <= r {
        acc[i + j] = fma(e, buf[j], acc[i + j]);
        acc[i + j + 1] = fma(e, buf[j + 1], acc[i + j + 1]);
        acc[i + j + 2] = fma(e, buf[j + 2], acc[i + j + 2]);
        acc[i + j + 3] = fma(e, buf[j + 3], acc[i + j + 3]);
        j += 4;
    }
    while j < r {
        acc[i + j] = fma(e, buf[j], acc[i + j]);
        j += 1;
    }
}

/// [`dot`] against int8 codes: accumulates `q · code` and lets the caller
/// multiply the region scale in once at the end (fewer multiplies than
/// dequantizing every lane).
#[inline]
fn dot_i8(a: &[f32], b: &[i8]) -> f32 {
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 = fma(x[0], y[0] as f32, s0);
        s1 = fma(x[1], y[1] as f32, s1);
        s2 = fma(x[2], y[2] as f32, s2);
        s3 = fma(x[3], y[3] as f32, s3);
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s0 = fma(*x, *y as f32, s0);
    }
    (s0 + s1) + (s2 + s3)
}

/// `acc += (e·scale) · code` over int8 lanes — the V scale is folded into
/// the accumulation weight, so the loop body is one fused op per lane;
/// same 4-lane unroll as [`axpy`].
#[inline]
fn axpy_i8(acc: &mut [f32], e_scaled: f32, v: &[i8]) {
    let mut cv = v.chunks_exact(4);
    let mut i = 0;
    for y in &mut cv {
        acc[i] = fma(e_scaled, y[0] as f32, acc[i]);
        acc[i + 1] = fma(e_scaled, y[1] as f32, acc[i + 1]);
        acc[i + 2] = fma(e_scaled, y[2] as f32, acc[i + 2]);
        acc[i + 3] = fma(e_scaled, y[3] as f32, acc[i + 3]);
        i += 4;
    }
    for y in cv.remainder() {
        acc[i] = fma(e_scaled, *y as f32, acc[i]);
        i += 1;
    }
}

/// One block's V lanes in storage dtype (int8 carries the region scale).
#[derive(Clone, Copy)]
enum VLanes<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    I8(&'a [i8], f32),
}

/// Online-softmax state for one query vector.
struct Online<'a> {
    m: f32,
    ssum: f32,
    acc: &'a mut [f32],
}

impl<'a> Online<'a> {
    /// `acc` must be zeroed by the caller.
    fn new(acc: &'a mut [f32]) -> Online<'a> {
        Online { m: NEG_INF, ssum: 0.0, acc }
    }

    /// Fold one block of `cnt` scored tokens in: `scores[t]` with value rows
    /// `t·hd..` of `vb`, dequantized in-register per lane.
    fn fold_block(&mut self, scores: &[f32], vb: VLanes<'_>, cnt: usize, hd: usize) {
        let mut bm = NEG_INF;
        for &s in &scores[..cnt] {
            if s > bm {
                bm = s;
            }
        }
        let m_new = if bm > self.m { bm } else { self.m };
        let corr = (self.m - m_new).exp();
        self.ssum *= corr;
        for a in self.acc.iter_mut() {
            *a *= corr;
        }
        match vb {
            VLanes::F32(vb) => {
                for t in 0..cnt {
                    let e = (scores[t] - m_new).exp();
                    self.ssum += e;
                    axpy(self.acc, e, &vb[t * hd..][..hd]);
                }
            }
            VLanes::F16(vb) => {
                for t in 0..cnt {
                    let e = (scores[t] - m_new).exp();
                    self.ssum += e;
                    axpy_f16(self.acc, e, &vb[t * hd..][..hd]);
                }
            }
            VLanes::I8(vb, scale) => {
                for t in 0..cnt {
                    let e = (scores[t] - m_new).exp();
                    self.ssum += e;
                    axpy_i8(self.acc, e * scale, &vb[t * hd..][..hd]);
                }
            }
        }
        self.m = m_new;
    }

    /// Fold a single extra token (score `s`, value `vt`) in.
    fn fold_one(&mut self, s: f32, vt: &[f32]) {
        let m_new = if s > self.m { s } else { self.m };
        let corr = (self.m - m_new).exp();
        self.ssum *= corr;
        let e = (s - m_new).exp();
        self.ssum += e;
        for (a, &v) in self.acc.iter_mut().zip(vt) {
            *a = *a * corr + e * v;
        }
        self.m = m_new;
    }

    /// Normalise `acc` in place (`A/S`); no-op on the empty state.
    fn normalize(&mut self) {
        if self.ssum > 0.0 {
            let inv = 1.0 / self.ssum;
            for a in self.acc.iter_mut() {
                *a *= inv;
            }
        }
    }
}

/// Run the online recurrence over one slot's cached prefix `[0, n)` for one
/// (head, group-query): walks the block table in logical-token order,
/// borrowing each block's K/V region from the arena in the storage dtype
/// (no copies; f16/int8 lanes are widened in-register).
#[allow(clippy::too_many_arguments)]
fn fold_cached(
    st: &mut Online,
    arena: &PagedKvArena,
    slot: u32,
    layer: usize,
    head: usize,
    qv: &[f32],
    n: usize,
    scale: f32,
    scores: &mut [f32],
) {
    let bs = arena.block_size();
    let hd = qv.len();
    let table = arena.table_view(slot);
    for (bi, &blk) in table.blocks().iter().enumerate() {
        let tok0 = bi * bs;
        if tok0 >= n {
            break;
        }
        let cnt = bs.min(n - tok0);
        match arena.block_slices(layer, blk, head) {
            KvBlockRef::F32 { k: kb, v: vb } => {
                for t in 0..cnt {
                    scores[t] = dot(qv, &kb[t * hd..][..hd]) * scale;
                }
                st.fold_block(scores, VLanes::F32(vb), cnt, hd);
            }
            KvBlockRef::F16 { k: kb, v: vb } => {
                for t in 0..cnt {
                    scores[t] = dot_f16(qv, &kb[t * hd..][..hd]) * scale;
                }
                st.fold_block(scores, VLanes::F16(vb), cnt, hd);
            }
            KvBlockRef::Int8 { k: kb, v: vb, k_scale, v_scale } => {
                // fold the K region scale into the softmax scale: one
                // multiply per score instead of one per lane
                let ks = scale * k_scale;
                for t in 0..cnt {
                    scores[t] = dot_i8(qv, &kb[t * hd..][..hd]) * ks;
                }
                st.fold_block(scores, VLanes::I8(vb, v_scale), cnt, hd);
            }
        }
    }
}

/// Valid cached length of `slot` for a row: `len` clamped to the seq bucket
/// and to what the table actually holds (pad rows → 0).
fn row_n(arena: &PagedKvArena, slot: u32, len: i32, seq_bucket: usize) -> usize {
    if slot == PAD_SLOT {
        return 0;
    }
    (len.max(0) as usize)
        .min(seq_bucket)
        .min(arena.table_view(slot).len_tokens())
}

/// Full decode attention over the block tables — the native replacement for
/// gather + `attention` artifact. Row `b` of `q` (`[bucket, H_s, hd]`)
/// attends the first `lens[b]` cached tokens of `slots[b]` (`lens` includes
/// this step's already-appended token). Pad rows yield zero rows, matching
/// the engine path's output on zero-padded gathers. Returns
/// `[bucket, H_s, hd]`.
pub fn paged_attn(
    arena: &PagedKvArena,
    slots: &[u32],
    layer: usize,
    q: &HostTensor,
    lens: &[i32],
    seq_bucket: usize,
    par: Par<'_>,
) -> HostTensor {
    let shape = q.shape();
    assert_eq!(shape.len(), 3, "q must be [bucket, H_s, hd]");
    let (bucket, hs, hd) = (shape[0], shape[1], shape[2]);
    assert_eq!(slots.len(), bucket);
    assert_eq!(lens.len(), bucket);
    let khs = arena.kv_heads();
    assert_eq!(hd, arena.head_dim());
    assert_eq!(hs % khs, 0, "query heads must divide into kv heads");
    let g = hs / khs;
    let scale = 1.0 / (hd as f32).sqrt();
    let qd = q.as_f32();
    let bs = arena.block_size();

    let rows: Vec<usize> = (0..bucket).collect();
    let out_rows = par.map(&rows, |&b| {
        let mut out = vec![0.0f32; hs * hd];
        let n = row_n(arena, slots[b], lens[b], seq_bucket);
        if n == 0 {
            return out;
        }
        kv_reads::add(arena.kv_read_bytes(n));
        let qrow = &qd[b * hs * hd..][..hs * hd];
        let mut scores = vec![0.0f32; bs];
        for h in 0..khs {
            for gi in 0..g {
                let qi = (h * g + gi) * hd;
                let qv = &qrow[qi..qi + hd];
                let acc = &mut out[qi..qi + hd];
                let mut st = Online::new(acc);
                fold_cached(&mut st, arena, slots[b], layer, h, qv, n, scale, &mut scores);
                st.normalize();
            }
        }
        out
    });

    let mut out = Vec::with_capacity(bucket * hs * hd);
    for r in out_rows {
        out.extend_from_slice(&r);
    }
    HostTensor::f32(vec![bucket, hs, hd], out)
}

/// Partial attention over the cached tokens only (overlap path, §4.2.2) —
/// the native replacement for gather + `attn_prev` artifact. Returns the
/// max-stabilised `(A, S, m)` state; rows with no cached tokens (including
/// pad rows) yield `(0, 0, NEG_INF)`, exactly the reference's empty state.
pub fn paged_attn_prev(
    arena: &PagedKvArena,
    slots: &[u32],
    layer: usize,
    q: &HostTensor,
    lens: &[i32],
    seq_bucket: usize,
    par: Par<'_>,
) -> PartialState {
    let shape = q.shape();
    assert_eq!(shape.len(), 3, "q must be [bucket, H_s, hd]");
    let (bucket, hs, hd) = (shape[0], shape[1], shape[2]);
    assert_eq!(slots.len(), bucket);
    assert_eq!(lens.len(), bucket);
    let khs = arena.kv_heads();
    assert_eq!(hd, arena.head_dim());
    assert_eq!(hs % khs, 0, "query heads must divide into kv heads");
    let g = hs / khs;
    let scale = 1.0 / (hd as f32).sqrt();
    let qd = q.as_f32();
    let bs = arena.block_size();

    let rows: Vec<usize> = (0..bucket).collect();
    let out_rows = par.map(&rows, |&b| {
        let mut a = vec![0.0f32; hs * hd];
        let mut s = vec![0.0f32; hs];
        let mut m = vec![NEG_INF; hs];
        let n = row_n(arena, slots[b], lens[b], seq_bucket);
        if n == 0 {
            return (a, s, m);
        }
        kv_reads::add(arena.kv_read_bytes(n));
        let qrow = &qd[b * hs * hd..][..hs * hd];
        let mut scores = vec![0.0f32; bs];
        for h in 0..khs {
            for gi in 0..g {
                let hi = h * g + gi;
                let qv = &qrow[hi * hd..][..hd];
                let acc = &mut a[hi * hd..hi * hd + hd];
                let mut st = Online::new(acc);
                fold_cached(&mut st, arena, slots[b], layer, h, qv, n, scale, &mut scores);
                s[hi] = st.ssum;
                m[hi] = st.m;
            }
        }
        (a, s, m)
    });

    let mut a = Vec::with_capacity(bucket * hs * hd);
    let mut s = Vec::with_capacity(bucket * hs);
    let mut m = Vec::with_capacity(bucket * hs);
    for (ra, rs, rm) in out_rows {
        a.extend_from_slice(&ra);
        s.extend_from_slice(&rs);
        m.extend_from_slice(&rm);
    }
    PartialState {
        a: HostTensor::f32(vec![bucket, hs, hd], a),
        s: HostTensor::f32(vec![bucket, hs], s),
        m: HostTensor::f32(vec![bucket, hs], m),
    }
}

/// Fold the newly generated token into a partial attention state and
/// normalise — the native replacement for the `attn_combine` artifact.
/// `q` `[bucket, H_s, hd]`, `k_new`/`v_new` `[bucket, KH_s, hd]` (wire
/// tensors, always f32 — the new token never touches quantized storage
/// before this). O(B·H·hd) and serial (not worth fanning out).
pub fn combine_new_token(
    q: &HostTensor,
    k_new: &HostTensor,
    v_new: &HostTensor,
    prev: &PartialState,
) -> HostTensor {
    let shape = q.shape();
    let (bucket, hs, hd) = (shape[0], shape[1], shape[2]);
    let khs = k_new.shape()[1];
    let g = hs / khs;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.as_f32(), k_new.as_f32(), v_new.as_f32());
    let (ad, sd, md) = (prev.a.as_f32(), prev.s.as_f32(), prev.m.as_f32());

    let mut out = vec![0.0f32; bucket * hs * hd];
    for b in 0..bucket {
        for h in 0..khs {
            let kn = &kd[(b * khs + h) * hd..][..hd];
            let vn = &vd[(b * khs + h) * hd..][..hd];
            for gi in 0..g {
                let hi = h * g + gi;
                let qv = &qd[(b * hs + hi) * hd..][..hd];
                let s_new = dot(qv, kn) * scale;
                let m_prev = md[b * hs + hi];
                let m2 = if s_new > m_prev { s_new } else { m_prev };
                let c_prev = (m_prev - m2).exp();
                let c_new = (s_new - m2).exp();
                let denom = sd[b * hs + hi] * c_prev + c_new;
                let ap = &ad[(b * hs + hi) * hd..][..hd];
                let o = &mut out[(b * hs + hi) * hd..][..hd];
                for d in 0..hd {
                    o[d] = (ap[d] * c_prev + vn[d] * c_new) / denom;
                }
            }
        }
    }
    HostTensor::f32(vec![bucket, hs, hd], out)
}

/// Chunked-prefill attention for ONE request — the native replacement for
/// gather + `prefill_attn` artifact. Chunk row `i` of `q` (`[T, H_s, hd]`)
/// attends the slot's `cached` prefix (read in place from the block table)
/// plus chunk tokens `0..=i` of `k_new`/`v_new` (`[T, KH_s, hd]`,
/// causally). Must be called *before* the chunk is appended. Returns
/// `[T, H_s, hd]` (padding rows beyond `valid` are computed like the
/// artifact does — deterministically, and discarded by the leader).
#[allow(clippy::too_many_arguments)]
pub fn paged_prefill(
    arena: &PagedKvArena,
    slot: u32,
    layer: usize,
    q: &HostTensor,
    k_new: &HostTensor,
    v_new: &HostTensor,
    cached: usize,
    seq_bucket: usize,
    par: Par<'_>,
) -> HostTensor {
    let shape = q.shape();
    assert_eq!(shape.len(), 3, "q must be [T, H_s, hd]");
    let (t_rows, hs, hd) = (shape[0], shape[1], shape[2]);
    let khs = arena.kv_heads();
    assert_eq!(hd, arena.head_dim());
    assert_eq!(hs % khs, 0, "query heads must divide into kv heads");
    let g = hs / khs;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.as_f32(), k_new.as_f32(), v_new.as_f32());
    let n = row_n(arena, slot, cached as i32, seq_bucket);
    let bs = arena.block_size();

    let rows: Vec<usize> = (0..t_rows).collect();
    let out_rows = par.map(&rows, |&i| {
        let mut out = vec![0.0f32; hs * hd];
        kv_reads::add(arena.kv_read_bytes(n));
        let qrow = &qd[i * hs * hd..][..hs * hd];
        let mut scores = vec![0.0f32; bs];
        for h in 0..khs {
            for gi in 0..g {
                let qi = (h * g + gi) * hd;
                let qv = &qrow[qi..qi + hd];
                let acc = &mut out[qi..qi + hd];
                let mut st = Online::new(acc);
                // cached prefix, in place from the block table
                fold_cached(&mut st, arena, slot, layer, h, qv, n, scale, &mut scores);
                // intra-chunk causal tail: chunk tokens 0..=i (wire f32)
                for j in 0..=i {
                    let kt = &kd[(j * khs + h) * hd..][..hd];
                    let vt = &vd[(j * khs + h) * hd..][..hd];
                    let s = dot(qv, kt) * scale;
                    st.fold_one(s, vt);
                }
                st.normalize();
            }
        }
        out
    });

    let mut out = Vec::with_capacity(t_rows * hs * hd);
    for r in out_rows {
        out.extend_from_slice(&r);
    }
    HostTensor::f32(vec![t_rows, hs, hd], out)
}

/// Validate a wire `q`, `layer`, and slot ids against the arena geometry
/// (and, when given, the batch vectors) so a misconfigured worker reports a
/// `WorkerError` string instead of panicking its thread on the kernel
/// asserts or on an out-of-bounds arena index.
fn check_shapes(
    arena: &PagedKvArena,
    q: &HostTensor,
    layer: usize,
    slots: &[u32],
    batch: Option<&[i32]>,
) -> Result<(), String> {
    let shape = q.shape();
    if shape.len() != 3 {
        return Err(format!("q must be [rows, H_s, hd], got {shape:?}"));
    }
    let (hs, hd) = (shape[1], shape[2]);
    if hd != arena.head_dim() {
        return Err(format!(
            "head_dim mismatch: q has {hd}, arena has {} (bad ModelGeom?)",
            arena.head_dim()
        ));
    }
    if hs == 0 || hs % arena.kv_heads() != 0 {
        return Err(format!(
            "query heads ({hs}) must divide into kv heads ({})",
            arena.kv_heads()
        ));
    }
    if layer >= arena.layers() {
        return Err(format!("layer {layer} out of range ({} layers)", arena.layers()));
    }
    if let Some(&bad) = slots.iter().find(|&&s| s != PAD_SLOT && s as usize >= arena.slots()) {
        return Err(format!("slot {bad} out of range ({} slots)", arena.slots()));
    }
    if let Some(lens) = batch {
        if slots.len() != shape[0] || lens.len() != shape[0] {
            return Err(format!(
                "batch mismatch: q rows {}, slots {}, lens {}",
                shape[0],
                slots.len(),
                lens.len()
            ));
        }
    }
    Ok(())
}

/// Validate wire `k`/`v` against `q` (and the arena's shard heads when
/// known): same row count and head_dim, equal shapes, and a KV-head count
/// that divides the query heads. Keeps malformed `StepKv`/`PrefillChunk`
/// payloads from panicking the worker (out-of-range rows) or silently
/// producing zero output (`g == 0` when kv heads exceed query heads).
fn check_kv(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    shard_khs: Option<usize>,
) -> Result<(), String> {
    let (qs, ks, vs) = (q.shape(), k.shape(), v.shape());
    if ks.len() != 3 || ks != vs {
        return Err(format!("k/v must be matching [rows, KH_s, hd]: k {ks:?} v {vs:?}"));
    }
    if ks[0] != qs[0] || ks[2] != qs[2] {
        return Err(format!("k/v rows/head_dim mismatch: q {qs:?} vs k {ks:?}"));
    }
    let kh = ks[1];
    if kh == 0 || qs[1] % kh != 0 {
        return Err(format!("kv heads ({kh}) must divide query heads ({})", qs[1]));
    }
    if let Some(khs) = shard_khs {
        if kh != khs {
            return Err(format!("kv heads ({kh}) != arena shard heads ({khs})"));
        }
    }
    Ok(())
}

/// The block-table-native [`AttnBackend`]: runs the kernels above directly
/// over the arena. Needs no artifacts, performs zero per-step host copies
/// (nothing in this backend ever calls `copies::add`), consumes quantized
/// block storage natively, and parallelises across the batch on an owned
/// **persistent** [`ScopedPool`] — worker threads are spawned once at
/// backend construction and reused every layer step (no per-call spawns on
/// the decode hot loop).
#[derive(Debug, Clone)]
pub struct NativeBackend {
    pool: std::sync::Arc<ScopedPool>,
}

impl NativeBackend {
    /// Thread count: available parallelism, capped (attention rows are
    /// short; beyond a handful of threads coordination costs dominate).
    pub fn new() -> NativeBackend {
        let t = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NativeBackend::with_threads(t.min(8))
    }

    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend { pool: std::sync::Arc::new(ScopedPool::new(threads.max(1))) }
    }

    fn par(&self) -> Par<'_> {
        Par::Pool(self.pool.as_ref())
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl AttnBackend for NativeBackend {
    fn kind(&self) -> AttnBackendKind {
        AttnBackendKind::Native
    }

    fn attention(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<HostTensor, String> {
        check_shapes(arena, q, layer, slots, Some(lens))?;
        let _sp = obs::span("kernel", "paged_attn").arg("layer", layer as i64);
        Ok(paged_attn(arena, slots, layer, q, lens, seq_bucket, self.par()))
    }

    fn attn_prev(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<PartialState, String> {
        check_shapes(arena, q, layer, slots, Some(lens))?;
        let _sp = obs::span("kernel", "paged_attn_prev").arg("layer", layer as i64);
        Ok(paged_attn_prev(arena, slots, layer, q, lens, seq_bucket, self.par()))
    }

    fn attn_combine(
        &mut self,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        prev: &PartialState,
    ) -> Result<HostTensor, String> {
        if q.shape().len() != 3 {
            return Err(format!("q must be [bucket, H_s, hd], got {:?}", q.shape()));
        }
        check_kv(q, k, v, None)?;
        let heads = q.shape()[0] * q.shape()[1];
        if prev.a.len() != q.len() || prev.s.len() != heads || prev.m.len() != heads {
            return Err(format!(
                "partial state mismatch: q {:?}, A {:?}, S {:?}",
                q.shape(),
                prev.a.shape(),
                prev.s.shape()
            ));
        }
        let _sp = obs::span("kernel", "combine_new_token");
        Ok(combine_new_token(q, k, v, prev))
    }

    fn prefill(
        &mut self,
        arena: &mut PagedKvArena,
        slot: u32,
        layer: usize,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        cached: i32,
        seq_bucket: usize,
    ) -> Result<HostTensor, String> {
        check_shapes(arena, q, layer, std::slice::from_ref(&slot), None)?;
        check_kv(q, k, v, Some(arena.kv_heads()))?;
        let _sp = obs::span("kernel", "paged_prefill").arg("layer", layer as i64);
        Ok(paged_prefill(
            arena,
            slot,
            layer,
            q,
            k,
            v,
            cached.max(0) as usize,
            seq_bucket,
            self.par(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{ArenaCfg, KvDtype};

    fn arena_with(tokens: usize) -> (PagedKvArena, Vec<f32>) {
        arena_with_dtype(tokens, KvDtype::F32)
    }

    fn arena_with_dtype(tokens: usize, dtype: KvDtype) -> (PagedKvArena, Vec<f32>) {
        let mut arena = PagedKvArena::new(ArenaCfg {
            layers: 1,
            kv_heads: 2,
            head_dim: 4,
            max_seq: 64,
            slots: 2,
            block_size: 4,
            initial_blocks: 2,
            dtype,
        });
        let mut all = Vec::new();
        for t in 0..tokens {
            let kv: Vec<f32> = (0..2 * 4).map(|i| ((t * 17 + i * 3) % 11) as f32 * 0.25 - 1.0).collect();
            let kt = HostTensor::f32(vec![1, 2, 4], kv.clone());
            arena.append_step(&[0], 0, &kt, &kt, &[t as i32]);
            all.extend_from_slice(&kv);
        }
        (arena, all)
    }

    #[test]
    fn single_token_attention_returns_its_value() {
        // one cached token → softmax weight 1 → output == v of that token
        let (arena, kv) = arena_with(1);
        let q = HostTensor::f32(vec![1, 4, 4], (0..16).map(|i| i as f32 * 0.1).collect());
        let out = paged_attn(&arena, &[0], 0, &q, &[1], 8, Par::Threads(1));
        assert_eq!(out.shape(), &[1, 4, 4]);
        let od = out.as_f32();
        // H_s = 4, khs = 2 → G = 2: query heads 0,1 share kv head 0
        for gi in 0..2 {
            assert_eq!(&od[gi * 4..gi * 4 + 4], &kv[0..4], "kv head 0 group {gi}");
            assert_eq!(&od[(2 + gi) * 4..(2 + gi) * 4 + 4], &kv[4..8], "kv head 1 group {gi}");
        }
    }

    #[test]
    fn pad_rows_are_zero() {
        let (arena, _) = arena_with(5);
        let q = HostTensor::f32(vec![2, 4, 4], vec![1.0; 32]);
        let out = paged_attn(&arena, &[PAD_SLOT, 0], 0, &q, &[1, 5], 8, Par::Threads(2));
        assert!(out.as_f32()[..16].iter().all(|&x| x == 0.0));
        assert!(out.as_f32()[16..].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn prev_plus_combine_matches_full() {
        let (mut arena, _) = arena_with(6);
        let q = HostTensor::f32(vec![1, 4, 4], (0..16).map(|i| (i as f32 - 8.0) * 0.07).collect());
        let prev = paged_attn_prev(&arena, &[0], 0, &q, &[6], 16, Par::Threads(1));
        // append the "new" token, then full attention over 7
        let kv: Vec<f32> = (0..8).map(|i| 0.3 - i as f32 * 0.11).collect();
        let kt = HostTensor::f32(vec![1, 2, 4], kv.clone());
        arena.append_step(&[0], 0, &kt, &kt, &[6]);
        let full = paged_attn(&arena, &[0], 0, &q, &[7], 16, Par::Threads(1));
        let comb = combine_new_token(&q, &kt, &kt, &prev);
        for (a, b) in comb.as_f32().iter().zip(full.as_f32()) {
            assert!((a - b).abs() <= 1e-5, "combine {a} vs full {b}");
        }
    }

    #[test]
    fn empty_prev_state_is_identity_for_combine() {
        let (arena, _) = arena_with(0);
        let q = HostTensor::f32(vec![1, 4, 4], vec![0.5; 16]);
        let prev = paged_attn_prev(&arena, &[0], 0, &q, &[0], 8, Par::Threads(1));
        assert!(prev.a.as_f32().iter().all(|&x| x == 0.0));
        assert!(prev.s.as_f32().iter().all(|&x| x == 0.0));
        assert!(prev.m.as_f32().iter().all(|&x| x == NEG_INF));
        // combining the first token with the empty state returns v_new
        let kv: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let kt = HostTensor::f32(vec![1, 2, 4], kv.clone());
        let out = combine_new_token(&q, &kt, &kt, &prev);
        let od = out.as_f32();
        assert_eq!(&od[0..4], &kv[0..4]);
        assert_eq!(&od[8..12], &kv[4..8]);
    }

    #[test]
    fn thread_count_and_pool_do_not_change_bits() {
        let (arena, _) = arena_with(9);
        let q = HostTensor::f32(vec![2, 4, 4], (0..32).map(|i| (i % 13) as f32 * 0.21 - 1.1).collect());
        let a = paged_attn(&arena, &[0, 0], 0, &q, &[9, 4], 16, Par::Threads(1));
        let b = paged_attn(&arena, &[0, 0], 0, &q, &[9, 4], 16, Par::Threads(4));
        assert_eq!(a.as_f32(), b.as_f32(), "parallelism must not change bits");
        // the persistent pool must also be bit-identical, at any width
        for width in [1usize, 2, 4, 7] {
            let pool = ScopedPool::new(width);
            let c = paged_attn(&arena, &[0, 0], 0, &q, &[9, 4], 16, Par::Pool(&pool));
            assert_eq!(a.as_f32(), c.as_f32(), "pool({width}) changed bits");
        }
    }

    #[test]
    fn quantized_arena_attention_tracks_f32_within_storage_error() {
        // same appended stream, three storage dtypes: outputs agree within
        // the storage format's derived error bound (the tight derivation +
        // property coverage lives in tests/kernel_native.rs)
        let (a32, _) = arena_with_dtype(9, KvDtype::F32);
        let (a16, _) = arena_with_dtype(9, KvDtype::F16);
        let (a8, _) = arena_with_dtype(9, KvDtype::Int8);
        let q = HostTensor::f32(vec![1, 4, 4], (0..16).map(|i| (i % 7) as f32 * 0.3 - 0.9).collect());
        let o32 = paged_attn(&a32, &[0], 0, &q, &[9], 16, Par::Threads(1));
        let o16 = paged_attn(&a16, &[0], 0, &q, &[9], 16, Par::Threads(1));
        let o8 = paged_attn(&a8, &[0], 0, &q, &[9], 16, Par::Threads(1));
        for ((x, y), z) in o32.as_f32().iter().zip(o16.as_f32()).zip(o8.as_f32()) {
            assert!((x - y).abs() <= 1e-2, "f16 {y} vs f32 {x}");
            assert!((x - z).abs() <= 2e-1, "int8 {z} vs f32 {x}");
        }
    }

    #[test]
    fn unrolled_dot_and_axpy_match_naive_within_ulps() {
        let a: Vec<f32> = (0..19).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..19).map(|i| (i as f32 * 0.61).cos()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() <= 1e-5 * naive.abs().max(1.0));
        let mut acc = vec![0.5f32; 19];
        let mut acc_ref = acc.clone();
        axpy(&mut acc, 0.75, &b);
        for (r, &y) in acc_ref.iter_mut().zip(&b) {
            *r += 0.75 * y;
        }
        for (x, y) in acc.iter().zip(&acc_ref) {
            assert!((x - y).abs() <= 1e-6, "{x} vs {y}");
        }
    }
}
