//! The PJRT [`AttnBackend`]: gathers contiguous K/V from the arena and
//! executes the AOT attention artifacts through the [`Engine`].
//!
//! This is the original attention-worker compute path, kept as the
//! `--attn-backend engine` option: it stages a
//! `[bucket, KH_s, seq_bucket, hd]` K/V pair per layer per step through
//! [`PagedKvArena::gather`] (a host copy, charged to
//! `runtime::host::copies`; the scratch pair is recycled across steps) and
//! runs the compiled Pallas kernels on it. The entry-point names are
//! resolved **once** at construction (they used to be `format!`ed per
//! message on the decode hot loop).

use std::path::Path;

use crate::kvcache::PagedKvArena;
use crate::obs;
use crate::runtime::engine::Engine;
use crate::runtime::host::HostTensor;
use crate::runtime::manifest::ModelCfg;

use super::{AttnBackend, AttnBackendKind, ModelGeom, PartialState};

pub struct EngineBackend {
    engine: Engine,
    /// This shard's KV heads / head dim (prefill reshapes need them).
    khs: usize,
    hd: usize,
    /// Entry names, resolved once per worker (not per message).
    attention_entry: String,
    attn_prev_entry: String,
    attn_combine_entry: String,
    prefill_entry: String,
}

impl EngineBackend {
    pub fn new(artifacts_dir: &Path, n_shards: usize) -> Result<EngineBackend, String> {
        let engine = Engine::load(artifacts_dir).map_err(|e| format!("engine load: {e:#}"))?;
        let mc = &engine.manifest.config;
        if mc.kv_heads % n_shards != 0 {
            return Err(format!(
                "shards ({n_shards}) must divide kv heads ({})",
                mc.kv_heads
            ));
        }
        let khs = mc.kv_heads / n_shards;
        let hd = mc.head_dim;
        let sfx = if n_shards == 1 { String::new() } else { format!("_w{n_shards}") };
        Ok(EngineBackend {
            khs,
            hd,
            attention_entry: format!("attention{sfx}"),
            attn_prev_entry: format!("attn_prev{sfx}"),
            attn_combine_entry: format!("attn_combine{sfx}"),
            prefill_entry: format!("prefill_attn{sfx}"),
            engine,
        })
    }

    pub fn config(&self) -> &ModelCfg {
        &self.engine.manifest.config
    }

    pub fn geom(&self) -> ModelGeom {
        ModelGeom::of(self.config())
    }
}

impl AttnBackend for EngineBackend {
    fn kind(&self) -> AttnBackendKind {
        AttnBackendKind::Engine
    }

    /// Pre-compile this shard's attention entry points (lazy compiles would
    /// otherwise spike the first decode steps' latency).
    fn warmup(&mut self) -> Result<(), String> {
        for e in &self.engine.manifest.entrypoints {
            let mine = e.entry == self.attention_entry
                || e.entry == self.attn_prev_entry
                || e.entry == self.attn_combine_entry
                || e.entry == self.prefill_entry;
            if mine {
                self.engine
                    .execute_warm(&e.entry, e.batch, e.seq)
                    .map_err(|err| format!("warmup {}: {err:#}", e.entry))?;
            }
        }
        Ok(())
    }

    fn attention(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<HostTensor, String> {
        let _sp = obs::span("kernel", "engine_attention").arg("layer", layer as i64);
        let bucket = q.shape()[0];
        let (kc, vc) = arena.gather(slots, layer, bucket, seq_bucket);
        let lens_t = HostTensor::i32(vec![bucket], lens.to_vec());
        Ok(self
            .engine
            .execute_raw(&self.attention_entry, bucket, Some(seq_bucket), &[q, &kc, &vc, &lens_t])
            .map_err(|e| format!("{}: {e:#}", self.attention_entry))?
            .remove(0))
    }

    fn attn_prev(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<PartialState, String> {
        let _sp = obs::span("kernel", "engine_attn_prev").arg("layer", layer as i64);
        let bucket = q.shape()[0];
        let (kc, vc) = arena.gather(slots, layer, bucket, seq_bucket);
        let lens_t = HostTensor::i32(vec![bucket], lens.to_vec());
        let out = self
            .engine
            .execute_raw(&self.attn_prev_entry, bucket, Some(seq_bucket), &[q, &kc, &vc, &lens_t])
            .map_err(|e| format!("{}: {e:#}", self.attn_prev_entry))?;
        let mut it = out.into_iter();
        let (Some(a), Some(s), Some(m)) = (it.next(), it.next(), it.next()) else {
            return Err(format!("{}: output arity", self.attn_prev_entry));
        };
        Ok(PartialState { a, s, m })
    }

    fn attn_combine(
        &mut self,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        prev: &PartialState,
    ) -> Result<HostTensor, String> {
        let _sp = obs::span("kernel", "engine_attn_combine");
        let bucket = q.shape()[0];
        Ok(self
            .engine
            .execute_raw(
                &self.attn_combine_entry,
                bucket,
                None,
                &[q, k, v, &prev.a, &prev.s, &prev.m],
            )
            .map_err(|e| format!("{}: {e:#}", self.attn_combine_entry))?
            .remove(0))
    }

    fn prefill(
        &mut self,
        arena: &mut PagedKvArena,
        slot: u32,
        layer: usize,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        cached: i32,
        seq_bucket: usize,
    ) -> Result<HostTensor, String> {
        let _sp = obs::span("kernel", "engine_prefill").arg("layer", layer as i64);
        let t = q.shape()[0];
        // gather this slot's cached prefix; drop the leading batch dim with
        // a zero-copy reshape to the kernel's [KH_s, S, hd]
        let (kc_b, vc_b) = arena.gather(&[slot], layer, 1, seq_bucket);
        let kc = kc_b.reshape(vec![self.khs, seq_bucket, self.hd]);
        let vc = vc_b.reshape(vec![self.khs, seq_bucket, self.hd]);
        let lens_t = HostTensor::i32(vec![1], vec![cached]);
        Ok(self
            .engine
            .execute_raw(
                &self.prefill_entry,
                t,
                Some(seq_bucket),
                &[q, &kc, &vc, &lens_t, k, v],
            )
            .map_err(|e| format!("{}: {e:#}", self.prefill_entry))?
            .remove(0))
    }
}
