//! `kernels` — the attention-worker compute backends.
//!
//! The paper's attention workers are *bandwidth-bound*: each decode step
//! reads the whole live KV working set once, so every extra byte the worker
//! moves per step cuts directly into tokens/s. This module owns the two
//! ways a worker can turn its paged KV arena + an incoming Q into an
//! attention output shard, behind one [`AttnBackend`] trait:
//!
//! * [`EngineBackend`] (`--attn-backend engine`) — the PJRT path: the arena
//!   **gathers** each step's `[bucket, KH_shard, seq_bucket, hd]` K/V into
//!   a contiguous staging pair (a per-layer-per-step host copy, charged to
//!   [`crate::runtime::host::copies`]) and executes the AOT Pallas
//!   artifacts (`attention` / `attn_prev` / `attn_combine` /
//!   `prefill_attn`) through the engine.
//! * [`NativeBackend`] (`--attn-backend native`) — the block-table-native
//!   path: the pure-Rust [`paged_attn`] kernel consumes the per-slot block
//!   lists ([`crate::kvcache::arena::PagedKvArena::table_view`]) directly
//!   and runs **online-softmax** attention over the arena's per-layer block
//!   buffers in place ([`PagedKvArena::block_slices`] borrows, never
//!   copies). No gather, no scratch K/V, zero per-step host copies — the
//!   decode hot loop becomes genuinely bandwidth-shaped, like the paper's
//!   memory-optimised attention devices. Batch fan-out runs on a
//!   **persistent per-worker thread pool** (`util::threadpool::ScopedPool`,
//!   owned by the backend) — no per-call thread spawns on the hot loop.
//!
//! # The block-table data path — and where dequantization happens
//!
//! A request slot's cache is a chain of fixed-size blocks
//! (`block_size × hd` lanes per KV head, contiguous per `(block, head)`,
//! stored in the arena's [`crate::kvcache::KvDtype`]: f32, f16, or int8
//! with a per-region scale), mapped by its `BlockTable`. The native kernel
//! walks that chain in logical-token order: for batch row `b` with slot
//! `s`, head `h`, group query `g`, it visits block `i` of `table(s)`
//! covering token positions `[i·bs, i·bs + bs)`, stopping at the row's
//! valid length. Each visit reads the block's K region once to score, then
//! its V region once to accumulate — exactly one pass over the live KV
//! bytes, which is the bandwidth lower bound — and with quantized storage
//! those are the *compact* bytes: dequantization happens **in-register
//! inside the dot/axpy loops** (an f16 lane is bit-widened as consumed; an
//! int8 K scale multiplies the score once per token, an int8 V scale folds
//! into the accumulation weight), never through a staging buffer. Per-step
//! KV bytes read drop 2× (f16) / ≈4× (int8) and are charged to
//! `runtime::host::kv_reads` so `BENCH_decode.json` machine-checks the
//! reduction.
//!
//! Quantization stays behind this boundary on purpose: the **wire is
//! always f32**. K/V tensors arrive f32, the arena quantizes on append,
//! and attention outputs leave f32 — so the leader, codec, transports and
//! engine backend are dtype-oblivious, two workers may run different
//! `--kv-dtype` settings, and the overlap path's `attn_combine` (which
//! folds the *wire* K/V of the new token) is exact regardless of storage.
//! The engine backend never sees compact lanes either: `gather` widens to
//! f32 while staging.
//!
//! # The online-softmax recurrence
//!
//! Per query vector `q` and block of scores `s_t = q·k_t / √hd`
//! (FlashAttention/flash-decoding style, also the recurrence the Pallas
//! `_online_softmax_chunks` kernel uses):
//!
//! ```text
//! m'   = max(m, max_t s_t)                 running max
//! c    = exp(m − m')                       rescale factor for old state
//! S'   = S·c + Σ_t exp(s_t − m')           stabilised denominator
//! A'   = A·c + Σ_t exp(s_t − m') · v_t     stabilised numerator [hd]
//! ```
//!
//! with `(A, S, m)` initialised to `(0, 0, −1e30)`; the final output is
//! `A/S`. The *partial* form (`attn_prev`) returns `(A, S, m)` unnormalised
//! so the paper's §4.2.2 overlap can fold the freshly projected token in
//! later (`attn_combine`), and chunked prefill continues the same recurrence
//! from the cached prefix into the chunk's causal tail. Because the
//! recurrence re-associates the softmax sums — and the unrolled
//! `mul_add` inner loops re-associate the dots — native outputs match the
//! two-pass reference within ~1e-5 absolute rather than bit-for-bit
//! (`tests/kernel_native.rs` documents and asserts the bound, plus the
//! derived f16/int8 storage-error bounds). Golden-token tests pin the
//! `engine` backend precisely so kernel-level reassociation stays
//! tolerance-tested, never bit-pinned.
//!
//! The native kernel parallelises across the batch via
//! [`crate::util::threadpool::Par`] (rows are independent) — the backend
//! uses its persistent pool; tests/benches sweep per-call thread counts.
//! Outputs are bit-identical for any parallelism, since each row's
//! arithmetic is sequential and self-contained.

pub mod engine_backend;
pub mod paged_attn;
pub mod reference;

use crate::kvcache::PagedKvArena;
use crate::runtime::host::HostTensor;
use crate::runtime::manifest::ModelCfg;

pub use engine_backend::EngineBackend;
pub use paged_attn::{
    axpy, combine_new_token, dot, paged_attn, paged_attn_prev, paged_prefill, NativeBackend,
    NEG_INF,
};
pub use crate::util::threadpool::Par;

/// Backend selector (the `--attn-backend` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AttnBackendKind {
    /// PJRT artifacts over gathered contiguous K/V (the original path).
    #[default]
    Engine,
    /// Pure-Rust block-table kernel reading the arena in place (zero
    /// per-step KV copies; needs no artifacts on the worker).
    Native,
}

impl AttnBackendKind {
    pub fn parse(s: &str) -> Option<AttnBackendKind> {
        match s {
            "engine" => Some(AttnBackendKind::Engine),
            "native" => Some(AttnBackendKind::Native),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AttnBackendKind::Engine => "engine",
            AttnBackendKind::Native => "native",
        }
    }
}

/// Max-stabilised partial attention state carried from `StepQ` (where the
/// overlap path computes attention over the *cached* tokens) to `StepKv`
/// (where the new token is folded in): `a` = stabilised numerator
/// `[bucket, H_shard, hd]`, `s` = stabilised denominator `[bucket, H_shard]`,
/// `m` = running max `[bucket, H_shard]`.
#[derive(Debug, Clone)]
pub struct PartialState {
    pub a: HostTensor,
    pub s: HostTensor,
    pub m: HostTensor,
}

/// Model geometry an attention worker needs to size its arena and run the
/// native kernel. The engine backend derives it from the artifact manifest;
/// the native backend can be handed one explicitly and then needs **no
/// artifacts at all** (this is what makes worker-side tests and deployments
/// artifact-free).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelGeom {
    pub layers: usize,
    /// Total KV heads of the model (the worker divides by its shard count).
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

impl ModelGeom {
    pub fn of(cfg: &ModelCfg) -> ModelGeom {
        ModelGeom {
            layers: cfg.layers,
            kv_heads: cfg.kv_heads,
            head_dim: cfg.head_dim,
            max_seq: cfg.max_seq,
        }
    }
}

/// One attention worker's compute backend: everything between the wire
/// messages and the attention math. All tensor conventions follow the wire
/// protocol (`workers::messages`): `q` is `[bucket, H_shard, hd]`, step K/V
/// are `[bucket, KH_shard, hd]`, prefill chunks are `[T, ·, hd]`, and
/// outputs are `[bucket|T, H_shard, hd]`.
///
/// The arena is passed `&mut` because the engine backend's gather recycles
/// its scratch buffers through the arena; the native backend only reads.
#[allow(clippy::too_many_arguments)]
pub trait AttnBackend {
    fn kind(&self) -> AttnBackendKind;

    /// Pre-compile / pre-warm whatever the backend lazily builds (removes
    /// first-step latency spikes). Default: nothing to warm.
    fn warmup(&mut self) -> Result<(), String> {
        Ok(())
    }

    /// Full decode attention for one layer step, *after* the step's K/V has
    /// been appended: row `b` attends the first `lens[b]` cached tokens of
    /// its slot (`lens` already includes the appended token).
    fn attention(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<HostTensor, String>;

    /// Overlap path, first half (§4.2.2): partial attention over the
    /// *cached* tokens only (`lens[b]` valid, before this step's append).
    fn attn_prev(
        &mut self,
        arena: &mut PagedKvArena,
        slots: &[u32],
        layer: usize,
        q: &HostTensor,
        lens: &[i32],
        seq_bucket: usize,
    ) -> Result<PartialState, String>;

    /// Overlap path, second half: fold the newly projected `k`/`v`
    /// (`[bucket, KH_shard, hd]`) into `prev` and normalise.
    fn attn_combine(
        &mut self,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        prev: &PartialState,
    ) -> Result<HostTensor, String>;

    /// Chunked-prefill attention for ONE request (paper §5): every chunk row
    /// attends the slot's `cached` prefix plus the chunk's causal prefix of
    /// `k`/`v` (`[T, KH_shard, hd]`). Called *before* the chunk is appended.
    fn prefill(
        &mut self,
        arena: &mut PagedKvArena,
        slot: u32,
        layer: usize,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        cached: i32,
        seq_bucket: usize,
    ) -> Result<HostTensor, String>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [AttnBackendKind::Engine, AttnBackendKind::Native] {
            assert_eq!(AttnBackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(AttnBackendKind::parse("cuda"), None);
        assert_eq!(AttnBackendKind::default(), AttnBackendKind::Engine);
    }
}
