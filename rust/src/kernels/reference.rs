//! Pure-Rust correctness oracles for the attention kernels — the Rust twin
//! of `python/compile/kernels/ref.py`, consuming the *gathered* dense
//! `[bucket, KH_s, seq, hd]` K/V the engine path stages.
//!
//! These are deliberately straightforward two-pass softmax implementations
//! (mask → max → exp → normalise), used by `tests/kernel_native.rs` to
//! validate the block-table-native kernels and by the bench suite as the
//! "gather + reference" comparator. Because the native kernels use a
//! one-pass online recurrence, agreement is within ~1e-5 absolute, not
//! bit-exact (see the test file for the documented bound).

use crate::runtime::host::HostTensor;

use super::NEG_INF;

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Reference GQA decode attention. `q` `[B, H, hd]`, `k`/`v`
/// `[B, KH, S, hd]` (first `lens[b]` rows valid), returns `[B, H, hd]`.
/// Mirrors `decode_attention_ref`: masked scores become `NEG_INF` and still
/// pass through the softmax (their weight underflows to zero). A row with
/// `lens[b] <= 0` yields zeros, matching the native kernel's empty-row
/// convention (the jnp oracle would return a uniform mean there, but that
/// degenerate case never occurs on the wire — decode rows always attend at
/// least the token just appended).
pub fn decode_attention_ref(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    lens: &[i32],
) -> HostTensor {
    let (b_n, hs, hd) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (kh, s_n) = (k.shape()[1], k.shape()[2]);
    let g = hs / kh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.as_f32(), k.as_f32(), v.as_f32());
    let mut out = vec![0.0f32; b_n * hs * hd];
    let mut scores = vec![0.0f32; s_n];
    for b in 0..b_n {
        let n = (lens[b].max(0) as usize).min(s_n);
        if n == 0 {
            continue; // empty row stays zero, like paged_attn
        }
        for h in 0..kh {
            let krow = &kd[(b * kh + h) * s_n * hd..][..s_n * hd];
            let vrow = &vd[(b * kh + h) * s_n * hd..][..s_n * hd];
            for gi in 0..g {
                let hi = h * g + gi;
                let qv = &qd[(b * hs + hi) * hd..][..hd];
                let mut m = NEG_INF;
                for t in 0..s_n {
                    let sc = if t < n { dot(qv, &krow[t * hd..][..hd]) * scale } else { NEG_INF };
                    scores[t] = sc;
                    if sc > m {
                        m = sc;
                    }
                }
                let mut ssum = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - m).exp();
                    ssum += *sc;
                }
                let o = &mut out[(b * hs + hi) * hd..][..hd];
                for t in 0..s_n {
                    let w = scores[t] / ssum;
                    if w != 0.0 {
                        for d in 0..hd {
                            o[d] += w * vrow[t * hd + d];
                        }
                    }
                }
            }
        }
    }
    HostTensor::f32(vec![b_n, hs, hd], out)
}

/// Reference partial attention over cached tokens (overlap first half):
/// returns the max-stabilised `(A, S, m)` with masked positions
/// contributing zero (mirrors `partial_attention_ref`).
pub fn partial_attention_ref(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    lens: &[i32],
) -> (HostTensor, HostTensor, HostTensor) {
    let (b_n, hs, hd) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (kh, s_n) = (k.shape()[1], k.shape()[2]);
    let g = hs / kh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.as_f32(), k.as_f32(), v.as_f32());
    let mut a = vec![0.0f32; b_n * hs * hd];
    let mut s = vec![0.0f32; b_n * hs];
    let mut mv = vec![NEG_INF; b_n * hs];
    let mut scores = vec![0.0f32; s_n];
    for b in 0..b_n {
        let n = (lens[b].max(0) as usize).min(s_n);
        for h in 0..kh {
            let krow = &kd[(b * kh + h) * s_n * hd..][..s_n * hd];
            let vrow = &vd[(b * kh + h) * s_n * hd..][..s_n * hd];
            for gi in 0..g {
                let hi = h * g + gi;
                let qv = &qd[(b * hs + hi) * hd..][..hd];
                let mut m = NEG_INF;
                for t in 0..n {
                    let sc = dot(qv, &krow[t * hd..][..hd]) * scale;
                    scores[t] = sc;
                    if sc > m {
                        m = sc;
                    }
                }
                let arow = &mut a[(b * hs + hi) * hd..][..hd];
                let mut ssum = 0.0f32;
                for t in 0..n {
                    let e = (scores[t] - m).exp();
                    ssum += e;
                    for d in 0..hd {
                        arow[d] += e * vrow[t * hd + d];
                    }
                }
                s[b * hs + hi] = ssum;
                mv[b * hs + hi] = m;
            }
        }
    }
    (
        HostTensor::f32(vec![b_n, hs, hd], a),
        HostTensor::f32(vec![b_n, hs], s),
        HostTensor::f32(vec![b_n, hs], mv),
    )
}

/// Reference chunked-prefill attention for one request (mirrors
/// `chunked_prefill_ref`): `q` `[T, H, hd]`, `k_cache`/`v_cache`
/// `[KH, S, hd]` (first `cached` rows valid), `k_new`/`v_new`
/// `[T, KH, hd]`. Chunk token `i` attends the cache prefix plus chunk
/// tokens `0..=i`.
pub fn chunked_prefill_ref(
    q: &HostTensor,
    k_cache: &HostTensor,
    v_cache: &HostTensor,
    cached: usize,
    k_new: &HostTensor,
    v_new: &HostTensor,
) -> HostTensor {
    let (t_n, hs, hd) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (kh, s_n) = (k_cache.shape()[0], k_cache.shape()[1]);
    let g = hs / kh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kcd, vcd) = (q.as_f32(), k_cache.as_f32(), v_cache.as_f32());
    let (knd, vnd) = (k_new.as_f32(), v_new.as_f32());
    let n = cached.min(s_n);
    let mut out = vec![0.0f32; t_n * hs * hd];
    let mut scores = vec![0.0f32; s_n + t_n];
    for i in 0..t_n {
        for h in 0..kh {
            let kc = &kcd[h * s_n * hd..][..s_n * hd];
            let vc = &vcd[h * s_n * hd..][..s_n * hd];
            for gi in 0..g {
                let hi = h * g + gi;
                let qv = &qd[(i * hs + hi) * hd..][..hd];
                // score the cache prefix, then the causal chunk prefix
                let mut m = NEG_INF;
                let mut cnt = 0;
                for t in 0..n {
                    let sc = dot(qv, &kc[t * hd..][..hd]) * scale;
                    scores[cnt] = sc;
                    cnt += 1;
                    if sc > m {
                        m = sc;
                    }
                }
                for j in 0..=i {
                    let sc = dot(qv, &knd[(j * kh + h) * hd..][..hd]) * scale;
                    scores[cnt] = sc;
                    cnt += 1;
                    if sc > m {
                        m = sc;
                    }
                }
                let mut ssum = 0.0f32;
                for sc in scores[..cnt].iter_mut() {
                    *sc = (*sc - m).exp();
                    ssum += *sc;
                }
                let o = &mut out[(i * hs + hi) * hd..][..hd];
                for (t, &w) in scores[..cnt].iter().enumerate() {
                    let w = w / ssum;
                    let vt = if t < n {
                        &vc[t * hd..][..hd]
                    } else {
                        &vnd[((t - n) * kh + h) * hd..][..hd]
                    };
                    for d in 0..hd {
                        o[d] += w * vt[d];
                    }
                }
            }
        }
    }
    HostTensor::f32(vec![t_n, hs, hd], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_length_row_is_zero_like_the_native_kernel() {
        let q = HostTensor::f32(vec![1, 2, 2], vec![1.0; 4]);
        let kv = HostTensor::f32(vec![1, 1, 4, 2], vec![5.0; 8]);
        let out = decode_attention_ref(&q, &kv, &kv, &[0]);
        assert!(out.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn single_valid_token_puts_full_weight_on_it() {
        // B=1, H=2, KH=1, S=4, hd=2
        let q = HostTensor::f32(vec![1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let mut kv = vec![0.0f32; 4 * 2];
        kv[0] = 3.0; // token 0
        kv[1] = -2.0;
        let k = HostTensor::f32(vec![1, 1, 4, 2], kv.clone());
        let v = HostTensor::f32(vec![1, 1, 4, 2], kv);
        let out = decode_attention_ref(&q, &k, &v, &[1]);
        assert_eq!(out.as_f32(), &[3.0, -2.0, 3.0, -2.0]);
    }

    #[test]
    fn partial_state_normalises_to_full_attention() {
        // (A, S) from the partial oracle, normalised, equals the full oracle
        // when every position participates
        let q = HostTensor::f32(vec![1, 2, 2], vec![0.4, -0.3, 0.9, 0.1]);
        let data: Vec<f32> = (0..1 * 1 * 4 * 2).map(|i| (i as f32) * 0.3 - 1.0).collect();
        let k = HostTensor::f32(vec![1, 1, 4, 2], data.clone());
        let v = HostTensor::f32(vec![1, 1, 4, 2], data);
        let full = decode_attention_ref(&q, &k, &v, &[4]);
        let (a, s, _m) = partial_attention_ref(&q, &k, &v, &[4]);
        let (ad, sd) = (a.as_f32(), s.as_f32());
        for hi in 0..2 {
            for d in 0..2 {
                let got = ad[hi * 2 + d] / sd[hi];
                let want = full.as_f32()[hi * 2 + d];
                assert!((got - want).abs() < 1e-6, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn prefill_first_row_without_cache_attends_itself_only() {
        let q = HostTensor::f32(vec![2, 2, 2], vec![0.5; 8]);
        let kc = HostTensor::f32(vec![1, 4, 2], vec![0.0; 8]);
        let vc = kc.clone();
        let kn = HostTensor::f32(vec![2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let vn = kn.clone();
        let out = chunked_prefill_ref(&q, &kc, &vc, 0, &kn, &vn);
        // row 0 attends only chunk token 0 → out = v_new[0]
        assert_eq!(&out.as_f32()[0..2], &[1.0, 2.0]);
        assert_eq!(&out.as_f32()[2..4], &[1.0, 2.0]);
    }
}
