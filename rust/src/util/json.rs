//! Minimal JSON parser/writer.
//!
//! The offline build environment carries no `serde`/`serde_json`, so Lamina
//! ships its own: a strict recursive-descent parser and a writer, used for
//! the artifact manifest, golden files, configs and result dumps. Supports
//! the full JSON grammar (objects, arrays, strings with escapes/`\uXXXX`,
//! numbers, bools, null). Not streaming — fine for manifests of a few MB.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so output is
/// deterministic (useful for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset into the input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index; `Json::Null` if out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `[usize]` from a JSON array of numbers.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---- writer ------------------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 1-space indent (matches python `indent=1`).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_usize(), Some(1));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"obj":{"k":"v \" esc"},"s":"x"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
        assert_eq!(Json::Num(-0.5).dump(), "-0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap().dump(), "[]");
        assert_eq!(Json::parse("{}").unwrap().dump(), "{}");
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("missing").is_null());
        assert!(v.get("missing").get("deeper").is_null());
        assert_eq!(v.idx(3), &Json::Null);
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.usize_vec(), Some(vec![1, 2, 3]));
        let bad = Json::parse("[1,-2]").unwrap();
        assert_eq!(bad.usize_vec(), None);
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a")])),
        ]);
        assert_eq!(v.dump(), r#"{"x":1,"y":["a"]}"#);
    }
}
