//! Minimal thread-pool + actor mailboxes (no `tokio` offline).
//!
//! Lamina's workers are long-lived actor threads that exchange typed
//! messages over `std::sync::mpsc` channels; short parallel jobs with
//! `'static` data use the [`ThreadPool`], and borrow-heavy fan-outs (the
//! native attention kernel mapping over batch rows while borrowing the KV
//! arena) use [`scoped_map`] — or, on the decode hot loop, a persistent
//! [`ScopedPool`] that keeps its worker threads alive across calls instead
//! of spawning per invocation (the PR-3 follow-up: `scoped_map`'s per-call
//! spawn cost is fine at tiny-model scale but measurable at big batch).
//! [`Par`] is the call-site selector between the two; both produce
//! bit-identical, input-ordered results for any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                std::thread::Builder::new()
                    .name(format!("lamina-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool lock poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                inflight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, inflight }
    }

    pub fn spawn(&self, f: impl FnOnce() + Send + 'static) {
        self.inflight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Busy count of queued + running jobs.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Run `f` over each item in parallel, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.spawn(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over **borrowed** items: run `f` over each element of
/// `items` on up to `threads` scoped threads, collecting results in order.
///
/// Unlike [`ThreadPool::map`], the closure and items may borrow local state
/// (no `'static` bound) — this is what lets the native attention kernel
/// fan out over batch rows while borrowing the KV arena in place. Work is
/// distributed by an atomic cursor, so results are deterministic (each
/// index is computed exactly once, by exactly one thread) and the output
/// order always matches the input order. `threads <= 1` (or a single item)
/// runs inline with no spawns.
pub fn scoped_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter().map(f).collect();
    }
    let workers = threads.min(n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().expect("scoped_map slot poisoned") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("scoped_map slot poisoned")
                .expect("scoped_map worker panicked")
        })
        .collect()
}

// ---- persistent scoped pool ----------------------------------------------

/// A lifetime-erased pointer to the per-call worker body. Only sent while
/// [`ScopedPool::map`] blocks on its completion latch, which guarantees the
/// pointee outlives every use (the standard scoped-executor contract).
struct ScopedJob {
    body: *const (dyn Fn() + Sync),
}
// SAFETY: the pointee is `Sync` (shared by reference across workers) and
// `map` does not return until every dispatched job has signalled the latch,
// so the erased borrow never dangles.
unsafe impl Send for ScopedJob {}

/// Countdown latch a `map` call waits on: (remaining jobs, wakeup).
struct Latch {
    left: Mutex<usize>,
    cv: std::sync::Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { left: Mutex::new(n), cv: std::sync::Condvar::new() }
    }

    fn count_down(&self) {
        let mut left = self.left.lock().expect("latch poisoned");
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.left.lock().expect("latch poisoned");
        while *left > 0 {
            left = self.cv.wait(left).expect("latch poisoned");
        }
    }
}

/// A **persistent** scoped executor: `threads` long-lived workers that run
/// borrow-friendly parallel maps without any per-call `thread::spawn`.
///
/// Semantically identical to [`scoped_map`] — work is distributed by an
/// atomic cursor, each index is computed exactly once by exactly one
/// thread, results come back in input order, and because each item's
/// arithmetic is sequential the output is **bit-identical for any thread
/// count** (including the inline `threads <= 1` path). What changes is the
/// lifecycle: the native attention backend creates one pool per worker at
/// startup and reuses it every layer step, so the decode hot loop pays a
/// channel send + latch wait instead of `threads` thread spawns per call.
pub struct ScopedPool {
    tx: Option<Sender<ScopedJob>>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ScopedPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScopedPool").field("threads", &self.workers.len()).finish()
    }
}

impl ScopedPool {
    pub fn new(threads: usize) -> ScopedPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<ScopedJob>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("lamina-scoped-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("scoped pool lock poisoned");
                            guard.recv()
                        };
                        match job {
                            // SAFETY: see `ScopedJob` — the dispatching
                            // `map` call is blocked on the latch until this
                            // body returns, so the borrow is live. The
                            // catch keeps the worker alive even if a body
                            // unwinds (the body's own latch guard has
                            // already signalled completion).
                            Ok(job) => {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| unsafe {
                                        (&*job.body)()
                                    }),
                                );
                            }
                            Err(_) => break, // pool dropped: shutdown
                        }
                    })
                    .expect("spawn scoped pool worker")
            })
            .collect();
        ScopedPool { tx: Some(tx), workers }
    }

    /// Worker threads this pool keeps alive.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Parallel map over borrowed items on the persistent workers,
    /// collecting results in input order. `f` may borrow local state (no
    /// `'static` bound). Single-threaded pools and single items run inline.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        let n = items.len();
        if self.workers.len() <= 1 || n <= 1 {
            return items.iter().map(f).collect();
        }
        let jobs = self.workers.len().min(n);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let panicked = std::sync::atomic::AtomicBool::new(false);
        let latch = Latch::new(jobs);
        {
            let body = || {
                // a panicking `f` must still release the latch, or `map`
                // (and the caller's borrowed stack) would wait forever
                struct Release<'a>(&'a Latch);
                impl Drop for Release<'_> {
                    fn drop(&mut self) {
                        self.0.count_down();
                    }
                }
                let _release = Release(&latch);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        f(&items[i])
                    }));
                    match r {
                        Ok(r) => {
                            *slots[i].lock().expect("scoped pool slot poisoned") = Some(r)
                        }
                        Err(_) => {
                            panicked.store(true, Ordering::Release);
                            break;
                        }
                    }
                }
            };
            let erased: &(dyn Fn() + Sync) = &body;
            // erase the stack lifetime; sound because of the latch wait below
            let erased: *const (dyn Fn() + Sync) = unsafe { std::mem::transmute(erased) };
            let tx = self.tx.as_ref().expect("scoped pool shut down");
            for _ in 0..jobs {
                tx.send(ScopedJob { body: erased }).expect("scoped pool workers gone");
            }
            latch.wait();
        }
        assert!(
            !panicked.load(Ordering::Acquire),
            "scoped pool worker panicked"
        );
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("scoped pool slot poisoned")
                    .expect("scoped pool left a slot unfilled")
            })
            .collect()
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// How a kernel fans out over batch rows: per-call scoped threads (the
/// PR-3 behaviour, kept for tests/benches that sweep thread counts) or a
/// persistent [`ScopedPool`]. Both are deterministic and bit-identical for
/// the same input.
#[derive(Clone, Copy)]
pub enum Par<'a> {
    /// Spawn up to `n` scoped threads for this call ([`scoped_map`]).
    Threads(usize),
    /// Run on a long-lived pool (no per-call spawns).
    Pool(&'a ScopedPool),
}

impl std::fmt::Debug for Par<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Par::Threads(n) => write!(f, "Par::Threads({n})"),
            Par::Pool(p) => write!(f, "Par::Pool({})", p.threads()),
        }
    }
}

impl Par<'_> {
    /// Parallel map over borrowed items, in input order (see the variants).
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        match self {
            Par::Threads(n) => scoped_map(*n, items, f),
            Par::Pool(p) => p.map(items, f),
        }
    }
}

/// A typed actor: a thread with an inbox, processing messages until the
/// sender side closes (or an Exit message the handler interprets).
pub struct Actor<M: Send + 'static> {
    tx: Sender<M>,
    handle: Option<JoinHandle<()>>,
    name: String,
}

impl<M: Send + 'static> Actor<M> {
    /// Spawn an actor whose body receives the inbox receiver.
    pub fn spawn(name: &str, body: impl FnOnce(Receiver<M>) + Send + 'static) -> Self {
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("lamina-{name}"))
            .spawn(move || body(rx))
            .expect("spawn actor");
        Actor { tx, handle: Some(handle), name: name.to_string() }
    }

    pub fn send(&self, msg: M) -> Result<(), String> {
        self.tx
            .send(msg)
            .map_err(|_| format!("actor '{}' has exited", self.name))
    }

    pub fn sender(&self) -> Sender<M> {
        self.tx.clone()
    }

    /// Close the inbox and join the thread. Only unblocks if no other
    /// `sender()` clones are still alive.
    pub fn join(mut self) {
        let handle = self.handle.take();
        drop(self); // drops tx → inbox closes
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl<M: Send + 'static> Drop for Actor<M> {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            // Senders may still be alive elsewhere; detach rather than hang.
            drop(h);
        }
    }
}

/// One-shot reply channel for request/response actor calls.
pub struct Reply<T>(Sender<T>);

pub fn reply_channel<T>() -> (Reply<T>, Receiver<T>) {
    let (tx, rx) = channel();
    (Reply(tx), rx)
}

impl<T> Reply<T> {
    pub fn send(self, value: T) {
        let _ = self.0.send(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_borrows_locals_and_preserves_order() {
        let data: Vec<u64> = (0..100).collect();
        let offset = 7u64; // borrowed by the closure — no 'static
        let out = scoped_map(4, &data, |&x| x * 2 + offset);
        assert_eq!(out, (0..100).map(|x| x * 2 + 7).collect::<Vec<_>>());
        // inline path produces the same result
        assert_eq!(scoped_map(1, &data, |&x| x * 2 + offset), out);
        // more threads than items is fine
        assert_eq!(scoped_map(16, &data[..2], |&x| x + 1), vec![1, 2]);
        assert!(scoped_map(3, &[] as &[u64], |&x| x).is_empty());
    }

    #[test]
    fn scoped_pool_matches_scoped_map_bit_for_bit() {
        let pool = ScopedPool::new(4);
        let data: Vec<f64> = (0..257).map(|i| i as f64 * 0.731).collect();
        let f = |&x: &f64| (x.sin() * 1e6).mul_add(0.125, x);
        let spawned = scoped_map(4, &data, f);
        let pooled = pool.map(&data, f);
        assert_eq!(spawned, pooled, "pool must not change results or order");
        // reuse across calls, varying sizes (incl. inline paths)
        for n in [0usize, 1, 2, 31] {
            assert_eq!(pool.map(&data[..n], f), scoped_map(3, &data[..n], f));
        }
        assert_eq!(Par::Pool(&pool).map(&data, f), Par::Threads(2).map(&data, f));
    }

    #[test]
    fn scoped_pool_borrows_locals() {
        let pool = ScopedPool::new(3);
        let offset = 41u64; // borrowed, no 'static
        let data: Vec<u64> = (0..64).collect();
        let out = pool.map(&data, |&x| x + offset);
        assert_eq!(out, (41..105).collect::<Vec<_>>());
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn scoped_pool_single_thread_runs_inline() {
        let pool = ScopedPool::new(1);
        let data = [1u32, 2, 3];
        assert_eq!(pool.map(&data, |&x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    #[should_panic(expected = "scoped pool worker panicked")]
    fn scoped_pool_propagates_worker_panics() {
        let pool = ScopedPool::new(2);
        let data: Vec<u32> = (0..16).collect();
        let _ = pool.map(&data, |&x| {
            if x == 7 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn actor_processes_messages() {
        let sum = Arc::new(AtomicU64::new(0));
        let s2 = Arc::clone(&sum);
        let actor = Actor::spawn("adder", move |rx| {
            for v in rx {
                s2.fetch_add(v, Ordering::SeqCst);
            }
        });
        for i in 1..=10u64 {
            actor.send(i).unwrap();
        }
        actor.join();
        assert_eq!(sum.load(Ordering::SeqCst), 55);
    }

    #[test]
    fn actor_request_reply() {
        enum Msg {
            Square(u64, Reply<u64>),
        }
        let actor = Actor::spawn("squarer", |rx: Receiver<Msg>| {
            for m in rx {
                match m {
                    Msg::Square(x, reply) => reply.send(x * x),
                }
            }
        });
        let (reply, rx) = reply_channel();
        actor.send(Msg::Square(9, reply)).unwrap();
        assert_eq!(rx.recv().unwrap(), 81);
        actor.join();
    }
}
