//! Criterion-style micro-bench harness (no `criterion` offline).
//!
//! Provides warmup, adaptive iteration counts targeting a wall-clock budget,
//! and mean/p50/p95/p99 reporting. Used by `rust/benches/*` (declared with
//! `harness = false`) and the perf pass.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats::{fmt_duration, Percentiles};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Max number of timed samples (each sample = `iters_per_sample` calls).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_samples: 200,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>12}  p95 {:>12}  p99 {:>12}  ({} samples × {} iters)",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
            fmt_duration(self.p99_s),
            self.samples,
            self.iters_per_sample,
        )
    }
}

/// Bench runner; collects results for a final summary table.
pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    quick: bool,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // LAMINA_BENCH_QUICK=1 shrinks budgets for CI smoke runs.
        let quick = std::env::var("LAMINA_BENCH_QUICK").ok().as_deref() == Some("1");
        let cfg = if quick {
            BenchConfig {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                max_samples: 30,
            }
        } else {
            BenchConfig::default()
        };
        Bench { cfg, results: Vec::new(), quick }
    }

    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Benchmark `f`, timing batches of calls.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + estimate per-call cost.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.cfg.warmup || calls == 0 {
            f();
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calls as f64;

        // Choose iters per sample so each sample is ~ measure/max_samples.
        let target_sample = self.cfg.measure.as_secs_f64() / self.cfg.max_samples as f64;
        let iters = ((target_sample / per_call.max(1e-9)).round() as u64).max(1);

        let mut pct = Percentiles::new();
        let bench_start = Instant::now();
        let mut samples = 0;
        while bench_start.elapsed() < self.cfg.measure && samples < self.cfg.max_samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            pct.add(t.elapsed().as_secs_f64() / iters as f64);
            samples += 1;
        }

        let result = BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
            mean_s: pct.mean(),
            p50_s: pct.p50(),
            p95_s: pct.p95(),
            p99_s: pct.p99(),
            min_s: pct.min(),
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary (and return it for dumping to file).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("\n== bench summary ({} benches) ==\n", self.results.len()));
        for r in &self.results {
            s.push_str(&r.report_line());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("LAMINA_BENCH_QUICK", "1");
        let mut b = Bench::new();
        let r = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_s > 0.0);
        assert!(r.samples > 0);
        assert!(r.p50_s <= r.p99_s * 1.0001);
    }

    #[test]
    fn ranks_slower_work_slower() {
        std::env::set_var("LAMINA_BENCH_QUICK", "1");
        let mut b = Bench::new();
        let fast = b.run("fast", || {
            black_box((0..10u64).sum::<u64>());
        })
        .mean_s;
        let slow = b
            .run("slow", || {
                black_box((0..100_000u64).map(|i| i ^ 0x5a5a).sum::<u64>());
            })
            .mean_s;
        assert!(slow > fast, "slow={slow} fast={fast}");
        assert_eq!(b.results().len(), 2);
        assert!(b.summary().contains("fast"));
    }
}
