//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `lamina <subcommand> [--flag] [--key value] [positional…]`.
//! Unknown flags are errors; `--help` handling is left to the caller.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw argv (without the program name). `spec` lists valid option
    /// names; names ending in `!` take a value, plain names are boolean.
    pub fn parse(argv: &[String], spec: &[&str]) -> Result<Args, CliError> {
        let mut valued = std::collections::BTreeSet::new();
        let mut boolean = std::collections::BTreeSet::new();
        for s in spec {
            if let Some(name) = s.strip_suffix('!') {
                valued.insert(name.to_string());
            } else {
                boolean.insert(s.to_string());
            }
        }

        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    if !valued.contains(k) {
                        return Err(CliError(format!("unknown option --{}", k)));
                    }
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if valued.contains(name) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{} needs a value", name)))?;
                    out.flags
                        .entry(name.to_string())
                        .or_default()
                        .push(v.clone());
                } else if boolean.contains(name) {
                    out.flags.entry(name.to_string()).or_default().push(String::new());
                } else {
                    return Err(CliError(format!("unknown option --{}", name)));
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{} expects an integer, got '{}'", name, v))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{} expects a number, got '{}'", name, v))),
        }
    }

    /// Comma-separated usize list, e.g. `--batches 1,2,4`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{}: bad integer '{}'", name, x)))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse(
            &argv(&["fig10", "--trace", "azure-conv", "--verbose", "extra"]),
            &["trace!", "verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("fig10"));
        assert_eq!(a.get("trace"), Some("azure-conv"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value() {
        let a = Args::parse(&argv(&["x", "--n=5"]), &["n!"]).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(&argv(&["--bogus"]), &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&argv(&["--n"]), &["n!"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]), &["n!"]).unwrap();
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("alpha", 0.2).unwrap(), 0.2);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(&argv(&["--b", "1,2, 8"]), &["b!"]).unwrap();
        assert_eq!(a.usize_list_or("b", &[]).unwrap(), vec![1, 2, 8]);
        let bad = Args::parse(&argv(&["--b", "1,x"]), &["b!"]).unwrap();
        assert!(bad.usize_list_or("b", &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["--n", "abc"]), &["n!"]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn repeated_flag_takes_last() {
        let a = Args::parse(&argv(&["--n", "1", "--n", "2"]), &["n!"]).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 2);
    }
}
