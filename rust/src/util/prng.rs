//! Deterministic PRNG + distributions.
//!
//! The offline environment has no `rand` crate, so Lamina ships xoshiro256**
//! (Blackman/Vigna) seeded via SplitMix64, plus the distributions the
//! workload generators need: uniform, normal (Box–Muller), lognormal,
//! exponential (Poisson arrivals) and categorical sampling.

/// xoshiro256** PRNG. Deterministic, splittable via `fork`.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller output
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker/per-request seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi exclusive, requires hi > lo.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        // Lemire-style bounded sampling without bias for our purposes
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// true with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Lognormal with given mean/std of the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Sample an index proportionally to `weights` (all >= 0, sum > 0).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize(0, i + 1);
            v.swap(i, j);
        }
    }
}

/// Parameters of a lognormal fitted so its *mean* equals `mean` with shape
/// `cv` (coefficient of variation). Used to synthesize Table-4 traces where
/// only the mean lengths are published.
pub fn lognormal_from_mean_cv(mean: f64, cv: f64) -> (f64, f64) {
    let sigma2 = (1.0 + cv * cv).ln();
    let mu = mean.ln() - sigma2 / 2.0;
    (mu, sigma2.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(3);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            let x = r.range(5, 15);
            assert!((5..15).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(19);
        let n = 100_000;
        let lambda = 4.0;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn lognormal_mean_matches_fit() {
        let mut r = Rng::new(23);
        let (mu, sigma) = lognormal_from_mean_cv(1000.0, 0.8);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.lognormal(mu, sigma)).sum::<f64>() / n as f64;
        assert!((mean - 1000.0).abs() / 1000.0 < 0.03, "mean={mean}");
    }

    #[test]
    fn categorical_distribution() {
        let mut r = Rng::new(29);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[0] as f64 / 6000.0 - 1.0).abs() < 0.15);
        assert!((counts[2] as f64 / 36000.0 - 1.0).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(37);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
