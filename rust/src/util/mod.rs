//! Substrate utilities built in-repo (the offline toolchain carries no
//! serde/clap/tokio/criterion/rand — see DESIGN.md §3.11).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod threadpool;
