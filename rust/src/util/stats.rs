//! Streaming statistics: Welford mean/variance, percentile recorder,
//! fixed-bucket histogram. Used by the metrics layer and the bench harness.

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exact percentile recorder: stores all samples, sorts lazily.
/// Fine for the sample counts in benches/sims (≤ millions).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// q in [0, 1]; linear interpolation between closest ranks.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        self.ensure_sorted();
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let pos = q * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn min(&mut self) -> f64 {
        self.quantile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

/// Log-spaced histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    lo: f64,
    ratio: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl LogHistogram {
    /// Buckets span [lo, hi] with `n` log-spaced bins.
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n > 0);
        LogHistogram {
            lo,
            ratio: (hi / lo).ln() / n as f64,
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.lo).ln() / self.ratio) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_bounds(&self, i: usize) -> (f64, f64) {
        let a = self.lo * (self.ratio * i as f64).exp();
        let b = self.lo * (self.ratio * (i + 1) as f64).exp();
        (a, b)
    }

    pub fn counts(&self) -> &[u64] {
        &self.buckets
    }
}

/// Format seconds human-readably (ns/µs/ms/s) for report tables.
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".into();
    }
    let abs = secs.abs();
    if abs >= 1.0 {
        format!("{:.3} s", secs)
    } else if abs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.2} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format bytes/sec as GB/s (decimal) for network tables.
pub fn fmt_bandwidth(bytes_per_sec: f64) -> String {
    format!("{:.2} GB/s", bytes_per_sec / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.add(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn welford_empty() {
        let w = Welford::new();
        assert_eq!(w.count(), 0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentiles_exact() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 100.0);
        assert!((p.quantile(0.99) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentiles_unsorted_input() {
        let mut p = Percentiles::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            p.add(x);
        }
        assert_eq!(p.p50(), 3.0);
        assert_eq!(p.mean(), 3.0);
    }

    #[test]
    fn percentiles_single() {
        let mut p = Percentiles::new();
        p.add(7.0);
        assert_eq!(p.p50(), 7.0);
        assert_eq!(p.p99(), 7.0);
    }

    #[test]
    fn histogram_placement() {
        let mut h = LogHistogram::new(1.0, 1000.0, 3); // decades
        h.add(5.0); // [1,10)
        h.add(50.0); // [10,100)
        h.add(500.0); // [100,1000)
        h.add(0.5); // underflow
        h.add(5000.0); // overflow
        assert_eq!(h.counts(), &[1, 1, 1]);
        assert_eq!(h.total(), 5);
        let (a, b) = h.bucket_bounds(1);
        assert!((a - 10.0).abs() < 1e-9 && (b - 100.0).abs() < 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(1.5), "1.500 s");
        assert_eq!(fmt_duration(0.0032), "3.200 ms");
        assert_eq!(fmt_duration(33e-6), "33.00 µs");
        assert_eq!(fmt_duration(12e-9), "12.0 ns");
        assert_eq!(fmt_bandwidth(45.7e9), "45.70 GB/s");
    }
}
