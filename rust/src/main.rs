//! `lamina` — CLI entry point.
//!
//! Subcommands:
//!   serve        run the real tiny-model disaggregated pipeline on a trace
//!   decode       greedy-decode a prompt through the real pipeline
//!   all          regenerate every paper table/figure (results/*.json)
//!   table1|3|4|5, fig2|3|4|10|11|12|13|14   individual experiments
//!   pingpong-live  wall-clock transport ping-pong
//!
//! Common flags: --requests N, --seed S, --results DIR, --artifacts DIR,
//! --workers N, --no-overlap, --waves N, --stack NAME, --time-scale X.

use lamina::figures;
use lamina::kernels::AttnBackendKind;
use lamina::net::TransportKind;
use lamina::obs;
use lamina::scheduler::AdmissionKind;
use lamina::netsim::stack::stack_by_name;
use lamina::trace::{synthesize, trace_by_name, Request};
use lamina::util::cli::Args;
use lamina::util::stats::fmt_duration;
use lamina::workers::{DisaggPipeline, PipelineOpts};

const USAGE: &str = "\
lamina — model-attention disaggregation (Lamina) reproduction

USAGE: lamina <subcommand> [flags]

experiments (analytical, paper-scale):
  all | table1 | table3 | table4 | table5
  fig2 | fig3 | fig4 | fig10 | fig11 | fig12 | fig13 | fig14
  fig9 | offload | alt-devices | slo | pingpong-live

real pipeline (tiny model, PJRT end-to-end):
  decode  --prompt 1,7,42 --steps 16 [--workers N|ADDRS] [--no-overlap]
          [--transport inproc|tcp] [--attn-backend engine|native]
          [--kv-dtype f32|f16|int8]
  serve   [--trace azure-conv] [--requests N] [--waves N]
          [--stack fhbn|nccl|nccl-nogdr|gloo] [--time-scale X]
          [--transport inproc|tcp] [--attn-backend engine|native]
          [--admission fifo|sjf] [--kv-budget BYTES]
          [--kv-budget-blocks N] [--kv-dtype f32|f16|int8]
          [--prefix-cache on|off] [--overcommit] [--wave-driver]
          [--step-trace] [--trace-out FILE] [--metrics-dump]
  trace-smoke  artifact-free scripted serve session (real native-backend
          attention worker) emitting a full leader/wire/worker/kernel span
          tree: --steps N, --trace-out FILE, --kill-worker exercises the
          mid-session worker-death drop-safety path
  fault-smoke  artifact-free chaos/failover session (real scheduler + real
          native attention workers, deterministic pseudo-model): runs a
          golden pass, then the same session under --fault-plan, and
          asserts recovered output is bit-identical with zero leaked KV
          blocks; prints the failover.* metrics. Flags: --transport,
          --fault-plan PLAN, --no-recover (typed failure instead of
          recovery), --workers N (1..=4, contiguous head-range shards),
          --no-respawn (degrade to the survivors instead of respawning),
          --min-workers N (degradation floor), --adopt N (scale up by one
          worker at step boundary N)

multi-host deployment (standalone lamina-attn workers):
  1. start one `lamina-attn` daemon per shard host; each prints its bound
     address on stdout and waits for a leader:
       hostA$ lamina-attn --listen 0.0.0.0:7001
       hostB$ lamina-attn --listen 0.0.0.0:7001
  2. point the leader at them with the address form of --workers:
       lead$  lamina decode --workers hostA:7001,hostB:7001 --prompt 1,7
       lead$  lamina fault-smoke --workers hostA:7001,hostB:7001 \\
                --fault-plan kill-recv=18
     worker i dials the i-th address (bounded, backoff-paced retry);
     respawn-style recovery re-dials the SAME address, and the daemon's
     accept loop serves the reconnect as a fresh session. IPv6 addresses
     use the bracket form [::1]:7001. A decode step's per-layer message
     burst rides one batched envelope per worker (single writev), and
     replies from many workers are multiplexed with poll(2).

flags:
  --requests N     trace subsample size for simulations (default 1000)
  --seed S         workload seed (default 42)
  --results DIR    where experiment JSON lands (default results/)
  --artifacts DIR  AOT artifact dir (default artifacts/)
  --workers W      attention pool: a width N (in-process shard workers,
                   default 2) or a comma-separated HOST:PORT list of
                   running lamina-attn daemons (worker i dials address i;
                   implies --transport tcp)
  --transport T    leader↔worker wire: inproc (paced channel, modelled
                   bytes) or tcp (real loopback sockets, serialized frames,
                   measured-vs-logical byte report)  (default inproc)
  --attn-backend B attention-worker compute: engine (PJRT artifacts over
                   gathered K/V) or native (pure-Rust block-table kernel
                   reading the paged arena in place — zero per-step KV
                   copies on the workers)  (default engine)
  --admission P    scheduler admission order: fifo (arrival order) or sjf
                   (shortest job first among deferred admissions, with
                   FIFO aging so nothing starves)  (default fifo)
  --kv-budget N    per-worker KV budget in BYTES; admission defers
                   requests that would overflow it (default: unlimited).
                   Bytes budget mixed --kv-dtype pools correctly
  --kv-budget-blocks N  the same budget in blocks (legacy spelling);
                   --kv-budget wins when both are given
  --kv-dtype D     KV block storage on the attention workers: f32
                   (bit-exact, default), f16 (2× fewer KV bytes), or int8
                   with per-block scales (≈4× fewer). Worker-local — the
                   wire stays f32; the native backend reads the compact
                   blocks directly
  --prefix-cache M prompt-prefix sharing: on = map shared prompt blocks
                   from a live donor request (refcounted, copy-on-write)
                   instead of re-prefilling them; off = disabled (default).
                   A cache miss is bit-identical to off
  --overcommit     reserve prompt-only KV at admission and grow block by
                   block; budget pressure preempts the newest request back
                   to the queue (it resumes with identical output). Only
                   meaningful with --kv-budget[-blocks]
  --wave-driver    serve with the legacy wave-partitioned grouping
                   (comparison only; the step-driven scheduler is default)
  --step-trace     emit one structured event per decode step (request ids,
                   slots, context lens, buckets) through the obs tracer;
                   without --trace-out the events stream to stderr as JSONL
                   at session end (replaces the old LAMINA_STEP_TRACE env)
  --trace-out F    record the session's span timeline and write it to F:
                   Chrome trace_event JSON (load in Perfetto or
                   chrome://tracing), or a JSONL event stream when F ends
                   in .jsonl
  --metrics-dump   print a Prometheus-style snapshot of the obs metrics
                   registry after the serve report
  --kill-worker    trace-smoke only: kill the attention worker mid-session
                   (drop-safety exercise; the trace must stay well-formed)
  --fault-plan P   deterministic fault schedule for the leader↔worker
                   links, comma-separated key=value pairs: seed=N,
                   worker=I (arm one link; default all), kill-send=N /
                   kill-recv=N (sever the link at the Nth operation),
                   drop=P (per-send loss probability — the message
                   vanishes and the link dies with it), corrupt=P
                   (per-recv frame corruption), delay-us=N. Zero cost
                   when absent (links are never wrapped)
  --recv-deadline-ms N  per-attempt worker recv deadline before a retry
                   strike (default 5000)
  --recv-retries N timeouts tolerated before declaring a worker dead
                   (default 2; each retry's deadline doubles)
  --no-recover     disable automatic worker-death recovery: the first
                   declared death surfaces as a typed error instead of
                   preempt-replay-rebuild
  --no-respawn     on worker death, degrade the pool to the survivors
                   (epoch-fenced W→W−1 reshard, bit-identical output)
                   instead of respawning a replacement at the same width
  --min-workers N  smallest pool width degradation may leave; a death that
                   would shrink below it fails typed with zero leaked KV
                   blocks (default 1)
  --adopt N        fault-smoke only: adopt one extra worker at step
                   boundary N — handshake, quiesce, epoch-fenced W→W+1
                   reshard, replay

serve drives the request-lifecycle engine (submit → step → drain):
requests join and leave the running batch at iteration granularity, and
invalid requests are rejected individually instead of aborting the run.
";

const SPEC: &[&str] = &[
    "requests!", "seed!", "results!", "artifacts!", "workers!", "no-overlap",
    "waves!", "stack!", "time-scale!", "prompt!", "steps!", "trace!",
    "transport!", "attn-backend!", "admission!", "kv-budget!",
    "kv-budget-blocks!", "kv-dtype!", "prefix-cache!", "overcommit",
    "wave-driver", "step-trace", "trace-out!", "metrics-dump",
    "kill-worker", "fault-plan!", "recv-deadline-ms!", "recv-retries!",
    "no-recover", "no-respawn", "min-workers!", "adopt!", "help",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, SPEC).map_err(|e| e.to_string())?;
    if args.has("help") || args.subcommand.is_none() {
        print!("{USAGE}");
        return Ok(());
    }
    let sub = args.subcommand.clone().unwrap();
    let n_requests = args.usize_or("requests", 1000).map_err(|e| e.to_string())?;
    let seed = args.usize_or("seed", 42).map_err(|e| e.to_string())? as u64;
    let results_dir = args.get_or("results", "results").to_string();
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    match sub.as_str() {
        "all" => {
            for id in figures::ALL_IDS {
                println!("\n=== {id} ===");
                let j = figures::run(id, n_requests, seed)?;
                figures::save(id, &j, &results_dir).map_err(|e| e.to_string())?;
            }
            println!("\nresults written to {results_dir}/");
            Ok(())
        }
        "decode" => {
            let prompt: Vec<i32> = args
                .get_or("prompt", "1,7,42,99,3")
                .split(',')
                .map(|t| t.trim().parse().map_err(|_| format!("bad token '{t}'")))
                .collect::<Result<_, _>>()?;
            let steps = args.usize_or("steps", 16).map_err(|e| e.to_string())?;
            let opts = pipeline_opts(&args, &artifacts)?;
            let mut pipe = DisaggPipeline::start(opts).map_err(|e| format!("{e:#}"))?;
            let t0 = std::time::Instant::now();
            let out = pipe.decode(&[prompt.clone()], steps).map_err(|e| format!("{e:#}"))?;
            let dt = t0.elapsed().as_secs_f64();
            println!("prompt:    {prompt:?}");
            println!("generated: {:?}", out[0]);
            println!(
                "{} tokens in {} ({:.1} tok/s end-to-end)",
                out[0].len(),
                fmt_duration(dt),
                out[0].len() as f64 / dt
            );
            pipe.shutdown();
            Ok(())
        }
        "serve" => {
            let opts = pipeline_opts(&args, &artifacts)?;
            let waves = args.usize_or("waves", 2).map_err(|e| e.to_string())?;
            let wave_driver = args.has("wave-driver");
            let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
            let tracing = trace_out.is_some() || args.has("step-trace");
            if tracing {
                // before pipeline start, so worker spin-up lands on the tape
                obs::trace::start();
            }
            let mut pipe = DisaggPipeline::start(opts).map_err(|e| format!("{e:#}"))?;
            let reqs = tiny_trace(&args, n_requests, seed, pipe.config().max_seq - 1)?;
            println!(
                "serving {} requests on the tiny model ({} scheduler, capacity {} waves)...",
                reqs.len(),
                if wave_driver { "wave-driver" } else { "continuous-batching" },
                waves
            );
            let mut m = if wave_driver {
                pipe.serve_waves(&reqs, waves).map_err(|e| format!("{e:#}"))?
            } else {
                pipe.serve(&reqs, waves).map_err(|e| format!("{e:#}"))?
            };
            println!("completed:   {}", m.requests_completed);
            if m.rejected_submissions() > 0 {
                println!("rejected:    {} invalid request(s) skipped at submit", m.rejected_submissions());
            }
            println!("tokens:      {}", m.tokens_generated);
            println!("throughput:  {:.1} tok/s", m.throughput());
            println!("mean batch:  {:.2}", m.mean_batch());
            println!(
                "requests:    mean queue {}  mean TTFT {}  mean {:.1} tokens/req",
                fmt_duration(m.mean_queue_s()),
                fmt_duration(m.mean_ttft_s()),
                m.mean_request_tokens()
            );
            if m.requests_completed > 0 {
                println!(
                    "queue: p50 {}  p95 {}  p99 {}",
                    fmt_duration(m.p50_queue_s()),
                    fmt_duration(m.p95_queue_s()),
                    fmt_duration(m.p99_queue_s())
                );
                println!(
                    "TTFT:  p50 {}  p95 {}  p99 {}",
                    fmt_duration(m.p50_ttft_s()),
                    fmt_duration(m.p95_ttft_s()),
                    fmt_duration(m.p99_ttft_s())
                );
            }
            println!(
                "TBT: mean {}  p50 {}  p95 {}  p99 {}",
                fmt_duration(m.mean_tbt()),
                fmt_duration(m.p50_tbt()),
                fmt_duration(m.p95_tbt()),
                fmt_duration(m.p99_tbt())
            );
            let bd = m.mean_breakdown();
            println!(
                "breakdown: model {}  attention {}  network {}  other {}",
                fmt_duration(bd.model_s),
                fmt_duration(bd.attn_s),
                fmt_duration(bd.network_s),
                fmt_duration(bd.sched_s)
            );
            let kv = m.kv_stats();
            println!(
                "kv arena:  peak {} blocks  last round {}/{} blocks  {} tokens internal waste",
                m.kv_peak_blocks(),
                kv.blocks_in_use,
                kv.total_blocks,
                kv.internal_waste_tokens
            );
            // byte view (dtype-aware): where f16/int8 storage shows up
            println!(
                "kv bytes [{}]: peak {} B  last round {}/{} B resident",
                pipe.kv_dtype().name(),
                m.kv_peak_bytes(),
                kv.bytes_in_use,
                kv.total_bytes
            );
            // physical view: where prefix sharing shows up (logical ÷
            // physical is the dedup factor)
            if m.prefix_hits() > 0 {
                println!(
                    "prefix cache: {} hits  {} tokens mapped  peak physical {} B (logical {} B)",
                    m.prefix_hits(),
                    m.prefix_hit_tokens(),
                    m.kv_peak_physical_bytes(),
                    m.kv_peak_bytes()
                );
            }
            if m.preemptions() > 0 {
                println!("kv overcommit: {} preemptions (budget pressure)", m.preemptions());
            }
            if m.kv_budget_blocks().is_some() || m.kv_budget_bytes().is_some() {
                println!(
                    "kv budget [{}]: {} blocks/worker ≈ {} B/worker  ({} deferrals)",
                    pipe.admission().name(),
                    m.kv_budget_blocks().map_or("?".into(), |b| b.to_string()),
                    m.kv_budget_bytes().map_or("?".into(), |b| b.to_string()),
                    m.deferred_admissions()
                );
            } else if m.deferred_admissions() > 0 {
                println!("kv admission: {} deferrals (budget back-pressure)", m.deferred_admissions());
            }
            println!("attn backend: {}", pipe.attn_backend().name());
            if m.worker_deaths() > 0 {
                println!(
                    "failover: {} worker death(s)  {} tokens replayed  mean recovery {}",
                    m.worker_deaths(),
                    m.tokens_replayed(),
                    fmt_duration(m.mean_recovery_s())
                );
            }
            // measured-vs-logical wire accounting, per message class
            let transport = pipe.transport();
            let wt = m.wire_stats().total();
            println!(
                "wire [{}]: {} msgs  logical {} B  serialized {} B",
                transport.name(),
                wt.msgs,
                wt.logical_bytes,
                wt.serialized_bytes
            );
            for (class, c) in m.wire_stats().iter() {
                if c.msgs == 0 {
                    continue;
                }
                let overhead = if c.serialized_bytes > 0 && c.logical_bytes > 0 {
                    format!(
                        "  (+{:.2}% vs wire_bytes model)",
                        (c.serialized_bytes as f64 / c.logical_bytes as f64 - 1.0) * 100.0
                    )
                } else {
                    String::new()
                };
                println!(
                    "  {:<9} {:>7} msgs  logical {:>12} B  serialized {:>12} B{}",
                    class.name(),
                    c.msgs,
                    c.logical_bytes,
                    c.serialized_bytes,
                    overhead
                );
            }
            pipe.shutdown();
            if tracing {
                let events = obs::trace::stop();
                let dropped = obs::trace::dropped();
                if let Some(path) = &trace_out {
                    write_trace(path, &events)?;
                    println!(
                        "trace: {} events -> {}{}",
                        events.len(),
                        path.display(),
                        if dropped > 0 { format!("  ({dropped} dropped)") } else { String::new() }
                    );
                } else {
                    // --step-trace alone: stream the per-step events
                    let steps: Vec<_> =
                        events.iter().filter(|e| e.name == "step-trace").cloned().collect();
                    eprint!("{}", obs::export::jsonl(&steps));
                }
            }
            if args.has("metrics-dump") {
                print!("{}", obs::export::prometheus(&obs::registry().snapshot()));
            }
            Ok(())
        }
        "trace-smoke" => {
            let steps = args.usize_or("steps", 8).map_err(|e| e.to_string())?;
            let kill = args.has("kill-worker");
            let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
            obs::trace::start();
            let rep = lamina::workers::run_trace_smoke(steps, kill)?;
            let events = obs::trace::stop();
            println!(
                "trace-smoke: {} decode steps  {} replies  worker_died={}  {} events",
                rep.decode_steps,
                rep.replies,
                rep.worker_died,
                events.len()
            );
            if let Some(path) = &trace_out {
                write_trace(path, &events)?;
                println!("trace written to {}", path.display());
            }
            Ok(())
        }
        "fault-smoke" => {
            let mut cfg = lamina::workers::ChaosCfg::default();
            if let Some(t) = args.get("transport") {
                cfg.transport = TransportKind::parse(t)
                    .ok_or_else(|| format!("unknown transport '{t}' (use inproc|tcp)"))?;
            }
            match args.get("workers") {
                None => {}
                Some(w) if !w.is_empty() && w.chars().all(|c| c.is_ascii_digit()) => {
                    cfg.workers = w.parse().map_err(|_| format!("--workers: bad count '{w}'"))?;
                }
                Some(w) => {
                    // address form: dial running lamina-attn daemons
                    // instead of spawning worker threads
                    let addrs = lamina::net::Addr::parse_list(w)
                        .map_err(|e| format!("--workers: {e}"))?;
                    cfg.workers = addrs.len();
                    cfg.worker_addrs = Some(addrs.iter().map(|a| a.to_string()).collect());
                    cfg.transport = TransportKind::Tcp;
                }
            }
            if !(1..=4).contains(&cfg.workers) {
                return Err(format!(
                    "--workers {}: need 1..=4 (4 KV heads to split)",
                    cfg.workers
                ));
            }
            cfg.auto_recover = !args.has("no-recover");
            cfg.allow_respawn = !args.has("no-respawn");
            cfg.min_workers = args.usize_or("min-workers", 1).map_err(|e| e.to_string())?;
            // adoption only applies to the faulted pass below: the golden
            // run stays the plain fault-free bit-identity reference
            let adopt_at = if args.has("adopt") {
                Some(args.usize_or("adopt", 0).map_err(|e| e.to_string())?)
            } else {
                None
            };
            parse_health(args.get("recv-deadline-ms"), args.get("recv-retries"), &mut cfg.health)?;
            let plan = args
                .get("fault-plan")
                .map(lamina::net::FaultPlan::parse)
                .transpose()?;

            // golden pass: same session, no faults — the bit-identity ref
            let golden = lamina::workers::run_chaos(&cfg).map_err(|f| f.to_string())?;
            println!(
                "golden: {} requests x {} tokens over {} ({} engine steps)",
                golden.outputs.len(),
                cfg.gen_tokens,
                cfg.transport.name(),
                golden.steps
            );
            cfg.adopt_at_step = adopt_at;
            if plan.is_none() && adopt_at.is_none() {
                println!("no --fault-plan or --adopt given: golden pass only");
                return Ok(());
            }
            cfg.fault_plan = plan;
            match lamina::workers::run_chaos(&cfg) {
                Ok(r) => {
                    let identical = r.outputs == golden.outputs;
                    println!(
                        "faulted: {} worker death(s)  {} recovery(s)  {} tokens replayed  \
                         {} engine steps",
                        r.worker_deaths, r.recoveries, r.tokens_replayed, r.steps
                    );
                    if r.degrades + r.adoptions > 0 {
                        println!(
                            "membership: {} degrade(s)  {} adoption(s)  pool {} -> {} workers",
                            r.degrades, r.adoptions, cfg.workers, r.final_workers
                        );
                    }
                    println!(
                        "recovered output bit-identical: {}   leaked KV blocks: {}",
                        identical, r.leaked_blocks
                    );
                    print_failover_metrics();
                    if !identical {
                        return Err("recovered output diverged from the golden run".into());
                    }
                    if r.leaked_blocks != 0 {
                        return Err(format!("{} KV blocks leaked", r.leaked_blocks));
                    }
                }
                Err(f) => {
                    println!(
                        "faulted session aborted (typed): {}   leaked KV blocks: {}",
                        f.death, f.leaked_blocks
                    );
                    print_failover_metrics();
                    if cfg.auto_recover {
                        return Err(format!("session failed to recover: {}", f.death));
                    }
                    if f.leaked_blocks != 0 {
                        return Err(format!("{} KV blocks leaked on abort", f.leaked_blocks));
                    }
                }
            }
            Ok(())
        }
        id => {
            let j = figures::run(id, n_requests, seed)?;
            figures::save(id, &j, &results_dir).map_err(|e| e.to_string())?;
            println!("\nsaved {results_dir}/{id}.json");
            Ok(())
        }
    }
}

fn pipeline_opts(args: &Args, artifacts: &str) -> Result<PipelineOpts, String> {
    let mut opts = PipelineOpts::new(artifacts);
    match args.get("workers") {
        None => opts.attn_workers = 2,
        Some(w) if !w.is_empty() && w.chars().all(|c| c.is_ascii_digit()) => {
            opts.attn_workers = w.parse().map_err(|_| format!("--workers: bad count '{w}'"))?;
        }
        Some(w) => {
            // address form: worker i dials addrs[i] — running lamina-attn
            // daemons instead of in-process shard threads
            let addrs = lamina::net::Addr::parse_list(w).map_err(|e| format!("--workers: {e}"))?;
            opts.attn_workers = addrs.len();
            opts.worker_addrs = Some(addrs);
        }
    }
    opts.overlap = !args.has("no-overlap");
    opts.allow_respawn = !args.has("no-respawn");
    opts.min_workers = args.usize_or("min-workers", 1).map_err(|e| e.to_string())?;
    opts.time_scale = args.f64_or("time-scale", 0.0).map_err(|e| e.to_string())?;
    if let Some(name) = args.get("stack") {
        opts.stack = stack_by_name(name).ok_or_else(|| format!("unknown stack '{name}'"))?;
    }
    if let Some(t) = args.get("transport") {
        opts.transport = TransportKind::parse(t)
            .ok_or_else(|| format!("unknown transport '{t}' (use inproc|tcp)"))?;
    }
    if opts.worker_addrs.is_some() {
        if args.get("transport").is_some_and(|t| !t.eq_ignore_ascii_case("tcp")) {
            return Err(
                "--workers with addresses dials real sockets; --transport inproc conflicts".into(),
            );
        }
        opts.transport = TransportKind::Tcp;
    }
    if let Some(b) = args.get("attn-backend") {
        opts.attn_backend = AttnBackendKind::parse(b)
            .ok_or_else(|| format!("unknown attention backend '{b}' (use engine|native)"))?;
    }
    if let Some(a) = args.get("admission") {
        opts.admission = AdmissionKind::parse(a)
            .ok_or_else(|| format!("unknown admission policy '{a}' (use fifo|sjf)"))?;
    }
    if args.has("kv-budget") {
        opts.kv_byte_budget = Some(args.usize_or("kv-budget", 0).map_err(|e| e.to_string())?);
    }
    if args.has("kv-budget-blocks") {
        opts.kv_block_budget =
            Some(args.usize_or("kv-budget-blocks", 0).map_err(|e| e.to_string())?);
    }
    if let Some(d) = args.get("kv-dtype") {
        opts.kv_dtype = lamina::kvcache::KvDtype::parse(d)
            .ok_or_else(|| format!("unknown kv dtype '{d}' (use f32|f16|int8)"))?;
    }
    if let Some(p) = args.get("prefix-cache") {
        opts.prefix_cache = match p.to_ascii_lowercase().as_str() {
            "on" => true,
            "off" => false,
            _ => return Err(format!("unknown prefix-cache mode '{p}' (use on|off)")),
        };
    }
    if let Some(p) = args.get("fault-plan") {
        opts.fault_plan = Some(lamina::net::FaultPlan::parse(p)?);
    }
    parse_health(args.get("recv-deadline-ms"), args.get("recv-retries"), &mut opts.health)?;
    opts.auto_recover = !args.has("no-recover");
    opts.overcommit = args.has("overcommit");
    opts.step_trace = args.has("step-trace");
    Ok(opts)
}

/// Apply the --recv-deadline-ms / --recv-retries overrides to a
/// [`HealthPolicy`](lamina::coordinator::failover::HealthPolicy).
fn parse_health(
    deadline_ms: Option<&str>,
    retries: Option<&str>,
    health: &mut lamina::coordinator::failover::HealthPolicy,
) -> Result<(), String> {
    if let Some(ms) = deadline_ms {
        let ms: u64 = ms.parse().map_err(|_| format!("bad --recv-deadline-ms '{ms}'"))?;
        health.recv_deadline = std::time::Duration::from_millis(ms.max(1));
    }
    if let Some(r) = retries {
        health.recv_retries = r.parse().map_err(|_| format!("bad --recv-retries '{r}'"))?;
    }
    Ok(())
}

/// Print the failover.* slice of the metrics registry snapshot (the
/// acceptance surface: deaths and recovery latency must be visible here).
fn print_failover_metrics() {
    let snap = obs::registry().snapshot();
    let text = obs::export::prometheus(&snap);
    let mut any = false;
    for line in text.lines() {
        if line.contains("failover") {
            println!("{line}");
            any = true;
        }
    }
    if !any {
        println!("(no failover metrics recorded)");
    }
}

/// Write a captured trace to `path` in the format its extension picks:
/// `.jsonl` → one event per line, anything else → Chrome `trace_event`.
fn write_trace(path: &std::path::Path, events: &[obs::TraceEvent]) -> Result<(), String> {
    let r = if path.extension().is_some_and(|e| e == "jsonl") {
        obs::export::write_jsonl(path, events)
    } else {
        obs::export::write_chrome_trace(path, events)
    };
    r.map_err(|e| format!("write {}: {e}", path.display()))
}

/// A trace scaled down to the tiny model's context window: real trace shape,
/// lengths clamped into [1, max_ctx].
fn tiny_trace(args: &Args, n: usize, seed: u64, max_ctx: usize) -> Result<Vec<Request>, String> {
    let spec = trace_by_name(args.get_or("trace", "azure-conv"))
        .ok_or_else(|| format!("unknown trace '{}'", args.get_or("trace", "azure-conv")))?;
    let scale = (spec.mean_prompt + spec.mean_gen) / (max_ctx as f64 / 4.0);
    Ok(synthesize(spec, n, seed)
        .into_iter()
        .map(|r| {
            let p = ((r.prompt_tokens as f64 / scale).round() as usize).clamp(1, max_ctx - 8);
            let g = ((r.gen_tokens as f64 / scale).ceil() as usize).clamp(1, max_ctx - p);
            Request { id: r.id, prompt_tokens: p, gen_tokens: g }
        })
        .collect())
}
