//! `lamina-attn` — standalone attention-worker daemon.
//!
//! One process per attention shard on a real multi-host deployment: bind
//! `--listen HOST:PORT`, print the bound address on stdout (exactly one
//! line — scripts and the e2e tests parse it, so everything else goes to
//! stderr), then serve leader connections forever.
//!
//! Each accepted connection is one worker *session*: the process speaks
//! the PR 9 membership handshake (worker sends `Hello`, leader replies
//! `Welcome` carrying the authoritative KV-head range and arena
//! geometry), then runs the attention data plane until the leader shuts
//! the link down or the session errors. The accept loop then returns to
//! listening, so a leader that respawns a "dead" worker by re-dialing
//! the same address gets a fresh session from the same process.
//!
//! The binary trusts `Welcome` for model geometry (`trust_welcome`): a
//! standalone worker has no artifact manifest to cross-check against, so
//! the handshake IS its configuration.
//!
//! Deployment walkthrough:
//!
//! ```text
//!   hostA$ lamina-attn --listen 0.0.0.0:7001 &
//!   hostB$ lamina-attn --listen 0.0.0.0:7001 &
//!   lead$  lamina decode --workers hostA:7001,hostB:7001 --prompt 1,7,42
//! ```
//!
//! `--listen 127.0.0.1:0` binds an ephemeral port (the stdout line tells
//! you which); `--once` exits after the first session ends (CI teardown).

use std::io::Write;
use std::net::TcpListener;

use lamina::kernels::AttnBackendKind;
use lamina::kvcache::KvDtype;
use lamina::net::{tcp::TcpTransport, Addr};
use lamina::util::cli::Args;
use lamina::workers::{run_attn_worker, AttnWorkerCfg};

const USAGE: &str = "\
lamina-attn — standalone Lamina attention worker

USAGE: lamina-attn --listen HOST:PORT [flags]

flags:
  --listen HOST:PORT  address to bind (required). Port 0 binds an
                      ephemeral port; the bound address is printed as the
                      single stdout line 'lamina-attn listening on A'
  --attn-backend B    attention compute: native (pure-Rust paged-KV
                      kernel, default — needs no artifacts) or engine
                      (PJRT artifacts from --artifacts)
  --artifacts DIR     AOT artifact dir for --attn-backend engine
                      (default artifacts/)
  --kv-dtype D        KV block storage: f32 (default) | f16 | int8
  --kv-block-size N   token slots per KV block (default 16)
  --slots N           wire-addressable batch slots (default 64; the
                      arena itself is sized by the leader's Welcome)
  --once              exit after the first session ends instead of
                      returning to accept (CI teardown)

The worker is passive: model geometry and the KV-head range it owns
arrive in the leader's Welcome at connect time, so the same daemon can
serve any pool width without restarting.
";

const SPEC: &[&str] = &[
    "listen!", "attn-backend!", "artifacts!", "kv-dtype!", "kv-block-size!",
    "slots!", "once", "help",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("lamina-attn: error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, SPEC).map_err(|e| e.to_string())?;
    if args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let listen = args
        .get("listen")
        .ok_or("--listen HOST:PORT is required (try --help)")?;
    let addr = Addr::parse(listen).map_err(|e| format!("--listen: {e}"))?;
    let sa = addr.resolve().map_err(|e| format!("--listen: {e}"))?;

    let mut cfg = AttnWorkerCfg {
        artifacts_dir: std::path::PathBuf::from(args.get_or("artifacts", "artifacts")),
        shard: 0,
        n_shards: 1,
        slots: args.usize_or("slots", 64).map_err(|e| e.to_string())?,
        kv_block_size: args.usize_or("kv-block-size", 16).map_err(|e| e.to_string())?,
        kv_dtype: KvDtype::F32,
        backend: AttnBackendKind::Native,
        geom: None,
        trust_welcome: true,
    };
    if let Some(d) = args.get("kv-dtype") {
        cfg.kv_dtype = KvDtype::parse(d)
            .ok_or_else(|| format!("unknown kv dtype '{d}' (use f32|f16|int8)"))?;
    }
    if let Some(b) = args.get("attn-backend") {
        cfg.backend = AttnBackendKind::parse(b)
            .ok_or_else(|| format!("unknown attention backend '{b}' (use engine|native)"))?;
    }

    let listener = TcpListener::bind(sa).map_err(|e| format!("bind {addr}: {e}"))?;
    let bound = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
    // the ONE stdout line — scripts parse it, so flush before serving
    println!("lamina-attn listening on {bound}");
    std::io::stdout().flush().map_err(|e| format!("stdout: {e}"))?;

    let once = args.has("once");
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                eprintln!("lamina-attn: accept: {e}");
                continue;
            }
        };
        eprintln!("lamina-attn: session from {peer}");
        match TcpTransport::from_stream(stream) {
            Ok(link) => {
                // one blocking session per connection: the leader drives
                // exactly one worker per link, so there is nothing to
                // serve concurrently
                run_attn_worker(cfg.clone(), link);
                eprintln!("lamina-attn: session from {peer} ended");
            }
            Err(e) => eprintln!("lamina-attn: session setup from {peer}: {e}"),
        }
        if once {
            return Ok(());
        }
    }
}
