//! Weighted operator graph IR — the input to the automated model converter
//! (paper §4.2.1, Fig. 6).
//!
//! Nodes are tensor operators; a directed edge `u → v` means v consumes a
//! tensor produced by u, weighted by that tensor's size in bytes (derived
//! from the model's shape specification, as the paper's symbolic executor
//! does). The converter cuts this graph at every attention operator.

use std::collections::BTreeMap;

/// Operator kinds appearing in a transformer decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Graph input (token ids / positions).
    Input,
    Embed,
    RmsNorm,
    /// Dense projection (QKVO, FFN matmuls, LM head).
    MatMul,
    Rope,
    /// The attention operator — the cut point.
    Attention,
    /// Residual or elementwise add.
    Add,
    /// Elementwise activation (SiLU) or product.
    Elementwise,
    ArgMax,
    /// Graph output.
    Output,
}

/// Node id.
pub type NodeId = usize;

#[derive(Debug, Clone)]
pub struct OpNode {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    /// Which transformer layer this op belongs to (None for embed/head).
    pub layer: Option<usize>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    /// Tensor bytes flowing along this edge (per decode iteration).
    pub bytes: f64,
}

/// The operator graph.
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    pub nodes: Vec<OpNode>,
    pub edges: Vec<Edge>,
}

impl OpGraph {
    pub fn add_node(&mut self, name: impl Into<String>, kind: OpKind, layer: Option<usize>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(OpNode { id, name: name.into(), kind, layer });
        id
    }

    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, bytes: f64) {
        assert!(src < self.nodes.len() && dst < self.nodes.len());
        assert!(src != dst, "self-loop");
        self.edges.push(Edge { src, dst, bytes });
    }

    pub fn node(&self, id: NodeId) -> &OpNode {
        &self.nodes[id]
    }

    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.src == id).map(|e| e.dst).collect()
    }

    pub fn predecessors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.dst == id).map(|e| e.src).collect()
    }

    /// Forward adjacency lists, built once — O(V+E). Use instead of
    /// repeated [`successors`] calls in traversal-heavy code (each of those
    /// scans every edge).
    pub fn out_adj(&self) -> Vec<Vec<NodeId>> {
        let mut adj = vec![Vec::new(); self.nodes.len()];
        for e in &self.edges {
            adj[e.src].push(e.dst);
        }
        adj
    }

    /// Reverse adjacency lists, built once.
    pub fn in_adj(&self) -> Vec<Vec<NodeId>> {
        let mut adj = vec![Vec::new(); self.nodes.len()];
        for e in &self.edges {
            adj[e.dst].push(e.src);
        }
        adj
    }

    pub fn attention_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.kind == OpKind::Attention)
            .map(|n| n.id)
            .collect()
    }

    /// Kahn topological order; panics on cycles (op graphs are DAGs).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let adj = self.out_adj();
        let mut indeg = vec![0usize; self.nodes.len()];
        for e in &self.edges {
            indeg[e.dst] += 1;
        }
        let mut queue: Vec<NodeId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(n) = queue.pop() {
            order.push(n);
            for &s in &adj[n] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        assert_eq!(order.len(), self.nodes.len(), "cycle in op graph");
        order
    }

    /// Topological order with a priority: nodes for which `prio` returns a
    /// *smaller* value are scheduled as early as dependencies allow. Used by
    /// the converter's Q-proj-early reordering (paper §4.2.2).
    pub fn topo_order_by<F: Fn(&OpNode) -> i64>(&self, prio: F) -> Vec<NodeId> {
        let adj = self.out_adj();
        let mut indeg = vec![0usize; self.nodes.len()];
        for e in &self.edges {
            indeg[e.dst] += 1;
        }
        // min-heap by (prio, id) via BTreeMap for determinism
        let mut ready: BTreeMap<(i64, NodeId), ()> = BTreeMap::new();
        for (i, &d) in indeg.iter().enumerate() {
            if d == 0 {
                ready.insert((prio(&self.nodes[i]), i), ());
            }
        }
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some((&(p, n), ())) = ready.iter().next().map(|(k, v)| (k, *v)) {
            ready.remove(&(p, n));
            order.push(n);
            for &s in &adj[n] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.insert((prio(&self.nodes[s]), s), ());
                }
            }
        }
        assert_eq!(order.len(), self.nodes.len(), "cycle in op graph");
        order
    }

    /// Verify `order` is a valid topological order of this graph.
    pub fn is_topo_order(&self, order: &[NodeId]) -> bool {
        if order.len() != self.nodes.len() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.nodes.len()];
        for (i, &n) in order.iter().enumerate() {
            pos[n] = i;
        }
        self.edges.iter().all(|e| pos[e.src] < pos[e.dst])
    }

    /// Sum of bytes over all edges crossing from `set` to its complement.
    pub fn cut_bytes(&self, in_set: &[bool]) -> f64 {
        self.edges
            .iter()
            .filter(|e| in_set[e.src] && !in_set[e.dst])
            .map(|e| e.bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> OpGraph {
        // a → b → d, a → c → d
        let mut g = OpGraph::default();
        let a = g.add_node("a", OpKind::Input, None);
        let b = g.add_node("b", OpKind::MatMul, None);
        let c = g.add_node("c", OpKind::MatMul, None);
        let d = g.add_node("d", OpKind::Output, None);
        g.add_edge(a, b, 1.0);
        g.add_edge(a, c, 2.0);
        g.add_edge(b, d, 3.0);
        g.add_edge(c, d, 4.0);
        g
    }

    #[test]
    fn topo_valid() {
        let g = diamond();
        let order = g.topo_order();
        assert!(g.is_topo_order(&order));
    }

    #[test]
    fn topo_by_priority_prefers_low() {
        let g = diamond();
        // make c (id 2) high priority (low value) over b (id 1)
        let order = g.topo_order_by(|n| if n.name == "c" { 0 } else { 1 });
        assert!(g.is_topo_order(&order));
        let pos_b = order.iter().position(|&x| g.node(x).name == "b").unwrap();
        let pos_c = order.iter().position(|&x| g.node(x).name == "c").unwrap();
        assert!(pos_c < pos_b);
    }

    #[test]
    fn neighbors() {
        let g = diamond();
        assert_eq!(g.successors(0), vec![1, 2]);
        assert_eq!(g.predecessors(3), vec![1, 2]);
    }

    #[test]
    fn cut_bytes_counts_forward_edges_only() {
        let g = diamond();
        // set = {a, b}: crossing edges a→c (2) and b→d (3)
        let cut = g.cut_bytes(&[true, true, false, false]);
        assert_eq!(cut, 5.0);
    }

    #[test]
    #[should_panic]
    fn cycle_panics() {
        let mut g = OpGraph::default();
        let a = g.add_node("a", OpKind::MatMul, None);
        let b = g.add_node("b", OpKind::MatMul, None);
        g.add_edge(a, b, 1.0);
        g.add_edge(b, a, 1.0);
        g.topo_order();
    }

    #[test]
    fn invalid_topo_detected() {
        let g = diamond();
        assert!(!g.is_topo_order(&[3, 1, 2, 0]));
        assert!(!g.is_topo_order(&[0, 1, 2]));
    }
}
