//! Minimum weighted s–t cut via Dinic's max-flow (paper §4.2.1).
//!
//! The model splitter removes an attention operator and computes the min cut
//! between its inputs and outputs in the remaining graph; the cut edges are
//! the context a slice must hand to the next one (residual stream etc.).
//!
//! Capacities are tensor byte counts (f64). Dinic runs in O(V²E), far more
//! than enough for operator graphs (a few thousand nodes).

use super::graph::{Edge, NodeId, OpGraph};

#[derive(Debug, Clone)]
struct FlowEdge {
    to: usize,
    cap: f64,
    /// index of the reverse edge in `adj[to]`
    rev: usize,
    /// original op-graph edge index (None for reverse/virtual edges);
    /// retained for debugging cut extraction
    #[allow(dead_code)]
    orig: Option<usize>,
}

/// Max-flow network.
pub struct Dinic {
    adj: Vec<Vec<FlowEdge>>,
    level: Vec<i32>,
    it: Vec<usize>,
}

const EPS: f64 = 1e-9;

impl Dinic {
    pub fn new(n: usize) -> Self {
        Dinic { adj: vec![Vec::new(); n], level: vec![0; n], it: vec![0; n] }
    }

    pub fn add_edge(&mut self, from: usize, to: usize, cap: f64, orig: Option<usize>) {
        let a = self.adj[from].len();
        let b = self.adj[to].len();
        self.adj[from].push(FlowEdge { to, cap, rev: b, orig });
        self.adj[to].push(FlowEdge { to: from, cap: 0.0, rev: a, orig: None });
    }

    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.iter_mut().for_each(|l| *l = -1);
        let mut q = std::collections::VecDeque::new();
        self.level[s] = 0;
        q.push_back(s);
        while let Some(v) = q.pop_front() {
            for e in &self.adj[v] {
                if e.cap > EPS && self.level[e.to] < 0 {
                    self.level[e.to] = self.level[v] + 1;
                    q.push_back(e.to);
                }
            }
        }
        self.level[t] >= 0
    }

    fn dfs(&mut self, v: usize, t: usize, f: f64) -> f64 {
        if v == t {
            return f;
        }
        while self.it[v] < self.adj[v].len() {
            let (to, cap, rev) = {
                let e = &self.adj[v][self.it[v]];
                (e.to, e.cap, e.rev)
            };
            if cap > EPS && self.level[v] < self.level[to] {
                let d = self.dfs(to, t, f.min(cap));
                if d > EPS {
                    self.adj[v][self.it[v]].cap -= d;
                    self.adj[to][rev].cap += d;
                    return d;
                }
            }
            self.it[v] += 1;
        }
        0.0
    }

    /// Run max-flow from s to t; returns total flow.
    pub fn max_flow(&mut self, s: usize, t: usize) -> f64 {
        assert_ne!(s, t);
        let mut flow = 0.0;
        while self.bfs(s, t) {
            self.it.iter_mut().for_each(|i| *i = 0);
            loop {
                let f = self.dfs(s, t, f64::INFINITY);
                if f <= EPS {
                    break;
                }
                flow += f;
            }
        }
        flow
    }

    /// After max_flow: the set of nodes reachable from s in the residual
    /// graph (the s-side of the min cut).
    pub fn min_cut_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.adj.len()];
        let mut q = std::collections::VecDeque::new();
        seen[s] = true;
        q.push_back(s);
        while let Some(v) = q.pop_front() {
            for e in &self.adj[v] {
                if e.cap > EPS && !seen[e.to] {
                    seen[e.to] = true;
                    q.push_back(e.to);
                }
            }
        }
        seen
    }
}

/// Result of a min-cut query over an op graph.
#[derive(Debug, Clone)]
pub struct CutResult {
    /// Total cut weight (bytes).
    pub weight: f64,
    /// Indices into `graph.edges` of the cut edges.
    pub cut_edges: Vec<usize>,
    /// `true` for nodes on the source side.
    pub source_side: Vec<bool>,
}

/// Minimum weighted cut separating `sources` from `sinks` in `graph`,
/// optionally ignoring some edges (e.g. those touching the removed
/// attention node).
pub fn min_cut(
    graph: &OpGraph,
    sources: &[NodeId],
    sinks: &[NodeId],
    skip_edge: impl Fn(usize, &Edge) -> bool,
) -> CutResult {
    let n = graph.nodes.len();
    let s = n;
    let t = n + 1;
    let mut dinic = Dinic::new(n + 2);
    for (i, e) in graph.edges.iter().enumerate() {
        if !skip_edge(i, e) {
            dinic.add_edge(e.src, e.dst, e.bytes, Some(i));
        }
    }
    for &src in sources {
        dinic.add_edge(s, src, f64::INFINITY, None);
    }
    for &snk in sinks {
        dinic.add_edge(snk, t, f64::INFINITY, None);
    }
    let weight = dinic.max_flow(s, t);
    let side = dinic.min_cut_side(s);
    let mut cut_edges = Vec::new();
    for (i, e) in graph.edges.iter().enumerate() {
        if !skip_edge(i, e) && side[e.src] && !side[e.dst] {
            cut_edges.push(i);
        }
    }
    CutResult { weight, cut_edges, source_side: side[..n].to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::graph::OpKind;

    #[test]
    fn simple_chain_cut_is_min_edge() {
        // a -5-> b -2-> c -7-> d : min cut between a and d is the 2-edge.
        let mut g = OpGraph::default();
        let a = g.add_node("a", OpKind::Input, None);
        let b = g.add_node("b", OpKind::MatMul, None);
        let c = g.add_node("c", OpKind::MatMul, None);
        let d = g.add_node("d", OpKind::Output, None);
        g.add_edge(a, b, 5.0);
        g.add_edge(b, c, 2.0);
        g.add_edge(c, d, 7.0);
        let cut = min_cut(&g, &[a], &[d], |_, _| false);
        assert!((cut.weight - 2.0).abs() < 1e-9);
        assert_eq!(cut.cut_edges.len(), 1);
        assert_eq!(g.edges[cut.cut_edges[0]].bytes, 2.0);
    }

    #[test]
    fn parallel_paths_sum() {
        // two disjoint paths of bottleneck 3 and 4 → min cut 7
        let mut g = OpGraph::default();
        let s = g.add_node("s", OpKind::Input, None);
        let a = g.add_node("a", OpKind::MatMul, None);
        let b = g.add_node("b", OpKind::MatMul, None);
        let t = g.add_node("t", OpKind::Output, None);
        g.add_edge(s, a, 3.0);
        g.add_edge(a, t, 9.0);
        g.add_edge(s, b, 9.0);
        g.add_edge(b, t, 4.0);
        let cut = min_cut(&g, &[s], &[t], |_, _| false);
        assert!((cut.weight - 7.0).abs() < 1e-9);
        assert_eq!(cut.cut_edges.len(), 2);
    }

    #[test]
    fn classic_maxflow_instance() {
        // CLRS-style instance with known max flow 23.
        let mut g = OpGraph::default();
        let ids: Vec<_> = (0..6)
            .map(|i| g.add_node(format!("n{i}"), OpKind::MatMul, None))
            .collect();
        let (s, v1, v2, v3, v4, t) = (ids[0], ids[1], ids[2], ids[3], ids[4], ids[5]);
        g.add_edge(s, v1, 16.0);
        g.add_edge(s, v2, 13.0);
        g.add_edge(v1, v3, 12.0);
        g.add_edge(v2, v1, 4.0);
        g.add_edge(v2, v4, 14.0);
        g.add_edge(v3, v2, 9.0);
        g.add_edge(v3, t, 20.0);
        g.add_edge(v4, v3, 7.0);
        g.add_edge(v4, t, 4.0);
        let cut = min_cut(&g, &[s], &[t], |_, _| false);
        assert!((cut.weight - 23.0).abs() < 1e-9);
    }

    #[test]
    fn cut_weight_equals_cut_edge_sum() {
        let mut g = OpGraph::default();
        let s = g.add_node("s", OpKind::Input, None);
        let a = g.add_node("a", OpKind::MatMul, None);
        let b = g.add_node("b", OpKind::MatMul, None);
        let t = g.add_node("t", OpKind::Output, None);
        g.add_edge(s, a, 2.5);
        g.add_edge(s, b, 1.5);
        g.add_edge(a, t, 1.0);
        g.add_edge(b, t, 8.0);
        g.add_edge(a, b, 0.25);
        let cut = min_cut(&g, &[s], &[t], |_, _| false);
        let sum: f64 = cut.cut_edges.iter().map(|&i| g.edges[i].bytes).sum();
        assert!((cut.weight - sum).abs() < 1e-9);
    }

    #[test]
    fn skip_edges_excluded() {
        let mut g = OpGraph::default();
        let s = g.add_node("s", OpKind::Input, None);
        let t = g.add_node("t", OpKind::Output, None);
        g.add_edge(s, t, 5.0);
        g.add_edge(s, t, 3.0);
        // skip the 5-edge → cut is just the 3-edge
        let cut = min_cut(&g, &[s], &[t], |i, _| i == 0);
        assert!((cut.weight - 3.0).abs() < 1e-9);
    }

    #[test]
    fn multi_source_sink() {
        let mut g = OpGraph::default();
        let s1 = g.add_node("s1", OpKind::Input, None);
        let s2 = g.add_node("s2", OpKind::Input, None);
        let m = g.add_node("m", OpKind::MatMul, None);
        let t1 = g.add_node("t1", OpKind::Output, None);
        let t2 = g.add_node("t2", OpKind::Output, None);
        g.add_edge(s1, m, 2.0);
        g.add_edge(s2, m, 3.0);
        g.add_edge(m, t1, 1.0);
        g.add_edge(m, t2, 1.5);
        let cut = min_cut(&g, &[s1, s2], &[t1, t2], |_, _| false);
        assert!((cut.weight - 2.5).abs() < 1e-9);
    }
}
