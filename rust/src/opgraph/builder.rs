//! Build the decode-step operator graph from an architecture description —
//! the stand-in for the paper's symbolic-execution front-end (§4.2.1):
//! given the model's shape specification, emit the weighted computation
//! graph the splitter cuts.

use super::graph::{NodeId, OpGraph, OpKind};

/// Architecture shape parameters needed to weight the graph (per-request,
/// i.e. batch size 1; edge bytes scale linearly with batch).
#[derive(Debug, Clone, Copy)]
pub struct ArchShape {
    pub d: usize,
    pub layers: usize,
    /// GQA group size (k/v are d/G wide).
    pub gqa_group: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub elem_bytes: f64,
}

impl ArchShape {
    pub fn hidden_bytes(&self) -> f64 {
        self.elem_bytes * self.d as f64
    }

    pub fn kv_bytes(&self) -> f64 {
        self.hidden_bytes() / self.gqa_group as f64
    }

    pub fn ffn_bytes(&self) -> f64 {
        self.elem_bytes * self.ffn as f64
    }
}

/// Handles to the structurally interesting nodes of the built graph.
#[derive(Debug, Clone)]
pub struct DecodeGraph {
    pub graph: OpGraph,
    pub input: NodeId,
    pub output: NodeId,
    /// Per layer: (attention node, residual-add after o_proj, q rope node,
    /// k rope node, v projection node).
    pub layer_handles: Vec<LayerHandles>,
}

#[derive(Debug, Clone, Copy)]
pub struct LayerHandles {
    pub attention: NodeId,
    pub resid_add: NodeId,
    pub rope_q: NodeId,
    pub rope_k: NodeId,
    pub v_proj: NodeId,
}

/// Construct the full decode-step op graph: embed → L × (attn block + FFN
/// block with residual connections) → final norm → LM head → argmax.
pub fn build_decode_graph(a: ArchShape) -> DecodeGraph {
    let mut g = OpGraph::default();
    let hb = a.hidden_bytes();
    let kvb = a.kv_bytes();
    let fb = a.ffn_bytes();

    let input = g.add_node("tokens", OpKind::Input, None);
    let embed = g.add_node("embed", OpKind::Embed, None);
    g.add_edge(input, embed, 4.0); // token ids, i32

    let mut resid = embed;
    let mut layer_handles = Vec::with_capacity(a.layers);
    for l in 0..a.layers {
        let attn_norm = g.add_node(format!("l{l}.attn_norm"), OpKind::RmsNorm, Some(l));
        g.add_edge(resid, attn_norm, hb);

        let q_proj = g.add_node(format!("l{l}.q_proj"), OpKind::MatMul, Some(l));
        let k_proj = g.add_node(format!("l{l}.k_proj"), OpKind::MatMul, Some(l));
        let v_proj = g.add_node(format!("l{l}.v_proj"), OpKind::MatMul, Some(l));
        g.add_edge(attn_norm, q_proj, hb);
        g.add_edge(attn_norm, k_proj, hb);
        g.add_edge(attn_norm, v_proj, hb);

        let rope_q = g.add_node(format!("l{l}.rope_q"), OpKind::Rope, Some(l));
        let rope_k = g.add_node(format!("l{l}.rope_k"), OpKind::Rope, Some(l));
        g.add_edge(q_proj, rope_q, hb);
        g.add_edge(k_proj, rope_k, kvb);

        let attention = g.add_node(format!("l{l}.attention"), OpKind::Attention, Some(l));
        g.add_edge(rope_q, attention, hb);
        g.add_edge(rope_k, attention, kvb);
        g.add_edge(v_proj, attention, kvb);

        let o_proj = g.add_node(format!("l{l}.o_proj"), OpKind::MatMul, Some(l));
        g.add_edge(attention, o_proj, hb);

        let resid_add = g.add_node(format!("l{l}.resid_add"), OpKind::Add, Some(l));
        g.add_edge(o_proj, resid_add, hb);
        g.add_edge(resid, resid_add, hb); // the residual skip over attention

        let ffn_norm = g.add_node(format!("l{l}.ffn_norm"), OpKind::RmsNorm, Some(l));
        g.add_edge(resid_add, ffn_norm, hb);
        let gate = g.add_node(format!("l{l}.gate_proj"), OpKind::MatMul, Some(l));
        let up = g.add_node(format!("l{l}.up_proj"), OpKind::MatMul, Some(l));
        g.add_edge(ffn_norm, gate, hb);
        g.add_edge(ffn_norm, up, hb);
        let silu = g.add_node(format!("l{l}.silu"), OpKind::Elementwise, Some(l));
        g.add_edge(gate, silu, fb);
        let mul = g.add_node(format!("l{l}.mul"), OpKind::Elementwise, Some(l));
        g.add_edge(silu, mul, fb);
        g.add_edge(up, mul, fb);
        let down = g.add_node(format!("l{l}.down_proj"), OpKind::MatMul, Some(l));
        g.add_edge(mul, down, fb);
        let ffn_add = g.add_node(format!("l{l}.ffn_add"), OpKind::Add, Some(l));
        g.add_edge(down, ffn_add, hb);
        g.add_edge(resid_add, ffn_add, hb); // residual skip over FFN

        layer_handles.push(LayerHandles { attention, resid_add, rope_q, rope_k, v_proj });
        resid = ffn_add;
    }

    let final_norm = g.add_node("final_norm", OpKind::RmsNorm, None);
    g.add_edge(resid, final_norm, hb);
    let lm_head = g.add_node("lm_head", OpKind::MatMul, None);
    g.add_edge(final_norm, lm_head, hb);
    let argmax = g.add_node("argmax", OpKind::ArgMax, None);
    g.add_edge(lm_head, argmax, a.elem_bytes * a.vocab as f64);
    let output = g.add_node("next_token", OpKind::Output, None);
    g.add_edge(argmax, output, 4.0);

    DecodeGraph { graph: g, input, output, layer_handles }
}

/// Shape of the repo's tiny artifact model (must match python TINY config).
pub fn tiny_shape() -> ArchShape {
    ArchShape { d: 128, layers: 4, gqa_group: 4, ffn: 256, vocab: 512, elem_bytes: 4.0 }
}

/// LLaMA3-70B shape for the analytical experiments.
pub fn llama3_70b_shape() -> ArchShape {
    ArchShape { d: 8192, layers: 80, gqa_group: 8, ffn: 28672, vocab: 128_256, elem_bytes: 2.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_count_scales_with_layers() {
        let g1 = build_decode_graph(ArchShape { layers: 1, ..tiny_shape() });
        let g4 = build_decode_graph(ArchShape { layers: 4, ..tiny_shape() });
        let per_layer = (g4.graph.nodes.len() - g1.graph.nodes.len()) / 3;
        assert_eq!(per_layer, 16); // ops per transformer block
        assert_eq!(g4.layer_handles.len(), 4);
    }

    #[test]
    fn graph_is_dag_with_valid_topo() {
        let dg = build_decode_graph(tiny_shape());
        let order = dg.graph.topo_order();
        assert!(dg.graph.is_topo_order(&order));
    }

    #[test]
    fn attention_nodes_found() {
        let dg = build_decode_graph(tiny_shape());
        let attn = dg.graph.attention_nodes();
        assert_eq!(attn.len(), 4);
        for (i, lh) in dg.layer_handles.iter().enumerate() {
            assert_eq!(attn[i], lh.attention);
        }
    }

    #[test]
    fn attention_has_three_inputs_one_output() {
        let dg = build_decode_graph(tiny_shape());
        for lh in &dg.layer_handles {
            assert_eq!(dg.graph.predecessors(lh.attention).len(), 3);
            assert_eq!(dg.graph.successors(lh.attention).len(), 1);
        }
    }

    #[test]
    fn kv_edges_shrunk_by_gqa() {
        let a = tiny_shape();
        let dg = build_decode_graph(a);
        let lh = dg.layer_handles[0];
        let kv_edge = dg
            .graph
            .edges
            .iter()
            .find(|e| e.src == lh.rope_k && e.dst == lh.attention)
            .unwrap();
        let q_edge = dg
            .graph
            .edges
            .iter()
            .find(|e| e.src == lh.rope_q && e.dst == lh.attention)
            .unwrap();
        assert!((q_edge.bytes / kv_edge.bytes - a.gqa_group as f64).abs() < 1e-9);
    }

    #[test]
    fn removing_attention_keeps_graph_connected() {
        // The paper's §4.2.1 premise: residuals keep input→output connected
        // even without attention, hence the need for a min cut.
        let dg = build_decode_graph(tiny_shape());
        let banned: std::collections::BTreeSet<_> =
            dg.graph.attention_nodes().into_iter().collect();
        // BFS from input avoiding attention nodes.
        let mut seen = vec![false; dg.graph.nodes.len()];
        let mut q = vec![dg.input];
        seen[dg.input] = true;
        while let Some(v) = q.pop() {
            for s in dg.graph.successors(v) {
                if !banned.contains(&s) && !seen[s] {
                    seen[s] = true;
                    q.push(s);
                }
            }
        }
        assert!(seen[dg.output], "residual path must reach the output");
    }
}
