//! The automated model converter (paper §4.2): operator-graph IR, min-cut
//! splitting at attention operators, and slice-program emission with the
//! Q-early resource-utilisation-overlapping reorder.

pub mod builder;
pub mod graph;
pub mod mincut;
pub mod schedule;
pub mod slicer;

pub use builder::{build_decode_graph, ArchShape, DecodeGraph};
pub use graph::{OpGraph, OpKind};
pub use schedule::{emit_programs, Instr, LayerTimings};
pub use slicer::{split_at_attention, SplitResult};
