//! The model splitter (paper §4.2.1): cut the op graph at every attention
//! operator, yielding L+1 invokable slices. Because residual connections
//! keep the graph connected after removing an attention node, each cut is a
//! *minimum weighted cut* between the graph input (plus the attention's
//! inputs) and the graph output (plus the attention's output consumer); the
//! cut edges are the inter-slice context that must be carried across
//! invocations.

use super::builder::DecodeGraph;
use super::graph::{NodeId, OpGraph, OpKind};
use super::mincut::{min_cut, CutResult};

/// One model slice.
#[derive(Debug, Clone)]
pub struct Slice {
    pub index: usize,
    /// Nodes executed by this slice, in topological order.
    pub nodes: Vec<NodeId>,
    /// Context tensors received from the previous slice (edge indices).
    pub carry_in: Vec<usize>,
    /// Context tensors passed to the next slice (edge indices).
    pub carry_out: Vec<usize>,
}

/// Result of splitting a decode graph.
#[derive(Debug, Clone)]
pub struct SplitResult {
    pub slices: Vec<Slice>,
    /// Per attention op: the min-cut found when slicing there.
    pub cuts: Vec<CutResult>,
    /// slice index of every node.
    pub node_slice: Vec<usize>,
}

/// Split at every attention operator.
///
/// Node → slice assignment: a node belongs to slice k where k = number of
/// attention operators among its ancestors (attention node a_i itself is
/// excluded — it runs on the attention workers, between slices i and i+1).
/// The min cut at each attention validates/extracts the carried context.
pub fn split_at_attention(dg: &DecodeGraph) -> SplitResult {
    let g = &dg.graph;
    let attn = g.attention_nodes();
    let n = g.nodes.len();

    // count attention ancestors per node via topo propagation
    let order = g.topo_order();
    let out_adj = g.out_adj();
    let in_adj = g.in_adj();
    let mut attn_depth = vec![0usize; n];
    for &v in &order {
        let base = attn_depth[v];
        let bump = if g.node(v).kind == OpKind::Attention { 1 } else { 0 };
        for &s in &out_adj[v] {
            attn_depth[s] = attn_depth[s].max(base + bump);
        }
    }

    // slice index per node; attention nodes assigned to the *earlier* slice
    // index purely for bookkeeping (they execute remotely).
    let node_slice: Vec<usize> = (0..n).map(|v| attn_depth[v]).collect();

    // compute the min cut at every attention op: the cut must separate
    // everything that runs *before* attention i (its ancestors) from
    // everything that runs *after* (descendants of its output); free nodes
    // fall on whichever side minimises the carried bytes.
    let mut cuts = Vec::with_capacity(attn.len());
    for &a in &attn {
        let sources = reach(&in_adj, a);
        let sinks = reach(&out_adj, a);
        let cut = min_cut(g, &sources, &sinks, |_, e| e.src == a || e.dst == a);
        cuts.push(cut);
    }

    // materialise slices
    let n_slices = attn.len() + 1;
    let mut slices: Vec<Slice> = (0..n_slices)
        .map(|i| Slice { index: i, nodes: Vec::new(), carry_in: Vec::new(), carry_out: Vec::new() })
        .collect();
    for &v in &order {
        if g.node(v).kind != OpKind::Attention {
            slices[node_slice[v]].nodes.push(v);
        }
    }
    for (i, e) in g.edges.iter().enumerate() {
        if g.node(e.src).kind == OpKind::Attention || g.node(e.dst).kind == OpKind::Attention {
            continue; // q/k/v and attention-out travel via the network, not carries
        }
        let (s0, s1) = (node_slice[e.src], node_slice[e.dst]);
        if s0 != s1 {
            slices[s0].carry_out.push(i);
            slices[s1].carry_in.push(i);
        }
    }

    SplitResult { slices, cuts, node_slice }
}

/// Strict reachable set from `node` along `adj` (excluding `node` itself).
fn reach(adj: &[Vec<NodeId>], node: NodeId) -> Vec<NodeId> {
    let mut seen = vec![false; adj.len()];
    let mut stack: Vec<NodeId> = adj[node].clone();
    let mut out = Vec::new();
    while let Some(v) = stack.pop() {
        if seen[v] {
            continue;
        }
        seen[v] = true;
        out.push(v);
        stack.extend(adj[v].iter().copied());
    }
    out
}

/// Total bytes carried between consecutive slices (per request) — what the
/// rotational pipeline must migrate when a batch hops model replicas.
pub fn carry_bytes(g: &OpGraph, slice: &Slice) -> f64 {
    slice.carry_out.iter().map(|&i| g.edges[i].bytes).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::builder::{build_decode_graph, tiny_shape, ArchShape};

    fn split_tiny() -> (DecodeGraph, SplitResult) {
        let dg = build_decode_graph(tiny_shape());
        let sr = split_at_attention(&dg);
        (dg, sr)
    }

    use crate::opgraph::builder::DecodeGraph;

    #[test]
    fn yields_l_plus_1_slices() {
        let (dg, sr) = split_tiny();
        assert_eq!(sr.slices.len(), dg.layer_handles.len() + 1);
    }

    #[test]
    fn min_cut_is_single_residual_edge() {
        // The expected context between slices is exactly the residual
        // stream: one e·d edge (the interface model.py hand-codes).
        let (dg, sr) = split_tiny();
        let hb = tiny_shape().hidden_bytes();
        for cut in &sr.cuts {
            assert!((cut.weight - hb).abs() < 1e-6, "cut weight {}", cut.weight);
            assert_eq!(cut.cut_edges.len(), 1);
            let e = dg.graph.edges[cut.cut_edges[0]];
            // it is the resid → resid_add skip edge
            assert_eq!(dg.graph.node(e.dst).kind, OpKind::Add);
        }
    }

    #[test]
    fn carries_match_cuts() {
        // The slice assignment's carried edges must equal the min cut: one
        // residual tensor between consecutive slices.
        let (dg, sr) = split_tiny();
        for s in &sr.slices[..sr.slices.len() - 1] {
            assert_eq!(s.carry_out.len(), 1, "slice {}", s.index);
            assert!((carry_bytes(&dg.graph, s) - tiny_shape().hidden_bytes()).abs() < 1e-6);
        }
        assert!(sr.slices.last().unwrap().carry_out.is_empty());
        assert!(sr.slices[0].carry_in.is_empty());
    }

    #[test]
    fn every_non_attention_node_in_exactly_one_slice() {
        let (dg, sr) = split_tiny();
        let mut count = vec![0usize; dg.graph.nodes.len()];
        for s in &sr.slices {
            for &v in &s.nodes {
                count[v] += 1;
            }
        }
        for node in &dg.graph.nodes {
            let expect = if node.kind == OpKind::Attention { 0 } else { 1 };
            assert_eq!(count[node.id], expect, "{}", node.name);
        }
    }

    #[test]
    fn slices_respect_dependencies() {
        // No edge may point from a later slice to an earlier one.
        let (dg, sr) = split_tiny();
        for e in &dg.graph.edges {
            if dg.graph.node(e.src).kind == OpKind::Attention
                || dg.graph.node(e.dst).kind == OpKind::Attention
            {
                continue;
            }
            assert!(sr.node_slice[e.src] <= sr.node_slice[e.dst]);
        }
    }

    #[test]
    fn first_slice_has_embed_last_has_head() {
        let (dg, sr) = split_tiny();
        let names = |s: &Slice| -> Vec<&str> {
            s.nodes.iter().map(|&v| dg.graph.node(v).name.as_str()).collect()
        };
        assert!(names(&sr.slices[0]).contains(&"embed"));
        assert!(names(sr.slices.last().unwrap()).contains(&"lm_head"));
        // mid slice i holds o_proj of layer i-1 and q_proj of layer i
        assert!(names(&sr.slices[1]).contains(&"l0.o_proj"));
        assert!(names(&sr.slices[1]).contains(&"l1.q_proj"));
    }

    #[test]
    fn scales_to_deep_models() {
        let dg = build_decode_graph(ArchShape { layers: 40, ..tiny_shape() });
        let sr = split_at_attention(&dg);
        assert_eq!(sr.slices.len(), 41);
        for cut in &sr.cuts {
            assert_eq!(cut.cut_edges.len(), 1);
        }
    }
}
