//! Slice program generation with resource-utilisation overlapping
//! (paper §4.2.2, Figs. 7 & 14).
//!
//! After splitting, the converter serialises each slice by a topological
//! sort that hoists Q-Proj (and its dependency cone) as early as possible,
//! inserts `SendQ` right after the Q path completes and `SendKV` at the end
//! of the slice. The attention workers can then compute the partial
//! attention over *previous* tokens while the model worker is still
//! producing K/V — hiding communication and attention work behind slice
//! compute.
//!
//! [`overlap_timeline`] is the analytic latency model of that pipeline used
//! by Fig. 12 (breakdown) and Fig. 14 (overlap on/off).

use super::builder::DecodeGraph;
use super::graph::{NodeId, OpKind};
use super::slicer::SplitResult;

/// One instruction of a serialised slice program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Instr {
    /// Execute an operator node locally.
    Compute(NodeId),
    /// Transmit q for layer `layer` to the attention workers.
    SendQ { layer: usize },
    /// Transmit k_new/v_new for layer `layer`.
    SendKV { layer: usize },
    /// Await the attention output of layer `layer`.
    RecvAttn { layer: usize },
}

/// Serialise every slice with the Q-early heuristic.
///
/// Priorities (lower = earlier, subject to dependencies):
///   0 = ancestors of the next layer's rope_q (the Q path),
///   1 = ancestors of k/v sends,
///   2 = everything else.
pub fn emit_programs(dg: &DecodeGraph, sr: &SplitResult) -> Vec<Vec<Instr>> {
    let g = &dg.graph;
    let n = g.nodes.len();

    // mark ancestor cones of each layer's q and kv nodes
    let in_adj = g.in_adj();
    let mut q_cone = vec![false; n];
    let mut kv_cone = vec![false; n];
    for lh in &dg.layer_handles {
        mark_ancestors(dg, &in_adj, lh.rope_q, &mut q_cone);
        mark_ancestors(dg, &in_adj, lh.rope_k, &mut kv_cone);
        mark_ancestors(dg, &in_adj, lh.v_proj, &mut kv_cone);
    }

    let order = g.topo_order_by(|node| {
        if q_cone[node.id] {
            0
        } else if kv_cone[node.id] {
            1
        } else {
            2
        }
    });
    debug_assert!(g.is_topo_order(&order));
    let pos: Vec<usize> = {
        let mut p = vec![0; n];
        for (i, &v) in order.iter().enumerate() {
            p[v] = i;
        }
        p
    };

    let mut programs = Vec::with_capacity(sr.slices.len());
    for slice in &sr.slices {
        let mut nodes: Vec<NodeId> = slice.nodes.clone();
        nodes.sort_by_key(|&v| pos[v]);

        let mut prog: Vec<Instr> = Vec::with_capacity(nodes.len() + 3);
        // A mid slice starts by consuming the previous layer's attention out.
        let consumes_attn = nodes.iter().any(|&v| {
            in_adj[v].iter().any(|&p| g.node(p).kind == OpKind::Attention)
        });
        if consumes_attn {
            let layer = slice.index - 1;
            prog.push(Instr::RecvAttn { layer });
        }

        let this_layer = if slice.index < dg.layer_handles.len() {
            Some(slice.index)
        } else {
            None
        };
        let lh = this_layer.map(|l| dg.layer_handles[l]);

        for &v in &nodes {
            prog.push(Instr::Compute(v));
            if let Some(lh) = lh {
                if v == lh.rope_q {
                    prog.push(Instr::SendQ { layer: slice.index });
                }
            }
        }
        if let Some(l) = this_layer {
            prog.push(Instr::SendKV { layer: l });
        }
        programs.push(prog);
    }
    programs
}

fn mark_ancestors(dg: &DecodeGraph, in_adj: &[Vec<NodeId>], node: NodeId, mark: &mut [bool]) {
    let mut stack = vec![node];
    while let Some(v) = stack.pop() {
        if mark[v] {
            continue;
        }
        mark[v] = true;
        for &p in &in_adj[v] {
            // stop at attention boundaries: remote ops are not local deps
            if dg.graph.node(p).kind != OpKind::Attention {
                stack.push(p);
            }
        }
    }
}

/// Per-layer latency timeline of the disaggregated decode step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerTimings {
    /// Model-slice compute time (o_proj + FFN + next qkv), seconds.
    pub t_slice: f64,
    /// Fraction of `t_slice` until q is ready to send (the Q-early point).
    pub q_ready_frac: f64,
    /// Attention-worker time over the *cached* tokens.
    pub t_attn_prev: f64,
    /// Attention-worker time to fold in the new token (tiny).
    pub t_attn_new: f64,
    /// One-way network latency for the q message.
    pub net_q: f64,
    /// One-way latency for the k/v message.
    pub net_kv: f64,
    /// One-way latency for the attention-output message.
    pub net_out: f64,
}

/// Per-layer decode latency **without** overlapping (Fig. 7a): strictly
/// sequential slice → send qkv → attention → return.
pub fn layer_latency_sequential(t: &LayerTimings) -> f64 {
    t.t_slice + t.net_q.max(t.net_kv) + t.t_attn_prev + t.t_attn_new + t.net_out
}

/// Per-layer decode latency **with** resource-utilisation overlapping
/// (Fig. 7b): q is sent at `q_ready_frac·t_slice`; the attention worker
/// processes previous tokens while the model worker finishes the slice and
/// ships k/v; the new token is folded in on arrival.
pub fn layer_latency_overlapped(t: &LayerTimings) -> f64 {
    let q_sent = t.q_ready_frac * t.t_slice + t.net_q;
    let prev_done = q_sent + t.t_attn_prev;
    let kv_arrived = t.t_slice + t.net_kv;
    prev_done.max(kv_arrived) + t.t_attn_new + t.net_out
}

/// Fractional latency saving of overlapping for the given timings.
pub fn overlap_saving(t: &LayerTimings) -> f64 {
    let seq = layer_latency_sequential(t);
    let ovl = layer_latency_overlapped(t);
    (seq - ovl) / seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::builder::{build_decode_graph, tiny_shape};
    use crate::opgraph::slicer::split_at_attention;

    fn programs() -> (DecodeGraph, Vec<Vec<Instr>>) {
        let dg = build_decode_graph(tiny_shape());
        let sr = split_at_attention(&dg);
        let progs = emit_programs(&dg, &sr);
        (dg, progs)
    }

    use crate::opgraph::builder::DecodeGraph;

    #[test]
    fn one_program_per_slice() {
        let (dg, progs) = programs();
        assert_eq!(progs.len(), dg.layer_handles.len() + 1);
    }

    #[test]
    fn sendq_before_sendkv_every_mid_slice() {
        let (_, progs) = programs();
        for prog in &progs[..progs.len() - 1] {
            let iq = prog.iter().position(|i| matches!(i, Instr::SendQ { .. }));
            let ikv = prog.iter().position(|i| matches!(i, Instr::SendKV { .. }));
            assert!(iq.unwrap() < ikv.unwrap());
        }
    }

    #[test]
    fn q_sent_before_kv_projections() {
        // Q-Proj depends on the previous layer's FFN output, so the earliest
        // legal send point is right after rope_q — before K-Proj/V-Proj run.
        // That is exactly the §4.2.2 reorder (Fig. 7b): the attention worker
        // computes prev-token attention while the model worker projects K/V.
        let (dg, progs) = programs();
        for (si, prog) in progs.iter().enumerate().take(dg.layer_handles.len()) {
            let iq = prog
                .iter()
                .position(|i| matches!(i, Instr::SendQ { .. }))
                .unwrap_or_else(|| panic!("slice {si} lacks SendQ"));
            let kv_after = prog[iq..].iter().any(|i| match i {
                Instr::Compute(v) => {
                    let n = &dg.graph.node(*v).name;
                    n.contains("k_proj") || n.contains("v_proj")
                }
                _ => false,
            });
            assert!(kv_after, "slice {si}: K/V projections should follow SendQ");
        }
    }

    #[test]
    fn mid_slices_start_with_recv() {
        let (_, progs) = programs();
        for prog in progs.iter().skip(1) {
            assert!(matches!(prog[0], Instr::RecvAttn { .. }));
        }
        assert!(!matches!(programs().1[0][0], Instr::RecvAttn { .. }));
    }

    #[test]
    fn compute_order_is_topological() {
        let (dg, progs) = programs();
        let mut seen = vec![false; dg.graph.nodes.len()];
        for prog in &progs {
            for instr in prog {
                if let Instr::Compute(v) = instr {
                    for p in dg.graph.predecessors(*v) {
                        if dg.graph.node(p).kind != OpKind::Attention {
                            assert!(seen[p], "dep {} of {} not yet computed",
                                dg.graph.node(p).name, dg.graph.node(*v).name);
                        }
                    }
                    seen[*v] = true;
                }
            }
        }
    }

    #[test]
    fn last_slice_has_no_sends() {
        let (_, progs) = programs();
        let last = progs.last().unwrap();
        assert!(!last.iter().any(|i| matches!(i, Instr::SendQ { .. } | Instr::SendKV { .. })));
    }

    fn typical_timings() -> LayerTimings {
        LayerTimings {
            t_slice: 300e-6,
            q_ready_frac: 0.85, // Q ready after the FFN + Q-proj; K/V remain
            t_attn_prev: 200e-6,
            t_attn_new: 5e-6,
            net_q: 20e-6,
            net_kv: 25e-6,
            net_out: 20e-6,
        }
    }

    #[test]
    fn overlap_never_slower() {
        let t = typical_timings();
        assert!(layer_latency_overlapped(&t) <= layer_latency_sequential(&t) + 1e-12);
    }

    #[test]
    fn overlap_saving_grows_with_kv_transfer() {
        // Fig. 14: bigger batches / G=1 → bigger KV tensors → more transfer
        // hidden behind prev-token attention → larger saving.
        let small = LayerTimings { net_kv: 10e-6, ..typical_timings() };
        let large = LayerTimings { net_kv: 80e-6, ..typical_timings() };
        assert!(overlap_saving(&large) > overlap_saving(&small));
    }

    #[test]
    fn overlap_hides_network_when_attention_dominates() {
        // If prev-attention finishes after kv arrival, kv latency is hidden.
        let t = LayerTimings { t_attn_prev: 400e-6, ..typical_timings() };
        let ovl = layer_latency_overlapped(&t);
        let expect = t.q_ready_frac * t.t_slice + t.net_q + t.t_attn_prev
            + t.t_attn_new + t.net_out;
        assert!((ovl - expect).abs() < 1e-12);
    }

    #[test]
    fn saving_in_paper_range_for_mha() {
        // LLaMA-65B-like ratios: saving should land in the ~5–15 % band
        // (paper: up to 13.2 %).
        let t = LayerTimings {
            t_slice: 280e-6,
            q_ready_frac: 0.85,
            t_attn_prev: 260e-6,
            t_attn_new: 4e-6,
            net_q: 18e-6,
            net_kv: 30e-6,
            net_out: 18e-6,
        };
        let s = overlap_saving(&t);
        assert!(s > 0.04 && s < 0.25, "saving={s}");
    }
}
