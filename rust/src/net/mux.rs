//! Readiness multiplexing over worker sockets, used by the leader's
//! receive path so W workers are serviced concurrently: instead of the
//! old sequential per-worker blocking receive (which let one slow shard
//! serialize the whole step and charge its stall to the *next* worker's
//! deadline), the leader polls every outstanding socket at once and
//! drains whichever answers first.
//!
//! Implemented directly on `poll(2)` via a minimal FFI declaration
//! against the system libc — std exposes no readiness API and the build
//! is vendored-deps-only. Unix-only; on other platforms
//! [`supported`] reports `false` and the leader keeps its sequential
//! path (as it does for inproc links, which have no fd to poll).
//!
//! The time spent parked in `poll` is charged to the `net.mux_wait_ns`
//! counter in the obs registry — the leader's "waiting on stragglers"
//! budget, to set against per-worker turnaround spans on the trace
//! timeline.

use std::io;
use std::time::{Duration, Instant};

use crate::obs;

/// Whether readiness multiplexing works on this platform.
pub fn supported() -> bool {
    cfg!(unix)
}

fn mux_wait_counter() -> &'static obs::Counter {
    static C: std::sync::OnceLock<obs::Counter> = std::sync::OnceLock::new();
    C.get_or_init(|| obs::registry().counter("net.mux_wait_ns"))
}

#[cfg(unix)]
mod sys {
    #[repr(C)]
    pub struct Pollfd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    // nfds_t: unsigned long on Linux (pointer-sized), unsigned int on
    // macOS. Declared per-OS so the FFI ABI is exact.
    #[cfg(target_os = "macos")]
    pub type Nfds = u32;
    #[cfg(not(target_os = "macos"))]
    pub type Nfds = usize;

    extern "C" {
        pub fn poll(fds: *mut Pollfd, nfds: Nfds, timeout_ms: i32) -> i32;
    }
}

/// Block until at least one of `fds` is readable (or has hung up /
/// errored — both mean "calling recv will return promptly with the
/// truth") or `timeout` expires. Returns the **indices into `fds`** that
/// are ready; empty means the timeout expired.
///
/// Readiness is level-triggered and advisory: the caller must still use
/// its normal (typed, deadline-guarded) receive on the ready links — a
/// spurious wakeup costs one short receive attempt, never a hang.
#[cfg(unix)]
pub fn wait_readable(fds: &[i32], timeout: Duration) -> io::Result<Vec<usize>> {
    if fds.is_empty() {
        return Ok(Vec::new());
    }
    let t0 = Instant::now();
    let deadline = t0 + timeout;
    let mut pfds: Vec<sys::Pollfd> = fds
        .iter()
        .map(|&fd| sys::Pollfd { fd, events: sys::POLLIN, revents: 0 })
        .collect();
    let ready = loop {
        let left = deadline.saturating_duration_since(Instant::now());
        // round sub-millisecond remainders *up* so a 400us deadline
        // parks instead of busy-spinning through poll(…, 0)
        let ms = left.as_millis().min(i32::MAX as u128) as i32;
        let ms = if ms == 0 && !left.is_zero() { 1 } else { ms };
        for p in pfds.iter_mut() {
            p.revents = 0;
        }
        let rc = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len() as sys::Nfds, ms) };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            mux_wait_counter().add(t0.elapsed().as_nanos() as u64);
            return Err(e);
        }
        if rc == 0 {
            if Instant::now() >= deadline {
                break Vec::new(); // timed out
            }
            continue;
        }
        let hits: Vec<usize> = pfds
            .iter()
            .enumerate()
            .filter(|(_, p)| p.revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP) != 0)
            .map(|(i, _)| i)
            .collect();
        if !hits.is_empty() {
            break hits;
        }
    };
    mux_wait_counter().add(t0.elapsed().as_nanos() as u64);
    Ok(ready)
}

/// Non-unix fallback: report unsupported so callers keep their
/// sequential path (gated by [`supported`], so this is defensive).
#[cfg(not(unix))]
pub fn wait_readable(_fds: &[i32], _timeout: Duration) -> io::Result<Vec<usize>> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "readiness mux needs poll(2)"))
}

/// Accept with a deadline: poll the listener for readability, then
/// accept. `Ok(None)` on timeout. Used by tests and harnesses that must
/// never hang on a leader that isn't coming; the standalone worker
/// binary accepts in a plain blocking loop instead.
#[cfg(unix)]
pub fn accept_timeout(
    listener: &std::net::TcpListener,
    timeout: Duration,
) -> io::Result<Option<(std::net::TcpStream, std::net::SocketAddr)>> {
    use std::os::unix::io::AsRawFd;
    if wait_readable(&[listener.as_raw_fd()], timeout)?.is_empty() {
        return Ok(None);
    }
    listener.accept().map(Some)
}

#[cfg(not(unix))]
pub fn accept_timeout(
    listener: &std::net::TcpListener,
    _timeout: Duration,
) -> io::Result<Option<(std::net::TcpStream, std::net::SocketAddr)>> {
    // no readiness primitive: block (callers on non-unix accept the hang
    // risk; every supported platform is unix)
    listener.accept().map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    #[cfg(unix)]
    use std::os::unix::io::AsRawFd;

    fn loopback_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[cfg(unix)]
    #[test]
    fn timeout_with_no_data_returns_empty() {
        let (a, _b) = loopback_pair();
        let t0 = Instant::now();
        let ready = wait_readable(&[a.as_raw_fd()], Duration::from_millis(30)).unwrap();
        assert!(ready.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25), "returned too early");
    }

    #[cfg(unix)]
    #[test]
    fn ready_fd_is_reported_by_index() {
        let (a, b) = loopback_pair();
        let (c, mut d) = loopback_pair();
        d.write_all(b"x").unwrap();
        let ready =
            wait_readable(&[a.as_raw_fd(), c.as_raw_fd()], Duration::from_secs(2)).unwrap();
        assert_eq!(ready, vec![1], "only the written-to socket is readable");
        drop(b);
        // a's peer hung up: now both report ready (HUP counts)
        let ready =
            wait_readable(&[a.as_raw_fd(), c.as_raw_fd()], Duration::from_secs(2)).unwrap();
        assert!(ready.contains(&0));
    }

    #[cfg(unix)]
    #[test]
    fn mux_wait_counter_accumulates() {
        let (a, _b) = loopback_pair();
        let before = mux_wait_counter().get();
        let _ = wait_readable(&[a.as_raw_fd()], Duration::from_millis(10)).unwrap();
        assert!(mux_wait_counter().get() > before);
    }

    #[test]
    fn accept_timeout_times_out_then_accepts() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        assert!(accept_timeout(&l, Duration::from_millis(20)).is_ok());
        let addr = l.local_addr().unwrap();
        let _c = TcpStream::connect(addr).unwrap();
        let got = accept_timeout(&l, Duration::from_secs(5)).unwrap();
        assert!(got.is_some());
    }
}
