//! Versioned, length-prefixed binary frame codec for [`WireMsg`].
//!
//! This is the byte format both real-socket endpoints speak. One message =
//! one frame:
//!
//! ```text
//! offset  size  field
//! 0       2     magic           0x1A31 (LE) — stream resync guard
//! 2       1     version         FORMAT_VERSION (currently 3)
//! 3       1     msg type tag    0..=9, one per WireMsg variant
//! 4       4     payload length  u32 LE (bytes after the 12-byte header)
//! 8       4     checksum        u32 LE, FNV-1a over version ‖ tag ‖ payload
//! 12      n     payload         variant-specific, all integers LE
//! ```
//!
//! The checksum covers the version and tag bytes as well as the payload, so
//! a corrupted tag (which would re-interpret the payload under the wrong
//! schema) is rejected as `BadChecksum` rather than mis-parsed.
//!
//! **Tensor encoding.** A [`HostTensor`] payload is `dtype:u8` (0 = f32,
//! 1 = i32), `ndim:u8`, `ndim × u32` dims, then the raw element bytes
//! (4 bytes each, LE). Decoding builds the element buffer *directly* as an
//! `Arc`-backed allocation, so the wire path is one copy in — receive
//! buffer → tensor — and zero-copy from there on (every later send/clone
//! moves the `Arc`).
//!
//! **f32/i32 encode fast path.** On little-endian targets the in-memory
//! element representation *is* the wire representation, so tensor (and
//! `slots`) payloads are encoded with one bulk byte-cast
//! `extend_from_slice` — no per-element `to_le_bytes` loop with its
//! per-push growth checks on the hot path (that loop previously bounded
//! encode GB/s; see the `net/codec` rows in `BENCH_decode.json`, which
//! keep the element-wise variant as a baseline). Big-endian targets fall
//! back to the portable element-wise conversion
//! ([`put_f32_le_elementwise`] & co.), bit-for-bit the same wire format.
//! Decode keeps the single-pass `TrustedLen` collect straight into the
//! `Arc` allocation on every target (see [`HostTensor`] docs: one copy in),
//! where LE `from_le_bytes` is already a bit-level no-op.
//!
//! **Streaming.** [`decode_frame`] is incremental: given a prefix of the
//! byte stream it returns `Ok(None)` ("need more bytes") until a full frame
//! is buffered, which is what lets the TCP transport keep a partial frame
//! across read timeouts without losing sync. All decode failures are typed
//! [`CodecError`]s — corrupt input can never panic (bounds, dims, element
//! counts and vector lengths are validated before any allocation).
//!
//! Vectors (`slots`, `lens`) are `u32 count` + packed elements. `usize`
//! protocol fields travel as `u32` (layer, seq bucket and chunk sizes are
//! bounded far below that in practice).

use std::sync::Arc;

use crate::metrics::KvCacheStats;
use crate::runtime::host::{Dtype, HostTensor};
use crate::workers::messages::WireMsg;

/// First two bytes of every frame.
pub const MAGIC: u16 = 0x1A31;
/// Current frame-format version.
/// v2: `KvStats` payload gained `bytes_in_use`/`total_bytes` (the
/// dtype-aware byte view of arena occupancy under `--kv-dtype`).
/// v3: new `MapBlocks` message (tag 9, prefix sharing: map a donor slot's
/// block chain into a destination slot) and `KvStats` gained the
/// `physical_blocks_in_use`/`physical_bytes_in_use` dedup view.
/// v4: elastic membership — new `Hello` (tag 10) / `Welcome` (tag 11)
/// handshake frames (codec-version check + negotiated KV-head range +
/// membership epoch), and `KvStats` gained the worker's echoed membership
/// `epoch` for the leader's reshard fencing barrier.
pub const FORMAT_VERSION: u8 = 4;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Hard caps a decoder enforces before allocating (corrupt-input defense).
const MAX_PAYLOAD: usize = 1 << 30;
const MAX_DIMS: usize = 8;
const MAX_TENSOR_ELEMS: usize = 1 << 27; // 512 MiB of f32
const MAX_VEC_LEN: usize = 1 << 20;

/// Typed decode failure. `Truncated`/`Malformed` mean a structurally broken
/// frame; `BadChecksum` means bit corruption in transit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    BadMagic(u16),
    BadVersion(u8),
    UnknownType(u8),
    BadChecksum { want: u32, got: u32 },
    Truncated(&'static str),
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::BadMagic(m) => write!(f, "bad frame magic {m:#06x}"),
            CodecError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            CodecError::UnknownType(t) => write!(f, "unknown message type {t}"),
            CodecError::BadChecksum { want, got } => {
                write!(f, "frame checksum mismatch (want {want:#010x}, got {got:#010x})")
            }
            CodecError::Truncated(what) => write!(f, "truncated frame ({what})"),
            CodecError::Malformed(why) => write!(f, "malformed frame: {why}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// FNV-1a 32-bit over `version ‖ tag ‖ payload`.
fn checksum(version: u8, tag: u8, payload: &[u8]) -> u32 {
    fn step(h: u32, b: u8) -> u32 {
        (h ^ b as u32).wrapping_mul(0x0100_0193)
    }
    let mut h = step(step(0x811c_9dc5, version), tag);
    for &b in payload {
        h = step(h, b);
    }
    h
}

fn tag_of(msg: &WireMsg) -> u8 {
    match msg {
        WireMsg::StepQ { .. } => 0,
        WireMsg::StepKv { .. } => 1,
        WireMsg::PrefillChunk { .. } => 2,
        WireMsg::AttnOut { .. } => 3,
        WireMsg::Retire { .. } => 4,
        WireMsg::KvStatsReq => 5,
        WireMsg::KvStats { .. } => 6,
        WireMsg::WorkerError { .. } => 7,
        WireMsg::Shutdown => 8,
        WireMsg::MapBlocks { .. } => 9,
        WireMsg::Hello { .. } => 10,
        WireMsg::Welcome { .. } => 11,
    }
}

// ---- encode ---------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &HostTensor) {
    out.push(match t.dtype() {
        Dtype::F32 => 0,
        Dtype::I32 => 1,
    });
    out.push(t.shape().len() as u8);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    match t.dtype() {
        Dtype::F32 => put_f32_payload(out, t.as_f32()),
        Dtype::I32 => put_i32_payload(out, t.as_i32()),
    }
}

// ---- element-payload fast path (LE bulk byte-cast) ------------------------

/// Portable element-wise LE conversion — the big-endian fallback and the
/// bench suite's baseline for the bulk-cast fast path.
pub fn put_f32_le_elementwise(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// i32 twin of [`put_f32_le_elementwise`].
pub fn put_i32_le_elementwise(out: &mut Vec<u8>, xs: &[i32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Portable element-wise LE decode — fallback + bench baseline.
pub fn get_f32_le_elementwise(bytes: &[u8]) -> Arc<[f32]> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// i32 twin of [`get_f32_le_elementwise`].
pub fn get_i32_le_elementwise(bytes: &[u8]) -> Arc<[i32]> {
    bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(target_endian = "little")]
fn put_f32_payload(out: &mut Vec<u8>, xs: &[f32]) {
    // On LE targets the in-memory bytes ARE the wire bytes: one memcpy.
    // SAFETY: every f32 bit pattern is a valid sequence of u8s, the cast
    // only lowers alignment, and the length covers exactly `xs`.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) };
    out.extend_from_slice(bytes);
}

#[cfg(target_endian = "little")]
fn put_i32_payload(out: &mut Vec<u8>, xs: &[i32]) {
    // SAFETY: as in `put_f32_payload`.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) };
    out.extend_from_slice(bytes);
}

#[cfg(not(target_endian = "little"))]
fn put_f32_payload(out: &mut Vec<u8>, xs: &[f32]) {
    put_f32_le_elementwise(out, xs);
}

#[cfg(not(target_endian = "little"))]
fn put_i32_payload(out: &mut Vec<u8>, xs: &[i32]) {
    put_i32_le_elementwise(out, xs);
}

/// Decode stays the single-pass `chunks_exact → collect::<Arc<_>>` on every
/// target: the `TrustedLen` collect writes the `Arc` allocation directly
/// (one copy in, as documented), and on LE `from_le_bytes` is a bit-level
/// no-op, so this *is* the bulk path — a byte-cast staging `Vec` would add
/// a second copy (`From<Vec>` reallocates for the `Arc` header).
fn get_f32_payload(bytes: &[u8]) -> Arc<[f32]> {
    get_f32_le_elementwise(bytes)
}

/// See [`get_f32_payload`].
fn get_i32_payload(bytes: &[u8]) -> Arc<[i32]> {
    get_i32_le_elementwise(bytes)
}

#[cfg(target_endian = "little")]
fn put_u32_payload(out: &mut Vec<u8>, xs: &[u32]) {
    // SAFETY: as in `put_f32_payload`.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) };
    out.extend_from_slice(bytes);
}

#[cfg(not(target_endian = "little"))]
fn put_u32_payload(out: &mut Vec<u8>, xs: &[u32]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32_slice(out: &mut Vec<u8>, xs: &[u32]) {
    put_u32(out, xs.len() as u32);
    put_u32_payload(out, xs);
}

fn put_i32_slice(out: &mut Vec<u8>, xs: &[i32]) {
    put_u32(out, xs.len() as u32);
    put_i32_payload(out, xs);
}

fn encode_payload(msg: &WireMsg, out: &mut Vec<u8>) {
    match msg {
        WireMsg::StepQ { layer, slots, q, lens, seq_bucket, overlap } => {
            put_u32(out, *layer as u32);
            put_u32(out, *seq_bucket as u32);
            out.push(*overlap as u8);
            put_u32_slice(out, slots);
            put_i32_slice(out, lens);
            put_tensor(out, q);
        }
        WireMsg::StepKv { layer, k, v } => {
            put_u32(out, *layer as u32);
            put_tensor(out, k);
            put_tensor(out, v);
        }
        WireMsg::PrefillChunk { layer, slot, q, k, v, cached, valid, seq_bucket } => {
            put_u32(out, *layer as u32);
            put_u32(out, *slot);
            out.extend_from_slice(&cached.to_le_bytes());
            put_u32(out, *valid as u32);
            put_u32(out, *seq_bucket as u32);
            put_tensor(out, q);
            put_tensor(out, k);
            put_tensor(out, v);
        }
        WireMsg::AttnOut { layer, out: t } => {
            put_u32(out, *layer as u32);
            put_tensor(out, t);
        }
        WireMsg::Retire { slot } => put_u32(out, *slot),
        WireMsg::KvStatsReq => {}
        WireMsg::KvStats { stats, epoch } => {
            put_u64(out, stats.blocks_in_use as u64);
            put_u64(out, stats.total_blocks as u64);
            put_u32(out, stats.block_size as u32);
            put_u64(out, stats.internal_waste_tokens as u64);
            put_u64(out, stats.bytes_in_use as u64);
            put_u64(out, stats.total_bytes as u64);
            put_u64(out, stats.physical_blocks_in_use as u64);
            put_u64(out, stats.physical_bytes_in_use as u64);
            put_u64(out, *epoch);
        }
        WireMsg::WorkerError { msg } => {
            put_u32(out, msg.len() as u32);
            out.extend_from_slice(msg.as_bytes());
        }
        WireMsg::Shutdown => {}
        WireMsg::MapBlocks { slot, src_slot, tokens } => {
            put_u32(out, *slot);
            put_u32(out, *src_slot);
            put_u32(out, *tokens as u32);
        }
        WireMsg::Hello { codec_version, shard } => {
            put_u32(out, *codec_version);
            put_u32(out, *shard);
        }
        WireMsg::Welcome { epoch, kv_start, kv_count, slots, kv_block_size, layers, head_dim, max_seq } => {
            put_u64(out, *epoch);
            put_u32(out, *kv_start);
            put_u32(out, *kv_count);
            put_u32(out, *slots);
            put_u32(out, *kv_block_size);
            put_u32(out, *layers);
            put_u32(out, *head_dim);
            put_u32(out, *max_seq);
        }
    }
}

/// Append one complete frame for `msg` to `out`; returns the frame size in
/// bytes. `out` is not cleared (callers batch frames into one write).
pub fn encode(msg: &WireMsg, out: &mut Vec<u8>) -> usize {
    let start = out.len();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(FORMAT_VERSION);
    let tag = tag_of(msg);
    out.push(tag);
    out.extend_from_slice(&[0u8; 8]); // length + checksum backpatched below
    let body = out.len();
    encode_payload(msg, out);
    let plen = (out.len() - body) as u32;
    let sum = checksum(FORMAT_VERSION, tag, &out[body..]);
    out[start + 4..start + 8].copy_from_slice(&plen.to_le_bytes());
    out[start + 8..start + 12].copy_from_slice(&sum.to_le_bytes());
    out.len() - start
}

/// Exact wire size of `msg`'s frame without materialising it.
pub fn encoded_len(msg: &WireMsg) -> usize {
    let tensor = |t: &HostTensor| 2 + 4 * t.shape().len() + t.byte_size();
    HEADER_LEN
        + match msg {
            WireMsg::StepQ { slots, q, lens, .. } => {
                4 + 4 + 1 + (4 + 4 * slots.len()) + (4 + 4 * lens.len()) + tensor(q)
            }
            WireMsg::StepKv { k, v, .. } => 4 + tensor(k) + tensor(v),
            WireMsg::PrefillChunk { q, k, v, .. } => {
                4 + 4 + 4 + 4 + 4 + tensor(q) + tensor(k) + tensor(v)
            }
            WireMsg::AttnOut { out, .. } => 4 + tensor(out),
            WireMsg::Retire { .. } => 4,
            WireMsg::KvStatsReq => 0,
            WireMsg::KvStats { .. } => 8 + 8 + 4 + 8 + 8 + 8 + 8 + 8 + 8,
            WireMsg::WorkerError { msg } => 4 + msg.len(),
            WireMsg::Shutdown => 0,
            WireMsg::MapBlocks { .. } => 4 + 4 + 4,
            WireMsg::Hello { .. } => 4 + 4,
            WireMsg::Welcome { .. } => 8 + 4 * 7,
        }
}

// ---- decode ---------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CodecError> {
        if self.buf.len() - self.pos < n {
            return Err(CodecError::Truncated(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, CodecError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn i32(&mut self, what: &'static str) -> Result<i32, CodecError> {
        Ok(i32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn finish(&self) -> Result<(), CodecError> {
        if self.pos != self.buf.len() {
            return Err(CodecError::Malformed(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn get_vec_len(r: &mut Reader, what: &'static str) -> Result<usize, CodecError> {
    let n = r.u32(what)? as usize;
    if n > MAX_VEC_LEN {
        return Err(CodecError::Malformed(format!("{what} length {n} exceeds cap")));
    }
    Ok(n)
}

fn get_u32_vec(r: &mut Reader, what: &'static str) -> Result<Vec<u32>, CodecError> {
    let n = get_vec_len(r, what)?;
    let bytes = r.take(4 * n, what)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn get_i32_vec(r: &mut Reader, what: &'static str) -> Result<Vec<i32>, CodecError> {
    let n = get_vec_len(r, what)?;
    let bytes = r.take(4 * n, what)?;
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn get_tensor(r: &mut Reader) -> Result<HostTensor, CodecError> {
    let dtype = r.u8("tensor dtype")?;
    let ndim = r.u8("tensor ndim")? as usize;
    if ndim > MAX_DIMS {
        return Err(CodecError::Malformed(format!("tensor rank {ndim} exceeds cap")));
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut elems: usize = 1;
    for _ in 0..ndim {
        let d = r.u32("tensor dim")? as usize;
        elems = elems
            .checked_mul(d)
            .filter(|&e| e <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| CodecError::Malformed("tensor element count overflow".into()))?;
        shape.push(d);
    }
    let bytes = r.take(4 * elems, "tensor data")?;
    match dtype {
        // one copy: receive buffer → the tensor's own Arc allocation
        // (single-pass TrustedLen collect; LE from_le_bytes is a bit no-op)
        0 => Ok(HostTensor::f32_arc(shape, get_f32_payload(bytes))),
        1 => Ok(HostTensor::i32_arc(shape, get_i32_payload(bytes))),
        d => Err(CodecError::Malformed(format!("unknown tensor dtype {d}"))),
    }
}

fn decode_payload(tag: u8, payload: &[u8]) -> Result<WireMsg, CodecError> {
    let mut r = Reader { buf: payload, pos: 0 };
    let msg = match tag {
        0 => {
            let layer = r.u32("layer")? as usize;
            let seq_bucket = r.u32("seq_bucket")? as usize;
            let overlap = r.u8("overlap")? != 0;
            let slots = get_u32_vec(&mut r, "slots")?;
            let lens = get_i32_vec(&mut r, "lens")?;
            let q = get_tensor(&mut r)?;
            WireMsg::StepQ { layer, slots, q, lens, seq_bucket, overlap }
        }
        1 => {
            let layer = r.u32("layer")? as usize;
            let k = get_tensor(&mut r)?;
            let v = get_tensor(&mut r)?;
            WireMsg::StepKv { layer, k, v }
        }
        2 => {
            let layer = r.u32("layer")? as usize;
            let slot = r.u32("slot")?;
            let cached = r.i32("cached")?;
            let valid = r.u32("valid")? as usize;
            let seq_bucket = r.u32("seq_bucket")? as usize;
            let q = get_tensor(&mut r)?;
            let k = get_tensor(&mut r)?;
            let v = get_tensor(&mut r)?;
            WireMsg::PrefillChunk { layer, slot, q, k, v, cached, valid, seq_bucket }
        }
        3 => {
            let layer = r.u32("layer")? as usize;
            let out = get_tensor(&mut r)?;
            WireMsg::AttnOut { layer, out }
        }
        4 => WireMsg::Retire { slot: r.u32("slot")? },
        5 => WireMsg::KvStatsReq,
        6 => {
            let stats = KvCacheStats {
                blocks_in_use: r.u64("blocks_in_use")? as usize,
                total_blocks: r.u64("total_blocks")? as usize,
                block_size: r.u32("block_size")? as usize,
                internal_waste_tokens: r.u64("internal_waste")? as usize,
                bytes_in_use: r.u64("bytes_in_use")? as usize,
                total_bytes: r.u64("total_bytes")? as usize,
                physical_blocks_in_use: r.u64("physical_blocks_in_use")? as usize,
                physical_bytes_in_use: r.u64("physical_bytes_in_use")? as usize,
            };
            let epoch = r.u64("epoch")?;
            WireMsg::KvStats { stats, epoch }
        }
        7 => {
            let n = get_vec_len(&mut r, "error text")?;
            let bytes = r.take(n, "error text")?;
            let msg = String::from_utf8(bytes.to_vec())
                .map_err(|_| CodecError::Malformed("error text not utf-8".into()))?;
            WireMsg::WorkerError { msg }
        }
        8 => WireMsg::Shutdown,
        9 => {
            let slot = r.u32("slot")?;
            let src_slot = r.u32("src_slot")?;
            let tokens = r.u32("tokens")? as usize;
            WireMsg::MapBlocks { slot, src_slot, tokens }
        }
        10 => {
            let codec_version = r.u32("codec_version")?;
            let shard = r.u32("shard")?;
            WireMsg::Hello { codec_version, shard }
        }
        11 => {
            let epoch = r.u64("epoch")?;
            let kv_start = r.u32("kv_start")?;
            let kv_count = r.u32("kv_count")?;
            let slots = r.u32("slots")?;
            let kv_block_size = r.u32("kv_block_size")?;
            let layers = r.u32("layers")?;
            let head_dim = r.u32("head_dim")?;
            let max_seq = r.u32("max_seq")?;
            WireMsg::Welcome { epoch, kv_start, kv_count, slots, kv_block_size, layers, head_dim, max_seq }
        }
        t => return Err(CodecError::UnknownType(t)),
    };
    r.finish()?;
    Ok(msg)
}

/// Try to decode one frame from the front of `buf`.
///
/// * `Ok(Some((msg, consumed)))` — a full frame was parsed; the caller
///   should drop the first `consumed` bytes.
/// * `Ok(None)` — `buf` holds only a frame prefix; read more and retry
///   (this is what makes short reads / read timeouts loss-free).
/// * `Err(_)` — the stream is corrupt at the current position.
pub fn decode_frame(buf: &[u8]) -> Result<Option<(WireMsg, usize)>, CodecError> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let magic = u16::from_le_bytes([buf[0], buf[1]]);
    if magic != MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = buf[2];
    if version != FORMAT_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let tag = buf[3];
    let plen = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if plen > MAX_PAYLOAD {
        return Err(CodecError::Malformed(format!("payload length {plen} exceeds cap")));
    }
    if buf.len() < HEADER_LEN + plen {
        return Ok(None);
    }
    let want = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    let payload = &buf[HEADER_LEN..HEADER_LEN + plen];
    let got = checksum(version, tag, payload);
    if want != got {
        return Err(CodecError::BadChecksum { want, got });
    }
    let msg = decode_payload(tag, payload)?;
    Ok(Some((msg, HEADER_LEN + plen)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &WireMsg) -> WireMsg {
        let mut buf = Vec::new();
        let n = encode(msg, &mut buf);
        assert_eq!(n, buf.len());
        assert_eq!(n, encoded_len(msg), "encoded_len must match encode");
        let (got, used) = decode_frame(&buf).unwrap().expect("complete frame");
        assert_eq!(used, buf.len());
        got
    }

    #[test]
    fn control_messages_roundtrip() {
        assert_eq!(roundtrip(&WireMsg::Shutdown), WireMsg::Shutdown);
        assert_eq!(roundtrip(&WireMsg::KvStatsReq), WireMsg::KvStatsReq);
        assert_eq!(roundtrip(&WireMsg::Retire { slot: 77 }), WireMsg::Retire { slot: 77 });
        let e = WireMsg::WorkerError { msg: "ünïcode blew up".into() };
        assert_eq!(roundtrip(&e), e);
        let s = WireMsg::KvStats {
            stats: KvCacheStats {
                blocks_in_use: 3,
                total_blocks: 9,
                block_size: 16,
                internal_waste_tokens: 5,
                bytes_in_use: 3 * 1056,
                total_bytes: 9 * 1056,
                physical_blocks_in_use: 2,
                physical_bytes_in_use: 2 * 1056,
            },
            epoch: 7,
        };
        assert_eq!(roundtrip(&s), s);
        let m = WireMsg::MapBlocks { slot: 3, src_slot: 0, tokens: 96 };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn handshake_messages_roundtrip() {
        let h = WireMsg::Hello { codec_version: FORMAT_VERSION as u32, shard: 3 };
        assert_eq!(roundtrip(&h), h);
        let w = WireMsg::Welcome {
            epoch: u64::MAX,
            kv_start: 2,
            kv_count: 1,
            slots: 8,
            kv_block_size: 4,
            layers: 2,
            head_dim: 16,
            max_seq: 64,
        };
        assert_eq!(roundtrip(&w), w);
    }

    #[test]
    fn tensor_messages_roundtrip() {
        let q = HostTensor::f32(vec![2, 3, 4], (0..24).map(|i| i as f32 * 0.25).collect());
        let m = WireMsg::StepQ {
            layer: 7,
            slots: vec![0, u32::MAX, 2],
            q: q.clone(),
            lens: vec![-1, 0, 12],
            seq_bucket: 256,
            overlap: true,
        };
        assert_eq!(roundtrip(&m), m);
        let m = WireMsg::StepKv { layer: 1, k: q.clone(), v: q.clone() };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn decoded_tensor_is_arc_backed_and_views_share() {
        let out = HostTensor::f32(vec![4, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let mut buf = Vec::new();
        encode(&WireMsg::AttnOut { layer: 0, out }, &mut buf);
        let (msg, _) = decode_frame(&buf).unwrap().unwrap();
        let WireMsg::AttnOut { out, .. } = msg else { panic!() };
        // zero copies after the decode: a clone shares the buffer
        assert!(out.clone().shares_buffer(&out));
        assert_eq!(out.view_rows(1, 2).as_f32(), &[3., 4., 5., 6.]);
    }

    #[test]
    fn f32_fast_path_bitwise_matches_elementwise() {
        // tricky bit patterns: signed zero, denormal, infinities, NaN
        let vals = vec![
            0.0f32,
            -0.0,
            1.5,
            -1e30,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::MIN_POSITIVE / 2.0,
            -3.25e-12,
        ];
        let t = HostTensor::f32(vec![vals.len()], vals.clone());
        let mut frame = Vec::new();
        encode(&WireMsg::AttnOut { layer: 0, out: t }, &mut frame);
        // the frame's payload tail must be exactly the element-wise bytes
        let mut base = Vec::new();
        put_f32_le_elementwise(&mut base, &vals);
        assert!(frame.ends_with(&base), "bulk cast diverged from to_le_bytes");
        // decode (fast path) and the element-wise decoder agree bit-for-bit
        let (msg, _) = decode_frame(&frame).unwrap().unwrap();
        let WireMsg::AttnOut { out, .. } = msg else { panic!() };
        let ew = get_f32_le_elementwise(&base);
        for ((a, b), c) in out.as_f32().iter().zip(&vals).zip(ew.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn i32_fast_path_bitwise_matches_elementwise() {
        let vals = vec![0i32, -1, i32::MIN, i32::MAX, 0x0102_0304];
        let t = HostTensor::i32(vec![vals.len()], vals.clone());
        let mut frame = Vec::new();
        encode(&WireMsg::AttnOut { layer: 0, out: t }, &mut frame);
        let mut base = Vec::new();
        put_i32_le_elementwise(&mut base, &vals);
        assert!(frame.ends_with(&base));
        let (msg, _) = decode_frame(&frame).unwrap().unwrap();
        let WireMsg::AttnOut { out, .. } = msg else { panic!() };
        assert_eq!(out.as_i32(), &vals[..]);
        assert_eq!(&get_i32_le_elementwise(&base)[..], &vals[..]);
    }

    #[test]
    fn incomplete_prefix_asks_for_more() {
        let mut buf = Vec::new();
        encode(&WireMsg::Retire { slot: 1 }, &mut buf);
        for cut in 0..buf.len() {
            assert_eq!(decode_frame(&buf[..cut]).unwrap(), None, "prefix of {cut}");
        }
    }

    #[test]
    fn corruption_is_an_error_not_a_panic() {
        let q = HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let mut buf = Vec::new();
        encode(&WireMsg::AttnOut { layer: 0, out: q }, &mut buf);
        // flip every byte position in turn; decode must return Err or (for
        // the length field, which can make the frame "incomplete") Ok(None)
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x40;
            match decode_frame(&bad) {
                Ok(Some(_)) => panic!("corrupt byte {i} decoded successfully"),
                Ok(None) | Err(_) => {}
            }
        }
    }

    #[test]
    fn two_frames_back_to_back() {
        let mut buf = Vec::new();
        encode(&WireMsg::Retire { slot: 5 }, &mut buf);
        let first_len = buf.len();
        encode(&WireMsg::Shutdown, &mut buf);
        let (m1, used) = decode_frame(&buf).unwrap().unwrap();
        assert_eq!(m1, WireMsg::Retire { slot: 5 });
        assert_eq!(used, first_len);
        let (m2, _) = decode_frame(&buf[used..]).unwrap().unwrap();
        assert_eq!(m2, WireMsg::Shutdown);
    }
}
