//! In-process transport: the paced [`Port`] link (`netsim::transport`)
//! wrapped as a [`Transport`].
//!
//! This is the simulator-faithful path: payload tensors cross threads as
//! `Arc` views (zero host copies, mirroring RDMA), while delivery is paced
//! by the calibrated [`NetStackModel`] and the link charges the *logical*
//! `wire_bytes()` to its [`crate::netsim::transport::LinkStats`]. The
//! adapter adds the per-message-class [`WireStats`] table the leader
//! reports, with `serialized_bytes` left at 0 — nothing is serialized here;
//! the TCP transport is what measures real frames.
//!
//! The netsim layer reports failures as strings (it is transport-agnostic
//! and predates the typed error plane); this adapter maps them into
//! [`TransportError`]: a dropped peer `Port` becomes `Disconnected`
//! (always `mid_frame: false` — messages cross whole, there are no
//! frames to truncate), anything else is an `Io` with the channel text.

use std::sync::Mutex;
use std::time::Duration;

use super::stats::{MsgClass, WireStats};
use super::{Transport, TransportError, TransportKind};
use crate::netsim::stack::NetStackModel;
use crate::netsim::transport::{link, LinkStats, Port};
use crate::obs;
use crate::workers::messages::WireMsg;

/// Map a netsim channel error string onto the typed plane.
fn map_err(e: String) -> TransportError {
    if e.contains("dropped") || e.contains("disconnected") {
        TransportError::Disconnected { mid_frame: false }
    } else {
        TransportError::Io { op: "inproc", kind: std::io::ErrorKind::Other, msg: e }
    }
}

/// [`Transport`] adapter over one paced in-process [`Port`].
pub struct InprocTransport {
    port: Port<WireMsg>,
    stats: Mutex<WireStats>,
}

impl InprocTransport {
    pub fn new(port: Port<WireMsg>) -> InprocTransport {
        InprocTransport { port, stats: Mutex::new(WireStats::new()) }
    }

    /// The underlying simulated link's counters (messages, logical bytes,
    /// modelled busy time).
    pub fn link_stats(&self) -> LinkStats {
        self.port.stats()
    }

    fn record(&self, msg: &WireMsg, logical: usize) {
        obs::lock(&self.stats).record(MsgClass::of(msg), logical, 0);
    }
}

/// Create a bidirectional paced in-process link; returns the two endpoints.
pub fn pair(
    stack: &'static NetStackModel,
    line_rate: f64,
    time_scale: f64,
) -> (InprocTransport, InprocTransport) {
    let (a, b) = link::<WireMsg>(stack, line_rate, time_scale);
    (InprocTransport::new(a), InprocTransport::new(b))
}

impl Transport for InprocTransport {
    fn send(&self, msg: WireMsg) -> Result<(), TransportError> {
        let logical = msg.wire_bytes();
        self.record(&msg, logical);
        self.port.send(msg, logical).map_err(map_err)
    }

    fn recv(&self) -> Result<WireMsg, TransportError> {
        let (msg, logical) = self.port.recv().map_err(map_err)?;
        self.record(&msg, logical);
        Ok(msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        match self.port.recv_timeout(timeout).map_err(map_err)? {
            None => Ok(None),
            Some((msg, logical)) => {
                self.record(&msg, logical);
                Ok(Some(msg))
            }
        }
    }

    fn stats(&self) -> WireStats {
        *obs::lock(&self.stats)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Inproc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::stack::{FHBN, LINE_RATE_400G};
    use crate::runtime::host::HostTensor;

    #[test]
    fn adapter_roundtrips_and_counts_logical_only() {
        let (a, b) = pair(&FHBN, LINE_RATE_400G, 0.0);
        let t = HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32).collect());
        let msg = WireMsg::AttnOut { layer: 1, out: t.clone() };
        let logical = msg.wire_bytes() as u64;
        a.send(msg).unwrap();
        let got = b.recv().unwrap();
        // zero-copy across the in-process wire: same Arc on both sides
        match got {
            WireMsg::AttnOut { ref out, .. } => assert!(out.shares_buffer(&t)),
            _ => panic!(),
        }
        let st = a.stats();
        let c = st.class(MsgClass::AttnOut);
        assert_eq!((c.msgs, c.logical_bytes, c.serialized_bytes), (1, logical, 0));
        assert_eq!(st.overhead_ratio(), None, "nothing serialized in-process");
        assert_eq!(a.link_stats().bytes, logical);
    }

    #[test]
    fn recv_timeout_expires() {
        let (a, _b) = pair(&FHBN, LINE_RATE_400G, 0.0);
        assert!(a.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
    }

    #[test]
    fn dropped_peer_is_typed_disconnect() {
        let (a, b) = pair(&FHBN, LINE_RATE_400G, 0.0);
        drop(b);
        assert_eq!(a.recv(), Err(TransportError::Disconnected { mid_frame: false }));
        assert_eq!(
            a.send(WireMsg::Shutdown),
            Err(TransportError::Disconnected { mid_frame: false })
        );
    }
}
