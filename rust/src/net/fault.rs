//! Deterministic fault injection for the leader↔worker wire.
//!
//! [`FaultTransport`] wraps any [`Transport`] and perturbs it according to
//! a seed-driven [`FaultPlan`]: dropping a send (the message vanishes and
//! the link dies with it — a peer crash with in-flight loss), delaying
//! receives, corrupting a received frame (surfaced as a
//! [`TransportError::Codec`] — exactly what a checksum failure on a real
//! wire looks like), or killing the link outright after a scheduled
//! message count. Every decision comes from a private xorshift64* stream
//! seeded by the plan, so a given `(plan, message sequence)` always
//! misbehaves identically — chaos tests replay bit-for-bit.
//!
//! Every injected fault is *detectable*: the system assumes reliable FIFO
//! links (TCP, in-process channels), so silent loss without link failure
//! is outside the operating contract — a swallowed `Retire` would leak KV
//! blocks with no error anywhere. Faults here therefore always end in a
//! typed link failure the leader's death detection can see.
//!
//! A *kill* drops the inner transport object. For TCP that closes the
//! socket and for inproc it drops the `Port`, so the remote worker
//! genuinely observes a disconnect and exits — the fault is not merely
//! simulated on the leader side. A *corrupt* also kills the link after
//! reporting the codec error, honoring the error-plane contract that
//! framing is unrecoverable after a bad frame.
//!
//! Zero cost when disabled: the leader only wraps links when a
//! `--fault-plan` is armed (see `PipelineOpts::fault_plan`), so the
//! healthy hot path never pays the wrapper's atomics. Respawned
//! replacement workers are never fault-wrapped — a plan fires once,
//! which keeps kill-and-recover chaos runs terminating.
//!
//! [`DeadTransport`] is the degenerate wrapper: every operation reports
//! the peer as gone. The leader swaps it in to script a deterministic
//! worker death at an exact point in a session (`inject_worker_death`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::{Transport, TransportError, TransportKind, WireStats};
use crate::obs;
use crate::workers::messages::WireMsg;

/// Seed-driven fault schedule for one (or every) worker link.
///
/// Parsed from the CLI `--fault-plan` spec: comma-separated `key=value`
/// pairs, e.g. `seed=7,worker=1,kill-recv=20,drop=0.01`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// RNG seed for the probabilistic faults (drop/corrupt).
    pub seed: u64,
    /// Which worker index to arm; `None` arms every link.
    pub worker: Option<usize>,
    /// Kill the link just before the Nth send (1-based).
    pub kill_send: Option<u64>,
    /// Kill the link just before the Nth receive (1-based).
    pub kill_recv: Option<u64>,
    /// Per-send probability of dropping the message. The send reports
    /// success but the message vanishes and the link dies with it (a
    /// peer crash with in-flight loss) — the caller observes the failure
    /// on a later operation, never a silent gap.
    pub drop_p: f64,
    /// Per-recv probability of corrupting the frame (codec error + link
    /// kill).
    pub corrupt_p: f64,
    /// Fixed extra latency injected before every receive.
    pub delay: Option<Duration>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 1,
            worker: None,
            kill_send: None,
            kill_recv: None,
            drop_p: 0.0,
            corrupt_p: 0.0,
            delay: None,
        }
    }
}

impl FaultPlan {
    /// Parse the `--fault-plan` spec. Unknown keys and malformed values
    /// are errors (a typo'd chaos plan silently doing nothing would make
    /// a fault test vacuous).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault-plan: expected key=value, got `{part}`"))?;
            let int = || val.parse::<u64>().map_err(|_| format!("fault-plan: bad {key}={val}"));
            let prob = || {
                val.parse::<f64>()
                    .ok()
                    .filter(|p| (0.0..=1.0).contains(p))
                    .ok_or_else(|| format!("fault-plan: {key} must be a probability, got {val}"))
            };
            match key {
                "seed" => plan.seed = int()?,
                "worker" => plan.worker = Some(int()? as usize),
                "kill-send" => plan.kill_send = Some(int()?.max(1)),
                "kill-recv" => plan.kill_recv = Some(int()?.max(1)),
                "drop" => plan.drop_p = prob()?,
                "corrupt" => plan.corrupt_p = prob()?,
                "delay-us" => plan.delay = Some(Duration::from_micros(int()?)),
                _ => return Err(format!("fault-plan: unknown key `{key}`")),
            }
        }
        Ok(plan)
    }

    /// Should the link to `worker` be wrapped under this plan?
    pub fn applies_to(&self, worker: usize) -> bool {
        self.worker.map_or(true, |w| w == worker)
    }

    /// True when the plan can actually do something (a plan with no
    /// armed fault keeps the link unwrapped).
    pub fn is_armed(&self) -> bool {
        self.kill_send.is_some()
            || self.kill_recv.is_some()
            || self.drop_p > 0.0
            || self.corrupt_p > 0.0
            || self.delay.is_some()
    }
}

/// xorshift64* step; the high bits make a decent uniform stream.
fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// Uniform draw in [0, 1).
fn unit(state: &mut u64) -> f64 {
    (xorshift(state) >> 11) as f64 / (1u64 << 53) as f64
}

/// Fault-injecting [`Transport`] wrapper. See the module docs.
pub struct FaultTransport {
    /// `None` once the plan killed the link.
    inner: Mutex<Option<Box<dyn Transport>>>,
    plan: FaultPlan,
    rng: Mutex<u64>,
    sends: AtomicU64,
    recvs: AtomicU64,
    kind: TransportKind,
    /// Stats snapshot kept across the kill so `wire_stats()` reporting
    /// survives the link's death.
    last_stats: Mutex<WireStats>,
}

impl FaultTransport {
    /// Wrap `inner` under `plan`. `salt` decorrelates the RNG streams of
    /// links sharing one plan (the leader passes the worker index).
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan, salt: u64) -> FaultTransport {
        let kind = inner.kind();
        // splitmix-style seed scramble so seed=0 / equal salts still
        // yield distinct non-zero states
        let mut s = plan.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        s = (s ^ (s >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        FaultTransport {
            inner: Mutex::new(Some(inner)),
            plan,
            rng: Mutex::new(s | 1),
            sends: AtomicU64::new(0),
            recvs: AtomicU64::new(0),
            kind,
            last_stats: Mutex::new(WireStats::new()),
        }
    }

    /// Kill the link now: snapshot stats, drop the inner transport (the
    /// peer sees a genuine disconnect), and fail the current op.
    fn kill(&self, guard: &mut Option<Box<dyn Transport>>) -> TransportError {
        if let Some(t) = guard.take() {
            *obs::lock(&self.last_stats) = t.stats();
            obs::instant("wire", "fault_kill", vec![]);
        }
        TransportError::Disconnected { mid_frame: false }
    }

    fn roll(&self, p: f64) -> bool {
        p > 0.0 && unit(&mut obs::lock(&self.rng)) < p
    }
}

impl Transport for FaultTransport {
    fn send(&self, msg: WireMsg) -> Result<(), TransportError> {
        let n = self.sends.fetch_add(1, Ordering::Relaxed) + 1;
        let mut inner = obs::lock(&self.inner);
        if self.plan.kill_send.is_some_and(|k| n >= k) {
            return Err(self.kill(&mut inner));
        }
        let Some(t) = inner.as_ref() else {
            return Err(TransportError::Disconnected { mid_frame: false });
        };
        if self.roll(self.plan.drop_p) {
            obs::instant("wire", "fault_drop", vec![]);
            // the message vanishes AND the link dies with it: the send
            // itself "succeeds" (async send to a peer that just crashed),
            // the loss surfaces as a disconnect on the next operation
            let _ = self.kill(&mut inner);
            return Ok(());
        }
        t.send(msg)
    }

    fn send_buffered(&self, msg: WireMsg) -> Result<(), TransportError> {
        // identical accounting and kill/drop logic to `send`: a buffered
        // frame is still the Nth send of the plan's schedule, so chaos
        // plans stay valid whether the leader batches or not
        let n = self.sends.fetch_add(1, Ordering::Relaxed) + 1;
        let mut inner = obs::lock(&self.inner);
        if self.plan.kill_send.is_some_and(|k| n >= k) {
            return Err(self.kill(&mut inner));
        }
        let Some(t) = inner.as_ref() else {
            return Err(TransportError::Disconnected { mid_frame: false });
        };
        if self.roll(self.plan.drop_p) {
            obs::instant("wire", "fault_drop", vec![]);
            let _ = self.kill(&mut inner);
            return Ok(());
        }
        t.send_buffered(msg)
    }

    fn flush(&self) -> Result<(), TransportError> {
        // not a scheduled op (plans count messages, not syscalls)
        let inner = obs::lock(&self.inner);
        let Some(t) = inner.as_ref() else {
            return Err(TransportError::Disconnected { mid_frame: false });
        };
        t.flush()
    }

    fn recv(&self) -> Result<WireMsg, TransportError> {
        // delegate through recv_timeout-with-None shape: same fault logic
        let n = self.recvs.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(d) = self.plan.delay {
            std::thread::sleep(d);
        }
        let mut inner = obs::lock(&self.inner);
        if self.plan.kill_recv.is_some_and(|k| n >= k) {
            return Err(self.kill(&mut inner));
        }
        let Some(t) = inner.as_ref() else {
            return Err(TransportError::Disconnected { mid_frame: false });
        };
        let msg = t.recv()?;
        if self.roll(self.plan.corrupt_p) {
            let _ = self.kill(&mut inner); // framing is lost: link dies with the frame
            return Err(TransportError::Codec(super::CodecError::BadChecksum {
                want: 0,
                got: !0,
            }));
        }
        Ok(msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        let n = self.recvs.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(d) = self.plan.delay {
            std::thread::sleep(d);
        }
        let mut inner = obs::lock(&self.inner);
        if self.plan.kill_recv.is_some_and(|k| n >= k) {
            return Err(self.kill(&mut inner));
        }
        let Some(t) = inner.as_ref() else {
            return Err(TransportError::Disconnected { mid_frame: false });
        };
        let Some(msg) = t.recv_timeout(timeout)? else {
            return Ok(None);
        };
        if self.roll(self.plan.corrupt_p) {
            let _ = self.kill(&mut inner);
            return Err(TransportError::Codec(super::CodecError::BadChecksum {
                want: 0,
                got: !0,
            }));
        }
        Ok(Some(msg))
    }

    fn stats(&self) -> WireStats {
        match obs::lock(&self.inner).as_ref() {
            Some(t) => {
                let st = t.stats();
                *obs::lock(&self.last_stats) = st;
                st
            }
            None => *obs::lock(&self.last_stats),
        }
    }

    fn kind(&self) -> TransportKind {
        self.kind
    }

    fn poll_fd(&self) -> Option<i32> {
        obs::lock(&self.inner).as_ref().and_then(|t| t.poll_fd())
    }
}

/// A link whose peer is already gone: every operation reports
/// `Disconnected`. Swapped in by the leader's `inject_worker_death` to
/// script a death at an exact session point, and usable anywhere a
/// guaranteed-dead `Transport` is needed.
pub struct DeadTransport {
    kind: TransportKind,
    stats: WireStats,
}

impl DeadTransport {
    /// `stats` preserves the dead link's traffic history for reporting.
    pub fn new(kind: TransportKind, stats: WireStats) -> DeadTransport {
        DeadTransport { kind, stats }
    }
}

impl Transport for DeadTransport {
    fn send(&self, _msg: WireMsg) -> Result<(), TransportError> {
        Err(TransportError::Disconnected { mid_frame: false })
    }

    fn flush(&self) -> Result<(), TransportError> {
        Err(TransportError::Disconnected { mid_frame: false })
    }

    fn recv(&self) -> Result<WireMsg, TransportError> {
        Err(TransportError::Disconnected { mid_frame: false })
    }

    fn recv_timeout(&self, _timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        Err(TransportError::Disconnected { mid_frame: false })
    }

    fn stats(&self) -> WireStats {
        self.stats
    }

    fn kind(&self) -> TransportKind {
        self.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::stack::{FHBN, LINE_RATE_400G};

    fn inproc_boxed() -> (Box<dyn Transport>, Box<dyn Transport>) {
        let (a, b) = super::super::inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
        (Box::new(a), Box::new(b))
    }

    #[test]
    fn plan_parse_roundtrip_and_errors() {
        let p = FaultPlan::parse("seed=7, worker=1, kill-recv=20, drop=0.25, delay-us=50")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.worker, Some(1));
        assert_eq!(p.kill_recv, Some(20));
        assert_eq!(p.drop_p, 0.25);
        assert_eq!(p.delay, Some(Duration::from_micros(50)));
        assert!(p.is_armed());
        assert!(p.applies_to(1) && !p.applies_to(0));

        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("drop=1.5").is_err());
        assert!(FaultPlan::parse("kill-send").is_err());
        let noop = FaultPlan::parse("seed=3").unwrap();
        assert!(!noop.is_armed());
        assert!(noop.applies_to(0) && noop.applies_to(5));
    }

    #[test]
    fn kill_after_n_sends_disconnects_both_sides() {
        let (a, b) = inproc_boxed();
        let plan = FaultPlan::parse("kill-send=3").unwrap();
        let faulty = FaultTransport::new(a, plan, 0);
        faulty.send(WireMsg::KvStatsReq).unwrap();
        faulty.send(WireMsg::KvStatsReq).unwrap();
        assert_eq!(
            faulty.send(WireMsg::KvStatsReq),
            Err(TransportError::Disconnected { mid_frame: false })
        );
        // the peer's port was genuinely dropped, not just error-mapped
        assert!(b.recv_timeout(Duration::from_millis(50)).unwrap().is_some());
        assert!(b.recv_timeout(Duration::from_millis(50)).unwrap().is_some());
        assert_eq!(b.recv(), Err(TransportError::Disconnected { mid_frame: false }));
        // stats survive the kill
        assert_eq!(faulty.stats().total().msgs, 2);
    }

    #[test]
    fn corrupt_is_codec_error_then_dead() {
        let (a, b) = inproc_boxed();
        let plan = FaultPlan::parse("seed=11,corrupt=1.0").unwrap();
        let faulty = FaultTransport::new(a, plan, 0);
        b.send(WireMsg::KvStatsReq).unwrap();
        match faulty.recv() {
            Err(TransportError::Codec(_)) => {}
            other => panic!("expected codec fault, got {other:?}"),
        }
        assert_eq!(faulty.recv(), Err(TransportError::Disconnected { mid_frame: false }));
    }

    #[test]
    fn drop_schedule_is_seed_deterministic_and_kills_the_link() {
        // deliveries before the first drop fires (killing the link)
        let run = |seed: u64| -> u64 {
            let (a, b) = inproc_boxed();
            let plan = FaultPlan { seed, drop_p: 0.25, ..FaultPlan::default() };
            let faulty = FaultTransport::new(a, plan, 3);
            let mut delivered = 0u64;
            loop {
                if faulty.send(WireMsg::KvStatsReq).is_err() {
                    break; // an earlier drop already killed the link
                }
                match b.recv_timeout(Duration::from_millis(50)) {
                    Ok(Some(_)) => delivered += 1,
                    // the drop genuinely severed the wire: the peer sees
                    // a disconnect, not a silent gap
                    _ => break,
                }
                assert!(delivered < 10_000, "drop never fired");
            }
            delivered
        };
        let first = run(42);
        assert_eq!(first, run(42), "same seed must replay identically");
        assert!((43..49).any(|s| run(s) != first), "seeds must decorrelate");
    }

    #[test]
    fn send_buffered_counts_against_the_same_kill_schedule() {
        // a plan written for plain sends must fire at the same message
        // number when the leader batches — buffered sends share the
        // counter
        let (a, b) = inproc_boxed();
        let plan = FaultPlan::parse("kill-send=3").unwrap();
        let faulty = FaultTransport::new(a, plan, 0);
        faulty.send_buffered(WireMsg::KvStatsReq).unwrap();
        faulty.send(WireMsg::KvStatsReq).unwrap();
        assert_eq!(
            faulty.send_buffered(WireMsg::KvStatsReq),
            Err(TransportError::Disconnected { mid_frame: false })
        );
        drop(faulty);
        let _ = b;
    }

    #[test]
    fn dead_transport_always_disconnected() {
        let d = DeadTransport::new(TransportKind::Inproc, WireStats::new());
        assert!(d.send(WireMsg::Shutdown).is_err());
        assert_eq!(d.recv(), Err(TransportError::Disconnected { mid_frame: false }));
        assert_eq!(d.kind(), TransportKind::Inproc);
    }
}
