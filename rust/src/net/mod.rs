//! `net` — the cross-process transport subsystem behind the leader↔worker
//! wire.
//!
//! The serving pipeline moves [`WireMsg`]s between the model worker
//! (leader) and the attention workers. This module makes that wire *real*
//! while keeping the simulator intact, by putting a [`Transport`] trait
//! between the workers and the bytes:
//!
//! * [`inproc`] — the original paced in-process link
//!   ([`crate::netsim::transport`]) as a `Transport` adapter: payloads move
//!   as `Arc` views (zero copies), latency is paced by the calibrated
//!   network-stack model, and byte accounting is the *logical*
//!   [`WireMsg::wire_bytes`] model.
//! * [`tcp`] — a real-socket loopback transport: every message is
//!   serialized through [`codec`] (versioned, length-prefixed,
//!   checksummed frames; see the `codec` docs for the exact header
//!   layout), written to a kernel TCP socket, and deserialized on the far
//!   side into `Arc`-backed tensors (one copy in, zero after).
//! * [`stats`] — per-message-class accounting shared by both:
//!   `logical_bytes` (the model) next to `serialized_bytes` (measured
//!   frames), so every `--transport tcp` run checks the simulator's
//!   `wire_bytes()` model against what a real wire carries.
//!
//! The leader and worker loops are generic over `Transport`
//! ([`crate::workers`]), selected at startup by
//! `PipelineOpts::transport` / the `--transport inproc|tcp` CLI flag; the
//! full decode + chunked-prefill session is bit-identical over either
//! (asserted by the `net_e2e` tests).

pub mod codec;
pub mod inproc;
pub mod stats;
pub mod tcp;

use std::time::Duration;

use crate::workers::messages::WireMsg;

pub use inproc::InprocTransport;
pub use stats::{ClassStats, MsgClass, WireStats};
pub use tcp::TcpTransport;

/// A bidirectional, ordered, reliable message link carrying [`WireMsg`]s.
///
/// One endpoint lives on the leader, its peer on an attention worker. All
/// methods take `&self` (endpoints do their own locking) and errors are
/// strings — the worker loop forwards them as `WireMsg::WorkerError`.
pub trait Transport: Send {
    /// Queue `msg` for delivery to the peer. Byte accounting (logical and,
    /// where applicable, serialized) happens here.
    fn send(&self, msg: WireMsg) -> Result<(), String>;

    /// Block until the next message arrives.
    fn recv(&self) -> Result<WireMsg, String>;

    /// Block up to `timeout`; `Ok(None)` on expiry. Expiry never loses
    /// data (a partially received frame stays buffered).
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, String>;

    /// Per-message-class traffic through this endpoint (both directions).
    fn stats(&self) -> WireStats;

    /// Which implementation this is (for reports).
    fn kind(&self) -> TransportKind;
}

/// Transport selector (the `--transport` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Paced in-process channel, zero-copy payloads, modelled bytes.
    #[default]
    Inproc,
    /// Real TCP loopback sockets, serialized frames, measured bytes.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" => Some(TransportKind::Inproc),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [TransportKind::Inproc, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert_eq!(TransportKind::parse("rdma"), None);
        assert_eq!(TransportKind::default(), TransportKind::Inproc);
    }
}
