//! `net` — the cross-process transport subsystem behind the leader↔worker
//! wire.
//!
//! The serving pipeline moves [`WireMsg`]s between the model worker
//! (leader) and the attention workers. This module makes that wire *real*
//! while keeping the simulator intact, by putting a [`Transport`] trait
//! between the workers and the bytes:
//!
//! * [`inproc`] — the original paced in-process link
//!   ([`crate::netsim::transport`]) as a `Transport` adapter: payloads move
//!   as `Arc` views (zero copies), latency is paced by the calibrated
//!   network-stack model, and byte accounting is the *logical*
//!   [`WireMsg::wire_bytes`] model.
//! * [`tcp`] — a real-socket loopback transport: every message is
//!   serialized through [`codec`] (versioned, length-prefixed,
//!   checksummed frames; see the `codec` docs for the exact header
//!   layout), written to a kernel TCP socket, and deserialized on the far
//!   side into `Arc`-backed tensors (one copy in, zero after).
//! * [`fault`] — a deterministic, seed-driven fault-injection wrapper
//!   ([`fault::FaultTransport`]) that drops, delays, corrupts, or kills a
//!   link after a scheduled message count, over either transport. Zero
//!   cost when no plan is armed (the leader only wraps links when
//!   `--fault-plan` is given).
//! * [`stats`] — per-message-class accounting shared by both:
//!   `logical_bytes` (the model) next to `serialized_bytes` (measured
//!   frames), so every `--transport tcp` run checks the simulator's
//!   `wire_bytes()` model against what a real wire carries.
//! * [`addr`] — typed `HOST:PORT` parsing for the cluster CLI surface
//!   (`--listen`, `--workers addr,…`), with actionable errors.
//! * [`batch`] — the multi-frame envelope that coalesces a decode step's
//!   per-layer message burst into one vectored write per worker per step
//!   (see "Batching" below).
//! * [`mux`] — `poll(2)`-based readiness multiplexing so the leader
//!   services W worker sockets concurrently instead of sequentially.
//!
//! # Remote topology
//!
//! The tcp transport is no longer loopback-only: a standalone
//! `lamina-attn` binary runs the attention-worker loop behind
//! `--listen HOST:PORT`, and the leader dials out with
//! `--workers addr1,addr2,…`. The connection lifecycle:
//!
//! ```text
//!   lamina-attn --listen 0.0.0.0:7001          lamina … --workers host:7001,…
//!   ┌───────────────────────────┐              ┌───────────────────────────┐
//!   │ bind + accept loop        │◄── dial ─────│ connect_timeout + bounded │
//!   │                           │   (retry     │ retry on HealthPolicy     │
//!   │ session:                  │    ladder)   │ backoff ladder            │
//!   │   send Hello ─────────────┼──────────────┼─► codec-version check     │
//!   │   validate Welcome ◄──────┼──────────────┼── shard plan + geometry   │
//!   │   serve StepQ/StepKv/…    │◄═ envelopes ═│ batched sends, writev     │
//!   │   (60s idle timeout)      │══ frames ═══►│ mux'd recv over poll(2)   │
//!   │ session ends (Shutdown,   │              │ death → failover: degrade │
//!   │  EOF, error) → accept     │              │ or re-dial + re-Welcome   │
//!   │  again (leader may return)│              │ (epoch-fenced reshard)    │
//!   └───────────────────────────┘              └───────────────────────────┘
//! ```
//!
//! A dead remote worker is indistinguishable from a dead loopback one at
//! the failover layer: the same typed errors feed the same
//! detection/recovery machinery, and respawn becomes "re-dial the same
//! address" (the worker's accept loop takes the leader back).
//!
//! # Batching
//!
//! `Transport` has a buffered send plane: `send_buffered` queues a frame,
//! `flush` emits everything queued as one length-prefixed multi-frame
//! [`batch`] envelope with a single vectored write. `send` flushes any
//! pending batch before its own frame, so FIFO order holds across both
//! paths. The receive side decodes envelopes incrementally with the same
//! never-lose-sync guarantees as bare frames. Transports without a real
//! syscall boundary (inproc) keep the default implementation, where
//! `send_buffered` degenerates to `send` and `flush` is a no-op.
//!
//! # Error plane
//!
//! Every fallible `Transport` method returns a typed [`TransportError`]:
//!
//! * [`TransportError::TimedOut`] — a deadline elapsed inside `recv`
//!   (only produced by deadline-aware wrappers; `recv_timeout` itself
//!   signals expiry as `Ok(None)` so expiry is not an error).
//! * [`TransportError::Disconnected`] — the peer is gone. `mid_frame`
//!   distinguishes an abrupt death that truncated a frame in flight from
//!   a close on a clean frame boundary. Either way the link is dead; the
//!   leader treats this as a declared worker death, never a retry.
//! * [`TransportError::Codec`] — the peer sent a frame that failed
//!   validation ([`codec::CodecError`]: bad magic/version/checksum,
//!   truncated or malformed payload). The stream is unrecoverable after
//!   this (framing is lost), so the leader also treats it as fatal for
//!   the link.
//! * [`TransportError::Io`] — an OS-level socket/channel error, tagged
//!   with the operation that hit it.
//!
//! The attention-worker loop distinguishes link errors (peer gone —
//! exit silently, nobody is listening) from protocol errors (report a
//! `WireMsg::WorkerError` back to the leader, then exit). The leader
//! side never panics on any of these: wire errors flow through
//! [`crate::coordinator::failover`]'s detection policy
//! (deadline → bounded retry/backoff → declare dead) and, on a declared
//! death, into preempt-replay-rebuild recovery (see
//! [`crate::workers::leader`]).
//!
//! The leader and worker loops are generic over `Transport`
//! ([`crate::workers`]), selected at startup by
//! `PipelineOpts::transport` / the `--transport inproc|tcp` CLI flag; the
//! full decode + chunked-prefill session is bit-identical over either
//! (asserted by the `net_e2e` tests).

pub mod addr;
pub mod batch;
pub mod codec;
pub mod fault;
pub mod inproc;
pub mod mux;
pub mod stats;
pub mod tcp;

use std::time::Duration;

use crate::workers::messages::WireMsg;

pub use addr::{Addr, AddrError};
pub use batch::BatchDecoder;
pub use codec::CodecError;
pub use fault::{DeadTransport, FaultPlan, FaultTransport};
pub use inproc::InprocTransport;
pub use stats::{ClassStats, MsgClass, WireStats};
pub use tcp::TcpTransport;

/// Typed transport failure. See the module docs for how each variant is
/// produced and how the leader/worker loops react.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// A recv deadline elapsed (used by deadline-aware callers; plain
    /// `recv_timeout` reports expiry as `Ok(None)` instead).
    TimedOut,
    /// The peer endpoint is gone. `mid_frame` is true when the stream was
    /// cut inside a frame (abrupt death), false on a clean frame boundary.
    Disconnected { mid_frame: bool },
    /// The peer sent bytes that failed frame validation; framing is lost
    /// and the link cannot be trusted afterwards.
    Codec(CodecError),
    /// OS-level I/O failure, tagged with the operation that hit it.
    Io { op: &'static str, kind: std::io::ErrorKind, msg: String },
}

impl TransportError {
    /// Build an `Io` variant from a `std::io::Error`.
    pub fn io(op: &'static str, e: &std::io::Error) -> TransportError {
        TransportError::Io { op, kind: e.kind(), msg: e.to_string() }
    }

    /// True when the link itself is unusable afterwards (disconnect or
    /// lost framing) as opposed to a transient condition.
    pub fn is_fatal(&self) -> bool {
        matches!(self, TransportError::Disconnected { .. } | TransportError::Codec(_))
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::TimedOut => write!(f, "recv deadline elapsed"),
            TransportError::Disconnected { mid_frame: true } => {
                write!(f, "peer disconnected mid-frame")
            }
            TransportError::Disconnected { mid_frame: false } => {
                write!(f, "peer disconnected")
            }
            TransportError::Codec(e) => write!(f, "frame validation failed: {e}"),
            TransportError::Io { op, kind, msg } => write!(f, "{op}: {msg} ({kind:?})"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<CodecError> for TransportError {
    fn from(e: CodecError) -> TransportError {
        TransportError::Codec(e)
    }
}

/// Convenience for `Result<_, String>` boundary code (scripted harnesses,
/// worker-side protocol errors): `?` on a transport call stringifies the
/// typed error. The leader never uses this — it propagates typed.
impl From<TransportError> for String {
    fn from(e: TransportError) -> String {
        e.to_string()
    }
}

/// A bidirectional, ordered, reliable message link carrying [`WireMsg`]s.
///
/// One endpoint lives on the leader, its peer on an attention worker. All
/// methods take `&self` (endpoints do their own locking) and all errors
/// are typed [`TransportError`]s — see the module docs for the error
/// plane contract.
pub trait Transport: Send {
    /// Queue `msg` for delivery to the peer. Byte accounting (logical and,
    /// where applicable, serialized) happens here. Any frames previously
    /// queued with [`Transport::send_buffered`] are flushed first, so
    /// mixing the two planes preserves FIFO order.
    fn send(&self, msg: WireMsg) -> Result<(), TransportError>;

    /// Queue `msg` into the pending batch; nothing reaches the peer until
    /// [`Transport::flush`] (or a subsequent `send`, which flushes first).
    /// Transports without a syscall boundary just send immediately — the
    /// contract is "delivered no later than the next flush", not
    /// "withheld until it".
    fn send_buffered(&self, msg: WireMsg) -> Result<(), TransportError> {
        self.send(msg)
    }

    /// Emit every frame queued by [`Transport::send_buffered`] as one
    /// multi-frame envelope (single vectored write on tcp). No-op when
    /// nothing is pending.
    fn flush(&self) -> Result<(), TransportError> {
        Ok(())
    }

    /// Block until the next message arrives.
    fn recv(&self) -> Result<WireMsg, TransportError>;

    /// Block up to `timeout`; `Ok(None)` on expiry. Expiry never loses
    /// data (a partially received frame stays buffered).
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, TransportError>;

    /// Per-message-class traffic through this endpoint (both directions).
    fn stats(&self) -> WireStats;

    /// Which implementation this is (for reports).
    fn kind(&self) -> TransportKind;

    /// A pollable raw fd whose readability implies `recv_timeout` would
    /// make progress, if this transport has one ([`mux`] readiness loop).
    /// `None` (the default) keeps the caller on its sequential path.
    ///
    /// Readability is advisory — frames already decoded into userspace
    /// buffers are *not* visible to `poll(2)`, so callers must sweep with
    /// a zero-timeout receive before parking on the fd.
    fn poll_fd(&self) -> Option<i32> {
        None
    }
}

/// Transport selector (the `--transport` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Paced in-process channel, zero-copy payloads, modelled bytes.
    #[default]
    Inproc,
    /// Real TCP loopback sockets, serialized frames, measured bytes.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" => Some(TransportKind::Inproc),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [TransportKind::Inproc, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert_eq!(TransportKind::parse("rdma"), None);
        assert_eq!(TransportKind::default(), TransportKind::Inproc);
    }

    #[test]
    fn transport_error_display_and_fatality() {
        assert!(!TransportError::TimedOut.is_fatal());
        assert!(TransportError::Disconnected { mid_frame: true }.is_fatal());
        assert!(TransportError::Codec(CodecError::BadChecksum { want: 1, got: 2 }).is_fatal());
        let io = TransportError::io(
            "tcp send",
            &std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe"),
        );
        assert!(!io.is_fatal());
        assert!(io.to_string().contains("tcp send"));
        assert_eq!(
            TransportError::Disconnected { mid_frame: true }.to_string(),
            "peer disconnected mid-frame"
        );
        assert!(TransportError::Codec(CodecError::BadChecksum { want: 1, got: 2 })
            .to_string()
            .contains("frame validation failed"));
    }
}
