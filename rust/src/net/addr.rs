//! Typed `HOST:PORT` address parsing for the cluster CLI surface
//! (`--listen`, `--workers addr1,addr2,…`).
//!
//! A malformed address on the command line must produce an actionable
//! error message, never a panic deep inside `ToSocketAddrs`. [`Addr`]
//! keeps the host **textual** (hostname, IPv4, or bracketed IPv6) so the
//! CLI can echo exactly what the user typed; [`Addr::resolve`] turns it
//! into a concrete [`SocketAddr`] at dial/bind time, which is also where
//! DNS failures surface — again typed, with the offending address in the
//! message.
//!
//! Accepted forms:
//!
//! ```text
//!   host:port          my-worker-3:7001, localhost:0
//!   ipv4:port          127.0.0.1:7001
//!   [ipv6]:port        [::1]:7001, [fe80::1]:7001
//! ```
//!
//! Port `0` is allowed (ephemeral bind for `--listen`; tests use it to
//! avoid port collisions). A bare IPv6 address without brackets is
//! rejected with a hint — `::1:7001` is hopelessly ambiguous otherwise.

use std::net::{SocketAddr, ToSocketAddrs};

/// A parsed-but-unresolved network address (`HOST:PORT`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Addr {
    /// Hostname, IPv4 literal, or IPv6 literal (brackets stripped).
    pub host: String,
    pub port: u16,
    /// Whether the host was written in `[…]` bracket (IPv6) form.
    ipv6: bool,
}

/// Typed address error; `Display` is the actionable CLI message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AddrError(pub String);

impl std::fmt::Display for AddrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for AddrError {}

fn err<T>(msg: String) -> Result<T, AddrError> {
    Err(AddrError(msg))
}

impl Addr {
    /// Parse one `HOST:PORT` (or `[IPV6]:PORT`) address.
    pub fn parse(s: &str) -> Result<Addr, AddrError> {
        let s = s.trim();
        if s.is_empty() {
            return err("empty address (expected HOST:PORT)".into());
        }
        let (host, port_str, ipv6) = if let Some(rest) = s.strip_prefix('[') {
            // bracketed IPv6: [addr]:port
            let Some((host, after)) = rest.split_once(']') else {
                return err(format!("`{s}`: missing `]` (expected [IPV6]:PORT)"));
            };
            let Some(port) = after.strip_prefix(':') else {
                return err(format!("`{s}`: expected `:PORT` after the `]`"));
            };
            if host.is_empty() {
                return err(format!("`{s}`: empty host inside `[…]`"));
            }
            (host, port, true)
        } else {
            let Some((host, port)) = s.rsplit_once(':') else {
                return err(format!("`{s}`: missing `:PORT` (expected HOST:PORT)"));
            };
            if host.contains(':') {
                return err(format!(
                    "`{s}`: bare IPv6 is ambiguous — write it bracketed, [{host}]:{port}"
                ));
            }
            if host.is_empty() {
                return err(format!("`{s}`: empty host (expected HOST:PORT)"));
            }
            (host, port, false)
        };
        if port_str.is_empty() {
            return err(format!("`{s}`: empty port (expected HOST:PORT)"));
        }
        let Ok(port) = port_str.parse::<u16>() else {
            return err(format!("`{s}`: port `{port_str}` is not a number in 0..=65535"));
        };
        Ok(Addr { host: host.to_string(), port, ipv6 })
    }

    /// Parse a comma-separated address list (`--workers a:1,b:2`). Empty
    /// segments are rejected — a trailing comma is more likely a typo'd
    /// worker than an intentional no-op.
    pub fn parse_list(s: &str) -> Result<Vec<Addr>, AddrError> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.iter().all(|p| p.is_empty()) {
            return err("empty worker list (expected HOST:PORT[,HOST:PORT…])".into());
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            if p.is_empty() {
                return err(format!("`{s}`: empty entry in the address list"));
            }
            out.push(Addr::parse(p)?);
        }
        Ok(out)
    }

    /// Resolve to a concrete socket address (DNS happens here). The first
    /// resolution result wins; failure is typed with the textual address.
    pub fn resolve(&self) -> Result<SocketAddr, AddrError> {
        match (self.host.as_str(), self.port).to_socket_addrs() {
            Ok(mut it) => match it.next() {
                Some(sa) => Ok(sa),
                None => err(format!("`{self}`: resolved to no addresses")),
            },
            Err(e) => err(format!("`{self}`: resolve failed: {e}")),
        }
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.ipv6 || self.host.contains(':') {
            write!(f, "[{}]:{}", self.host, self.port)
        } else {
            write!(f, "{}:{}", self.host, self.port)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_ipv4_host_and_port() {
        let a = Addr::parse("127.0.0.1:7001").unwrap();
        assert_eq!(a.host, "127.0.0.1");
        assert_eq!(a.port, 7001);
        assert_eq!(a.to_string(), "127.0.0.1:7001");
    }

    #[test]
    fn parses_hostname_and_ephemeral_port() {
        let a = Addr::parse("localhost:0").unwrap();
        assert_eq!(a.host, "localhost");
        assert_eq!(a.port, 0);
        // resolvable (loopback)
        let sa = a.resolve().unwrap();
        assert!(sa.ip().is_loopback());
    }

    #[test]
    fn parses_bracketed_ipv6() {
        let a = Addr::parse("[::1]:8080").unwrap();
        assert_eq!(a.host, "::1");
        assert_eq!(a.port, 8080);
        assert_eq!(a.to_string(), "[::1]:8080");
        let sa = a.resolve().unwrap();
        assert!(sa.is_ipv6());
    }

    #[test]
    fn malformed_addresses_are_typed_errors_not_panics() {
        for bad in [
            "",
            "   ",
            "no-port",
            ":7001",
            "host:",
            "host:notanum",
            "host:70000",
            "host:-1",
            "[::1]",
            "[::1]7001",
            "[]:7001",
            "[::1:7001",
        ] {
            let e = Addr::parse(bad).expect_err(bad);
            assert!(!e.0.is_empty(), "error for `{bad}` must carry a message");
        }
    }

    #[test]
    fn bare_ipv6_gets_a_bracket_hint() {
        let e = Addr::parse("::1:7001").unwrap_err();
        assert!(e.0.contains("bracket"), "hint missing: {e}");
    }

    #[test]
    fn list_parses_and_rejects_empties() {
        let l = Addr::parse_list("127.0.0.1:1, localhost:2,[::1]:3").unwrap();
        assert_eq!(l.len(), 3);
        assert_eq!(l[1].host, "localhost");
        assert_eq!(l[2].to_string(), "[::1]:3");
        assert!(Addr::parse_list("").is_err());
        assert!(Addr::parse_list("a:1,,b:2").is_err());
        assert!(Addr::parse_list("a:1,b:bad").is_err());
    }

    #[test]
    fn resolve_failure_is_typed_with_the_address() {
        let a = Addr::parse("definitely-not-a-real-host.invalid:9").unwrap();
        let e = a.resolve().unwrap_err();
        assert!(e.0.contains("definitely-not-a-real-host.invalid"), "{e}");
    }
}
