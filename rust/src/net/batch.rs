//! Multi-frame batch envelopes: coalesce a decode step's per-layer
//! message burst into **one** length-prefixed envelope so the TCP
//! transport can flush it with a single vectored write (`writev`) per
//! worker per step instead of a syscall per `WireMsg`.
//!
//! # Wire format
//!
//! An envelope is a 12-byte header followed by `payload_len` bytes of
//! back-to-back ordinary [`codec`] frames:
//!
//! ```text
//!   offset  size  field
//!   0       2     envelope magic 0xB1A3 (LE) — first byte 0xA3, distinct
//!                 from a frame's first byte 0x31, so the stream decoder
//!                 can tell envelopes and bare frames apart at any point
//!   2       1     format version (must equal codec::FORMAT_VERSION)
//!   3       1     reserved, must be 0
//!   4       4     frame count (u32 LE, 1..=MAX_ENV_FRAMES)
//!   8       4     payload length in bytes (u32 LE)
//! ```
//!
//! Inner frames carry their own per-frame checksums, so the envelope
//! itself needs none — but its bookkeeping is still validated: the
//! declared frame count must match exactly the frames that consume the
//! declared payload, and an inner frame that crosses the envelope
//! boundary is a typed [`CodecError::Malformed`], never a desync.
//!
//! # Incremental decoding
//!
//! [`BatchDecoder`] is the stream-side state machine: feed it the front
//! of the receive buffer and it yields one message at a time, whether the
//! bytes arrived as bare frames, envelopes, or any interleaving. It obeys
//! the same never-lose-sync contract as [`codec::decode_frame`]:
//! `Ok(None)` means "wait for more bytes" and **consumes nothing** (state
//! only advances when a message is returned), so a sender may be cut off
//! at any byte offset without the receiver misparsing what came before.

use super::codec::{self, CodecError};
use crate::workers::messages::WireMsg;

/// Envelope magic (LE on the wire: `A3 B1`). Chosen so neither byte
/// collides with a frame's first byte (`0x31`).
pub const ENV_MAGIC: u16 = 0xB1A3;
/// Envelope header length in bytes.
pub const ENV_HEADER_LEN: usize = 12;
/// Cap on frames per envelope (far above any real step burst).
pub const MAX_ENV_FRAMES: usize = 1 << 16;
/// Cap on envelope payload bytes (mirrors the codec's payload cap).
pub const MAX_ENV_PAYLOAD: usize = 1 << 30;

/// Build the 12-byte header for an envelope of `frames` frames covering
/// `payload_len` bytes. The write path accumulates encoded frames in a
/// pending buffer and emits `[header, pending]` as one vectored write.
pub fn envelope_header(frames: u32, payload_len: u32) -> [u8; ENV_HEADER_LEN] {
    let mut h = [0u8; ENV_HEADER_LEN];
    h[0..2].copy_from_slice(&ENV_MAGIC.to_le_bytes());
    h[2] = codec::FORMAT_VERSION;
    // h[3] reserved = 0
    h[4..8].copy_from_slice(&frames.to_le_bytes());
    h[8..12].copy_from_slice(&payload_len.to_le_bytes());
    h
}

/// Encode `msgs` as one envelope appended to `out`; returns bytes
/// appended. Test/bench convenience — the transport's hot path builds
/// the header separately to keep the pending buffer un-copied.
pub fn encode_batch(msgs: &[WireMsg], out: &mut Vec<u8>) -> usize {
    assert!(!msgs.is_empty(), "an envelope carries at least one frame");
    assert!(msgs.len() <= MAX_ENV_FRAMES);
    let start = out.len();
    out.extend_from_slice(&[0u8; ENV_HEADER_LEN]);
    for m in msgs {
        codec::encode(m, out);
    }
    let payload = out.len() - start - ENV_HEADER_LEN;
    let header = envelope_header(msgs.len() as u32, payload as u32);
    out[start..start + ENV_HEADER_LEN].copy_from_slice(&header);
    out.len() - start
}

/// Stream decoder for interleaved bare frames and batch envelopes.
///
/// `env_remaining`/`env_frames` track the envelope currently being
/// drained; both are zero between envelopes. State advances **only**
/// when `decode` returns a message, so a call that returns `Ok(None)` or
/// an error is side-effect free and may be retried with more bytes.
#[derive(Debug, Default)]
pub struct BatchDecoder {
    /// Payload bytes of the current envelope not yet consumed.
    env_remaining: usize,
    /// Frames of the current envelope not yet decoded.
    env_frames: usize,
}

impl BatchDecoder {
    pub fn new() -> BatchDecoder {
        BatchDecoder::default()
    }

    /// True when the stream stopped mid-envelope (peer died between the
    /// frames it promised) — the receive path reports such a death as
    /// `Disconnected { mid_frame: true }`.
    pub fn mid_envelope(&self) -> bool {
        self.env_remaining > 0
    }

    /// Decode one message from the front of `buf`.
    ///
    /// * `Ok(Some((msg, consumed)))` — drain `consumed` bytes and go again.
    /// * `Ok(None)` — incomplete; read more bytes and retry.
    /// * `Err(_)` — the stream is corrupt; framing is unrecoverable.
    pub fn decode(&mut self, buf: &[u8]) -> Result<Option<(WireMsg, usize)>, CodecError> {
        if self.env_remaining > 0 {
            return self.decode_inner(buf, 0);
        }
        if buf.len() < 2 {
            return Ok(None);
        }
        let magic = u16::from_le_bytes([buf[0], buf[1]]);
        if magic == codec::MAGIC {
            return codec::decode_frame(buf);
        }
        if magic != ENV_MAGIC {
            return Err(CodecError::BadMagic(magic));
        }
        if buf.len() < ENV_HEADER_LEN {
            return Ok(None);
        }
        if buf[2] != codec::FORMAT_VERSION {
            return Err(CodecError::BadVersion(buf[2]));
        }
        if buf[3] != 0 {
            return Err(CodecError::Malformed(format!(
                "envelope reserved byte is {:#04x}, want 0",
                buf[3]
            )));
        }
        let frames = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
        let payload = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
        if frames == 0 || frames > MAX_ENV_FRAMES {
            return Err(CodecError::Malformed(format!(
                "envelope frame count {frames} outside 1..={MAX_ENV_FRAMES}"
            )));
        }
        if payload > MAX_ENV_PAYLOAD {
            return Err(CodecError::Malformed(format!(
                "envelope payload {payload} exceeds cap {MAX_ENV_PAYLOAD}"
            )));
        }
        if payload < frames * codec::HEADER_LEN {
            return Err(CodecError::Malformed(format!(
                "envelope payload {payload} bytes cannot hold {frames} frames"
            )));
        }
        // Tentatively consume the header: commit happens only if the
        // first inner frame decodes, otherwise state is rolled back so
        // the call stays side-effect free.
        self.env_remaining = payload;
        self.env_frames = frames;
        match self.decode_inner(&buf[ENV_HEADER_LEN..], ENV_HEADER_LEN) {
            Ok(Some((msg, consumed))) => Ok(Some((msg, consumed))),
            other => {
                self.env_remaining = 0;
                self.env_frames = 0;
                other
            }
        }
    }

    /// Decode the next frame inside the current envelope. `extra` is
    /// added to the consumed count (the envelope header, when this call
    /// rides the same `decode` that parsed it).
    fn decode_inner(
        &mut self,
        buf: &[u8],
        extra: usize,
    ) -> Result<Option<(WireMsg, usize)>, CodecError> {
        let limit = self.env_remaining.min(buf.len());
        match codec::decode_frame(&buf[..limit])? {
            Some((msg, used)) => {
                if self.env_frames == 0 {
                    // unreachable by construction (count/payload are
                    // cross-checked below), kept as a typed guard
                    return Err(CodecError::Malformed(
                        "envelope payload outlives its frame count".into(),
                    ));
                }
                self.env_remaining -= used;
                self.env_frames -= 1;
                if self.env_remaining == 0 && self.env_frames != 0 {
                    return Err(CodecError::Malformed(format!(
                        "envelope ended with {} declared frame(s) missing",
                        self.env_frames
                    )));
                }
                if self.env_remaining > 0 && self.env_frames == 0 {
                    return Err(CodecError::Malformed(format!(
                        "envelope has {} trailing byte(s) after its last frame",
                        self.env_remaining
                    )));
                }
                Ok(Some((msg, extra + used)))
            }
            None if limit < self.env_remaining => Ok(None), // stream short: wait
            None => Err(CodecError::Malformed(
                "inner frame crosses the envelope boundary".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::host::HostTensor;

    fn burst() -> Vec<WireMsg> {
        vec![
            WireMsg::Retire { slot: 3 },
            WireMsg::StepKv {
                layer: 1,
                k: HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32).collect()),
                v: HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32 * 0.5).collect()),
            },
            WireMsg::KvStatsReq,
            WireMsg::Shutdown,
        ]
    }

    /// Drain everything decodable from `buf` with a fresh decoder.
    fn drain(buf: &[u8]) -> Result<Vec<WireMsg>, CodecError> {
        let mut d = BatchDecoder::new();
        let mut off = 0;
        let mut out = Vec::new();
        while let Some((msg, used)) = d.decode(&buf[off..])? {
            out.push(msg);
            off += used;
        }
        assert_eq!(off, buf.len(), "fully-formed input must be fully consumed");
        Ok(out)
    }

    #[test]
    fn envelope_roundtrips_all_frames_in_order() {
        let msgs = burst();
        let mut buf = Vec::new();
        let n = encode_batch(&msgs, &mut buf);
        assert_eq!(n, buf.len());
        let got = drain(&buf).unwrap();
        assert_eq!(got.len(), msgs.len());
        assert!(matches!(got[0], WireMsg::Retire { slot: 3 }));
        assert!(matches!(got[2], WireMsg::KvStatsReq));
        assert!(matches!(got[3], WireMsg::Shutdown));
    }

    #[test]
    fn bare_frames_and_envelopes_interleave() {
        let mut buf = Vec::new();
        codec::encode(&WireMsg::KvStatsReq, &mut buf);
        encode_batch(&burst(), &mut buf);
        codec::encode(&WireMsg::Retire { slot: 9 }, &mut buf);
        encode_batch(&[WireMsg::Shutdown], &mut buf);
        let got = drain(&buf).unwrap();
        assert_eq!(got.len(), 1 + 4 + 1 + 1);
        assert!(matches!(got[0], WireMsg::KvStatsReq));
        assert!(matches!(got[5], WireMsg::Retire { slot: 9 }));
        assert!(matches!(got[6], WireMsg::Shutdown));
    }

    #[test]
    fn partial_envelope_never_desyncs() {
        // every prefix cut of (envelope ++ bare frame) must decode a
        // strict prefix of the messages and then ask for more — stateful
        // decoding across arbitrary packetization boundaries
        let mut buf = Vec::new();
        encode_batch(&burst(), &mut buf);
        codec::encode(&WireMsg::Retire { slot: 7 }, &mut buf);
        for cut in 0..buf.len() {
            let mut d = BatchDecoder::new();
            let mut off = 0;
            let mut n = 0usize;
            loop {
                match d.decode(&buf[off..cut]) {
                    Ok(Some((_, used))) => {
                        off += used;
                        n += 1;
                    }
                    Ok(None) => break,
                    Err(e) => panic!("cut at {cut}: prefix must never error, got {e}"),
                }
            }
            assert!(n <= 5, "cut at {cut} produced {n} messages");
            // feeding the remainder completes the stream exactly
            let mut total = n;
            let mut off2 = off;
            while let Some((_, used)) = d.decode(&buf[off2..]).unwrap() {
                off2 += used;
                total += 1;
            }
            assert_eq!(total, 5, "cut at {cut}");
            assert_eq!(off2, buf.len(), "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_inner_frame_fails_typed_never_panics() {
        // flip each byte of the envelope somewhere: the decoder must
        // return a typed error or ask for more — never panic, never
        // yield a bogus extra message
        let mut clean = Vec::new();
        encode_batch(&burst(), &mut clean);
        for i in 0..clean.len() {
            let mut buf = clean.clone();
            buf[i] ^= 0x40;
            let mut d = BatchDecoder::new();
            let mut off = 0;
            let mut n = 0;
            let r = loop {
                match d.decode(&buf[off..]) {
                    Ok(Some((_, used))) => {
                        off += used;
                        n += 1;
                        if off >= buf.len() {
                            break Ok(());
                        }
                    }
                    Ok(None) => break Ok(()),
                    Err(e) => break Err(e),
                }
            };
            // a flipped byte may land in a tensor payload (checksum
            // catches it) or in envelope bookkeeping (typed Malformed) —
            // but the frame count can never exceed the real one
            assert!(n <= 4, "byte {i}: {n} messages from a corrupt stream");
            let _ = r;
        }
    }

    #[test]
    fn frame_crossing_envelope_boundary_is_typed() {
        // envelope declaring 1 frame but truncating it: shorten the
        // declared payload so the inner frame pokes past the boundary
        let mut inner = Vec::new();
        codec::encode(&WireMsg::Retire { slot: 1 }, &mut inner);
        let mut buf = Vec::new();
        let declared = inner.len() as u32 - 4; // cut into the frame
        buf.extend_from_slice(&envelope_header(1, declared));
        buf.extend_from_slice(&inner);
        let mut d = BatchDecoder::new();
        match d.decode(&buf) {
            Err(CodecError::Malformed(m)) => {
                assert!(m.contains("boundary") || m.contains("hold"), "{m}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn frame_count_mismatch_is_typed() {
        // payload holds 2 frames but the header declares 3
        let mut inner = Vec::new();
        codec::encode(&WireMsg::KvStatsReq, &mut inner);
        codec::encode(&WireMsg::Shutdown, &mut inner);
        let mut buf = Vec::new();
        buf.extend_from_slice(&envelope_header(3, inner.len() as u32));
        buf.extend_from_slice(&inner);
        let mut d = BatchDecoder::new();
        let mut off = 0;
        let e = loop {
            match d.decode(&buf[off..]) {
                Ok(Some((_, used))) => off += used,
                Ok(None) => panic!("stream is complete"),
                Err(e) => break e,
            }
        };
        assert!(matches!(e, CodecError::Malformed(_)), "{e}");
    }

    #[test]
    fn trailing_bytes_after_declared_frames_are_typed() {
        // header declares 1 frame but the payload holds 2
        let mut inner = Vec::new();
        codec::encode(&WireMsg::KvStatsReq, &mut inner);
        codec::encode(&WireMsg::Shutdown, &mut inner);
        let mut buf = Vec::new();
        buf.extend_from_slice(&envelope_header(1, inner.len() as u32));
        buf.extend_from_slice(&inner);
        let mut d = BatchDecoder::new();
        match d.decode(&buf) {
            Err(CodecError::Malformed(m)) => assert!(m.contains("trailing"), "{m}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_version_and_zero_frames_are_typed() {
        let mut d = BatchDecoder::new();
        assert!(matches!(d.decode(&[0x00, 0x00, 1, 2]), Err(CodecError::BadMagic(_))));

        let mut h = envelope_header(1, 12);
        h[2] = 99;
        let mut d = BatchDecoder::new();
        assert!(matches!(d.decode(&h), Err(CodecError::BadVersion(99))));

        let h = envelope_header(0, 0);
        let mut d = BatchDecoder::new();
        assert!(matches!(d.decode(&h), Err(CodecError::Malformed(_))));
    }

    #[test]
    fn header_only_consumes_nothing_and_reports_mid_envelope_correctly() {
        let mut buf = Vec::new();
        encode_batch(&[WireMsg::KvStatsReq, WireMsg::Shutdown], &mut buf);
        let mut d = BatchDecoder::new();
        // header alone: no state change, not mid-envelope
        assert!(d.decode(&buf[..ENV_HEADER_LEN]).unwrap().is_none());
        assert!(!d.mid_envelope());
        // first frame out: now mid-envelope until the second arrives
        let (m1, used) = d.decode(&buf).unwrap().unwrap();
        assert!(matches!(m1, WireMsg::KvStatsReq));
        assert!(d.mid_envelope());
        let (m2, used2) = d.decode(&buf[used..]).unwrap().unwrap();
        assert!(matches!(m2, WireMsg::Shutdown));
        assert!(!d.mid_envelope());
        assert_eq!(used + used2, buf.len());
    }
}
