//! Real-socket transport: one TCP loopback connection per leader↔worker
//! link, speaking the [`super::codec`] frame format.
//!
//! Unlike the paced in-process link (which moves `Arc` pointers and charges
//! *modelled* bytes), every message here is genuinely serialized, written
//! to a kernel socket, read back and deserialized — so `--transport tcp`
//! proves the whole decode/prefill protocol survives a real wire, and its
//! [`WireStats`] report the *actual* frame bytes next to the logical
//! `wire_bytes()` model.
//!
//! Design notes:
//! * **Write path**: a frame is assembled in a reusable scratch buffer and
//!   flushed with a single `write_all` (`TCP_NODELAY` is set, so small
//!   control frames don't sit in Nagle's buffer behind an ACK).
//! * **Read path**: a persistent receive buffer accumulates socket reads
//!   and [`super::codec::decode_frame`] is retried on every fill. Partial
//!   frames survive short reads *and* `recv_timeout` expiry without losing
//!   stream sync (the buffer simply keeps the prefix).
//! * **Graceful shutdown**: the protocol-level `WireMsg::Shutdown` drains
//!   the worker loop first; dropping an endpoint then closes the socket
//!   (`shutdown(Both)`), and a peer blocked in `recv` gets a clean
//!   "connection closed" error instead of a hang.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::stats::{MsgClass, WireStats};
use super::{codec, Transport, TransportKind};
use crate::obs;
use crate::workers::messages::WireMsg;

const READ_CHUNK: usize = 64 * 1024;

struct WriteHalf {
    stream: TcpStream,
    /// Reusable frame-assembly buffer (write buffering without `BufWriter`:
    /// one syscall per frame, no flush bookkeeping).
    scratch: Vec<u8>,
}

struct ReadHalf {
    stream: TcpStream,
    /// Accumulated-but-unparsed stream bytes (may hold a partial frame).
    buf: Vec<u8>,
    /// Last read timeout applied to the socket (avoid a syscall per recv).
    timeout: Option<Duration>,
}

/// One endpoint of a leader↔worker TCP link.
pub struct TcpTransport {
    writer: Mutex<WriteHalf>,
    reader: Mutex<ReadHalf>,
    stats: Mutex<WireStats>,
    peer: SocketAddr,
}

impl TcpTransport {
    /// Wrap an established stream (sets `TCP_NODELAY`).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<TcpTransport> {
        stream.set_nodelay(true)?;
        let peer = stream.peer_addr()?;
        let rd = stream.try_clone()?;
        Ok(TcpTransport {
            writer: Mutex::new(WriteHalf { stream, scratch: Vec::with_capacity(4096) }),
            reader: Mutex::new(ReadHalf { stream: rd, buf: Vec::with_capacity(4096), timeout: None }),
            stats: Mutex::new(WireStats::new()),
            peer,
        })
    }

    /// Connect to a listening peer.
    pub fn connect(addr: SocketAddr) -> std::io::Result<TcpTransport> {
        TcpTransport::from_stream(TcpStream::connect(addr)?)
    }

    /// Remote endpoint address.
    pub fn peer_addr(&self) -> SocketAddr {
        self.peer
    }

    /// Close both directions; a peer blocked in `recv` unblocks with an
    /// error. Idempotent (drop calls it too).
    pub fn close(&self) {
        if let Ok(w) = self.writer.lock() {
            let _ = w.stream.shutdown(Shutdown::Both);
        }
    }

    fn recv_inner(&self, timeout: Option<Duration>) -> Result<Option<WireMsg>, String> {
        // spans socket wait + deframe; on the calling thread's track
        let _sp = obs::span("wire", "tcp_recv");
        let mut r = self.reader.lock().map_err(|_| "tcp reader poisoned".to_string())?;
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match codec::decode_frame(&r.buf) {
                Ok(Some((msg, used))) => {
                    r.buf.drain(..used);
                    let mut st = self.stats.lock().map_err(|_| "tcp stats poisoned")?;
                    st.record(MsgClass::of(&msg), msg.wire_bytes(), used);
                    return Ok(Some(msg));
                }
                Ok(None) => {} // need more bytes
                Err(e) => return Err(format!("tcp recv from {}: {e}", self.peer)),
            }
            // compute the remaining budget; expire before a zero-duration
            // timeout (set_read_timeout(Some(0)) is an error in std)
            let want = match deadline {
                None => None,
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    Some(d - now)
                }
            };
            // re-arm the socket timeout only when the armed value is
            // meaningfully off (steady-state recv_timeout(T) calls reuse
            // the armed T instead of paying a setsockopt per message).
            // Overshoot is bounded by the tolerance: the deadline checks
            // above and below stay authoritative.
            let rearm = match (r.timeout, want) {
                (None, None) => false,
                (Some(armed), Some(remaining)) => {
                    let tol = Duration::from_millis(5);
                    armed > remaining + tol || armed + tol < remaining
                }
                _ => true,
            };
            if rearm {
                r.stream
                    .set_read_timeout(want)
                    .map_err(|e| format!("tcp set timeout: {e}"))?;
                r.timeout = want;
            }
            match r.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(format!(
                        "tcp connection to {} closed by peer{}",
                        self.peer,
                        if r.buf.is_empty() { "" } else { " mid-frame" }
                    ))
                }
                Ok(n) => r.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return Ok(None);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(format!("tcp read from {}: {e}", self.peer)),
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&self, msg: WireMsg) -> Result<(), String> {
        let class = MsgClass::of(&msg);
        let logical = msg.wire_bytes();
        let _sp = obs::span("wire", "tcp_send").arg("bytes", logical as i64);
        let mut w = self.writer.lock().map_err(|_| "tcp writer poisoned".to_string())?;
        w.scratch.clear();
        let frame = codec::encode(&msg, &mut w.scratch);
        let WriteHalf { stream, scratch } = &mut *w;
        stream
            .write_all(scratch)
            .map_err(|e| format!("tcp send to {}: {e}", self.peer))?;
        drop(w);
        let mut st = self.stats.lock().map_err(|_| "tcp stats poisoned")?;
        st.record(class, logical, frame);
        Ok(())
    }

    fn recv(&self) -> Result<WireMsg, String> {
        match self.recv_inner(None)? {
            Some(m) => Ok(m),
            None => unreachable!("recv without timeout cannot expire"),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, String> {
        self.recv_inner(Some(timeout))
    }

    fn stats(&self) -> WireStats {
        *self.stats.lock().expect("tcp stats poisoned")
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.close();
    }
}

/// Create a connected loopback pair: bind an ephemeral 127.0.0.1 listener,
/// connect, accept. The two endpoints are real kernel sockets — hand one to
/// a worker thread and keep the other on the leader.
pub fn pair() -> std::io::Result<(TcpTransport, TcpTransport)> {
    let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))?;
    let addr = listener.local_addr()?;
    let client = TcpStream::connect(addr)?;
    let (server, _) = listener.accept()?;
    Ok((TcpTransport::from_stream(server)?, TcpTransport::from_stream(client)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::host::HostTensor;

    #[test]
    fn roundtrip_over_real_socket() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32).collect());
        a.send(WireMsg::AttnOut { layer: 3, out: t.clone() }).unwrap();
        let got = b.recv().unwrap();
        assert_eq!(got, WireMsg::AttnOut { layer: 3, out: t });
    }

    #[test]
    fn bidirectional_and_ordered() {
        let (a, b) = pair().unwrap();
        for slot in 0..10u32 {
            a.send(WireMsg::Retire { slot }).unwrap();
        }
        b.send(WireMsg::KvStatsReq).unwrap();
        for slot in 0..10u32 {
            assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot });
        }
        assert_eq!(a.recv().unwrap(), WireMsg::KvStatsReq);
    }

    #[test]
    fn recv_timeout_preserves_partial_then_completes() {
        let (a, b) = pair().unwrap();
        // idle link: timeout fires, nothing lost
        assert!(b.recv_timeout(Duration::from_millis(20)).unwrap().is_none());
        a.send(WireMsg::Shutdown).unwrap();
        let got = b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, Some(WireMsg::Shutdown));
    }

    #[test]
    fn threaded_echo() {
        let (a, b) = pair().unwrap();
        let h = std::thread::spawn(move || loop {
            let msg = b.recv().unwrap();
            if msg == WireMsg::Shutdown {
                return;
            }
            b.send(msg).unwrap();
        });
        let t = HostTensor::f32(vec![8, 64], vec![0.5; 512]);
        for layer in 0..4 {
            a.send(WireMsg::StepKv { layer, k: t.clone(), v: t.clone() }).unwrap();
            let got = a.recv().unwrap();
            assert_eq!(got, WireMsg::StepKv { layer, k: t.clone(), v: t.clone() });
        }
        a.send(WireMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn closed_peer_errors_cleanly() {
        let (a, b) = pair().unwrap();
        drop(b);
        assert!(a.recv().is_err());
    }

    #[test]
    fn stats_count_measured_and_logical() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![4, 2, 8], vec![1.0; 64]);
        let msg = WireMsg::AttnOut { layer: 0, out: t };
        let logical = msg.wire_bytes() as u64;
        a.send(msg).unwrap();
        b.recv().unwrap();
        for st in [a.stats(), b.stats()] {
            let c = st.class(MsgClass::AttnOut);
            assert_eq!(c.msgs, 1);
            assert_eq!(c.logical_bytes, logical);
            assert!(c.serialized_bytes > c.logical_bytes, "frame adds header overhead");
            assert!(st.overhead_ratio().unwrap() < 1.2, "overhead must be small");
        }
    }
}
