//! Real-socket transport: one TCP connection per leader↔worker link,
//! speaking the [`super::codec`] frame format — loopback pairs for
//! in-process tests, outbound connections to standalone `lamina-attn`
//! processes for real multi-host deployments.
//!
//! Unlike the paced in-process link (which moves `Arc` pointers and charges
//! *modelled* bytes), every message here is genuinely serialized, written
//! to a kernel socket, read back and deserialized — so `--transport tcp`
//! proves the whole decode/prefill protocol survives a real wire, and its
//! [`WireStats`] report the *actual* frame bytes next to the logical
//! `wire_bytes()` model.
//!
//! Design notes:
//! * **Write path**: `send` assembles a frame in a reusable scratch buffer
//!   and flushes it with a single `write_all` (`TCP_NODELAY` is set, so
//!   small control frames don't sit in Nagle's buffer behind an ACK).
//!   `send_buffered` instead appends the frame to a pending batch that
//!   `flush` wraps in one [`super::batch`] envelope and emits with a
//!   single **vectored write** (`writev` of header + payload) — one
//!   syscall for a whole decode-step burst instead of one per `WireMsg`.
//!   FIFO order across the two paths is absolute: `send` flushes any
//!   pending batch before its own frame, so callers may mix freely.
//! * **Read path**: a persistent receive buffer accumulates socket reads
//!   and [`super::batch::BatchDecoder`] is retried on every fill — it
//!   handles bare frames and batch envelopes interleaved. Partial frames
//!   (and partial envelopes) survive short reads *and* `recv_timeout`
//!   expiry without losing stream sync (the buffer simply keeps the
//!   prefix).
//! * **Failure taxonomy**: an empty read (`Ok(0)`) means the peer is gone
//!   and maps to [`TransportError::Disconnected`] — with `mid_frame: true`
//!   when the receive buffer still holds a frame prefix or the decoder is
//!   mid-envelope (the peer died between frames it promised), `false` on
//!   a clean frame boundary. Reset/aborted/broken-pipe socket errors map
//!   to `Disconnected` too (the kernel saw the peer vanish before we read
//!   the FIN). Frame validation failures surface as
//!   [`TransportError::Codec`]; everything else is [`TransportError::Io`]
//!   tagged with the failing operation.
//! * **Graceful shutdown**: the protocol-level `WireMsg::Shutdown` drains
//!   the worker loop first; dropping an endpoint then closes the socket
//!   (`shutdown(Both)`) — after flushing any partially-buffered batch
//!   envelope, so a graceful drain never truncates the final frames
//!   mid-envelope. A peer blocked in `recv` gets a typed `Disconnected`
//!   error instead of a hang.
//!
//! Syscall accounting for the batch path lands in the obs registry:
//! `net.writev_calls` counts vectored-write syscalls, `net.batched_frames`
//! the frames they carried — the `net/frame-batch` bench row derives its
//! ≥4× fewer-writes-per-step claim from exactly these counters.

use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::batch::{self, BatchDecoder};
use super::stats::{MsgClass, WireStats};
use super::{codec, Transport, TransportError, TransportKind};
use crate::obs;
use crate::workers::messages::WireMsg;

const READ_CHUNK: usize = 64 * 1024;
/// Auto-flush threshold for the pending batch: a burst larger than this
/// goes out in several envelopes (still few syscalls, bounded memory).
const MAX_BATCH_BYTES: usize = 4 << 20;

fn writev_calls() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("net.writev_calls"))
}

fn batched_frames() -> &'static obs::Counter {
    static C: OnceLock<obs::Counter> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("net.batched_frames"))
}

/// Socket error kinds that mean "the peer is gone", not "the syscall
/// failed": the wire contract wants those typed as `Disconnected` so the
/// leader's death detection doesn't have to pattern-match io kinds.
fn disconnect_kind(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::NotConnected
    )
}

struct WriteHalf {
    stream: TcpStream,
    /// Reusable frame-assembly buffer for the unbatched `send` path.
    scratch: Vec<u8>,
    /// Encoded-but-unsent frames awaiting `flush` (batch envelope payload).
    pending: Vec<u8>,
    /// Frames in `pending`.
    pending_frames: u32,
}

/// Emit the pending batch as one envelope via vectored writes. On error
/// the pending buffer is dropped — a failed socket write condemns the
/// link, and a later best-effort `close` must not replay half-written
/// bytes.
fn flush_half(w: &mut WriteHalf) -> Result<(), TransportError> {
    if w.pending.is_empty() {
        return Ok(());
    }
    let _sp = obs::span("wire", "tcp_flush")
        .arg("frames", w.pending_frames as i64)
        .arg("bytes", w.pending.len() as i64);
    let header = batch::envelope_header(w.pending_frames, w.pending.len() as u32);
    let total = batch::ENV_HEADER_LEN + w.pending.len();
    let frames = w.pending_frames;
    let WriteHalf { stream, pending, pending_frames, .. } = w;
    let mut wrote = 0usize;
    let res = loop {
        if wrote >= total {
            break Ok(());
        }
        let (h, p): (&[u8], &[u8]) = if wrote < batch::ENV_HEADER_LEN {
            (&header[wrote..], &pending[..])
        } else {
            (&[][..], &pending[wrote - batch::ENV_HEADER_LEN..])
        };
        let bufs = [IoSlice::new(h), IoSlice::new(p)];
        match stream.write_vectored(&bufs) {
            Ok(0) => break Err(TransportError::Disconnected { mid_frame: false }),
            Ok(n) => {
                writev_calls().inc();
                wrote += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // write side is blocking (no SO_SNDTIMEO armed); defensive
                std::thread::yield_now();
            }
            Err(e) if disconnect_kind(e.kind()) => {
                break Err(TransportError::Disconnected { mid_frame: false });
            }
            Err(e) => break Err(TransportError::io("tcp writev", &e)),
        }
    };
    pending.clear();
    *pending_frames = 0;
    if res.is_ok() {
        batched_frames().add(frames as u64);
    }
    res
}

struct ReadHalf {
    stream: TcpStream,
    /// Accumulated-but-unparsed stream bytes (may hold a partial frame).
    buf: Vec<u8>,
    /// Last read timeout applied to the socket (avoid a syscall per recv).
    timeout: Option<Duration>,
    /// Stream decoder (bare frames + batch envelopes, stateful).
    decoder: BatchDecoder,
}

/// One endpoint of a leader↔worker TCP link.
pub struct TcpTransport {
    writer: Mutex<WriteHalf>,
    reader: Mutex<ReadHalf>,
    stats: Mutex<WireStats>,
    peer: SocketAddr,
}

impl TcpTransport {
    /// Wrap an established stream (sets `TCP_NODELAY`).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<TcpTransport> {
        stream.set_nodelay(true)?;
        let peer = stream.peer_addr()?;
        let rd = stream.try_clone()?;
        Ok(TcpTransport {
            writer: Mutex::new(WriteHalf {
                stream,
                scratch: Vec::with_capacity(4096),
                pending: Vec::new(),
                pending_frames: 0,
            }),
            reader: Mutex::new(ReadHalf {
                stream: rd,
                buf: Vec::with_capacity(4096),
                timeout: None,
                decoder: BatchDecoder::new(),
            }),
            stats: Mutex::new(WireStats::new()),
            peer,
        })
    }

    /// Connect to a listening peer.
    pub fn connect(addr: SocketAddr) -> std::io::Result<TcpTransport> {
        TcpTransport::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with a dial deadline — a not-yet-listening remote worker
    /// is a timely typed error, never a hang. The leader wraps this in
    /// the `HealthPolicy` backoff ladder for bounded retry.
    pub fn connect_timeout(addr: SocketAddr, timeout: Duration) -> std::io::Result<TcpTransport> {
        TcpTransport::from_stream(TcpStream::connect_timeout(&addr, timeout)?)
    }

    /// Remote endpoint address.
    pub fn peer_addr(&self) -> SocketAddr {
        self.peer
    }

    /// Close both directions; a peer blocked in `recv` unblocks with an
    /// error. Any partially-buffered batch envelope is flushed first so a
    /// graceful drain never cuts the final frames mid-envelope.
    /// Idempotent (drop calls it too).
    pub fn close(&self) {
        let mut w = obs::lock(&self.writer);
        let _ = flush_half(&mut w);
        let _ = w.stream.shutdown(Shutdown::Both);
    }

    fn recv_inner(&self, timeout: Option<Duration>) -> Result<Option<WireMsg>, TransportError> {
        // spans socket wait + deframe; on the calling thread's track
        let _sp = obs::span("wire", "tcp_recv");
        let mut r = obs::lock(&self.reader);
        let ReadHalf { stream, buf, timeout: armed, decoder } = &mut *r;
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match decoder.decode(buf) {
                Ok(Some((msg, used))) => {
                    buf.drain(..used);
                    obs::lock(&self.stats).record(MsgClass::of(&msg), msg.wire_bytes(), used);
                    return Ok(Some(msg));
                }
                Ok(None) => {} // need more bytes
                Err(e) => return Err(TransportError::Codec(e)),
            }
            // compute the remaining budget; expire before a zero-duration
            // timeout (set_read_timeout(Some(0)) is an error in std)
            let want = match deadline {
                None => None,
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    Some(d - now)
                }
            };
            // re-arm the socket timeout only when the armed value is
            // meaningfully off (steady-state recv_timeout(T) calls reuse
            // the armed T instead of paying a setsockopt per message).
            // Overshoot is bounded by the tolerance: the deadline checks
            // above and below stay authoritative.
            let rearm = match (*armed, want) {
                (None, None) => false,
                (Some(a), Some(remaining)) => {
                    let tol = Duration::from_millis(5);
                    a > remaining + tol || a + tol < remaining
                }
                _ => true,
            };
            if rearm {
                stream
                    .set_read_timeout(want)
                    .map_err(|e| TransportError::io("tcp set timeout", &e))?;
                *armed = want;
            }
            match stream.read(&mut chunk) {
                // empty read: the peer closed. Unparsed buffered bytes or
                // an open envelope at this point are a promise that will
                // never complete — an abrupt mid-frame death, not a clean
                // shutdown.
                Ok(0) => {
                    return Err(TransportError::Disconnected {
                        mid_frame: !buf.is_empty() || decoder.mid_envelope(),
                    })
                }
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return Ok(None);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if disconnect_kind(e.kind()) => {
                    return Err(TransportError::Disconnected {
                        mid_frame: !buf.is_empty() || decoder.mid_envelope(),
                    })
                }
                Err(e) => return Err(TransportError::io("tcp read", &e)),
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&self, msg: WireMsg) -> Result<(), TransportError> {
        let class = MsgClass::of(&msg);
        let logical = msg.wire_bytes();
        let _sp = obs::span("wire", "tcp_send").arg("bytes", logical as i64);
        let mut w = obs::lock(&self.writer);
        // FIFO across paths: anything batched goes out before this frame
        flush_half(&mut w)?;
        w.scratch.clear();
        let frame = codec::encode(&msg, &mut w.scratch);
        let WriteHalf { stream, scratch, .. } = &mut *w;
        stream.write_all(scratch).map_err(|e| {
            if disconnect_kind(e.kind()) {
                TransportError::Disconnected { mid_frame: false }
            } else {
                TransportError::io("tcp send", &e)
            }
        })?;
        drop(w);
        obs::lock(&self.stats).record(class, logical, frame);
        Ok(())
    }

    fn send_buffered(&self, msg: WireMsg) -> Result<(), TransportError> {
        let class = MsgClass::of(&msg);
        let logical = msg.wire_bytes();
        let mut w = obs::lock(&self.writer);
        if w.pending_frames as usize >= batch::MAX_ENV_FRAMES
            || w.pending.len() >= MAX_BATCH_BYTES
        {
            flush_half(&mut w)?;
        }
        let frame = codec::encode(&msg, &mut w.pending);
        w.pending_frames += 1;
        drop(w);
        obs::lock(&self.stats).record(class, logical, frame);
        Ok(())
    }

    fn flush(&self) -> Result<(), TransportError> {
        let mut w = obs::lock(&self.writer);
        flush_half(&mut w)
    }

    fn recv(&self) -> Result<WireMsg, TransportError> {
        match self.recv_inner(None)? {
            Some(m) => Ok(m),
            // no deadline was armed, so the expiry path cannot be taken
            None => unreachable!("recv without timeout cannot expire"),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        self.recv_inner(Some(timeout))
    }

    fn stats(&self) -> WireStats {
        *obs::lock(&self.stats)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn poll_fd(&self) -> Option<i32> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            return Some(obs::lock(&self.reader).stream.as_raw_fd());
        }
        #[cfg(not(unix))]
        {
            None
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.close();
    }
}

/// Create a connected loopback pair: bind an ephemeral 127.0.0.1 listener,
/// connect, accept. The two endpoints are real kernel sockets — hand one to
/// a worker thread and keep the other on the leader.
pub fn pair() -> std::io::Result<(TcpTransport, TcpTransport)> {
    let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))?;
    let addr = listener.local_addr()?;
    let client = TcpStream::connect(addr)?;
    let (server, _) = listener.accept()?;
    Ok((TcpTransport::from_stream(server)?, TcpTransport::from_stream(client)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::CodecError;
    use crate::runtime::host::HostTensor;

    /// A (TcpTransport, raw TcpStream) pair for byte-level peer misbehavior.
    fn raw_pair() -> (TcpTransport, TcpStream) {
        let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (TcpTransport::from_stream(server).unwrap(), client)
    }

    #[test]
    fn roundtrip_over_real_socket() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32).collect());
        a.send(WireMsg::AttnOut { layer: 3, out: t.clone() }).unwrap();
        let got = b.recv().unwrap();
        assert_eq!(got, WireMsg::AttnOut { layer: 3, out: t });
    }

    #[test]
    fn bidirectional_and_ordered() {
        let (a, b) = pair().unwrap();
        for slot in 0..10u32 {
            a.send(WireMsg::Retire { slot }).unwrap();
        }
        b.send(WireMsg::KvStatsReq).unwrap();
        for slot in 0..10u32 {
            assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot });
        }
        assert_eq!(a.recv().unwrap(), WireMsg::KvStatsReq);
    }

    #[test]
    fn recv_timeout_preserves_partial_then_completes() {
        let (a, b) = pair().unwrap();
        // idle link: timeout fires, nothing lost
        assert!(b.recv_timeout(Duration::from_millis(20)).unwrap().is_none());
        a.send(WireMsg::Shutdown).unwrap();
        let got = b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, Some(WireMsg::Shutdown));
    }

    #[test]
    fn threaded_echo() {
        let (a, b) = pair().unwrap();
        let h = std::thread::spawn(move || loop {
            let msg = b.recv().unwrap();
            if msg == WireMsg::Shutdown {
                return;
            }
            b.send(msg).unwrap();
        });
        let t = HostTensor::f32(vec![8, 64], vec![0.5; 512]);
        for layer in 0..4 {
            a.send(WireMsg::StepKv { layer, k: t.clone(), v: t.clone() }).unwrap();
            let got = a.recv().unwrap();
            assert_eq!(got, WireMsg::StepKv { layer, k: t.clone(), v: t.clone() });
        }
        a.send(WireMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn closed_peer_is_clean_boundary_disconnect() {
        let (a, b) = pair().unwrap();
        drop(b);
        assert_eq!(a.recv(), Err(TransportError::Disconnected { mid_frame: false }));
    }

    #[test]
    fn mid_frame_death_is_typed_as_such() {
        // The peer writes a frame *prefix* then dies: the unfinished bytes
        // in the parse buffer prove the stream was cut inside a frame.
        let (srv, mut raw) = raw_pair();
        let mut frame = Vec::new();
        codec::encode(&WireMsg::Retire { slot: 7 }, &mut frame);
        assert!(frame.len() > 4);
        raw.write_all(&frame[..frame.len() / 2]).unwrap();
        raw.flush().unwrap();
        drop(raw);
        assert_eq!(srv.recv(), Err(TransportError::Disconnected { mid_frame: true }));
    }

    #[test]
    fn mid_envelope_death_is_typed_as_mid_frame() {
        // The peer ships a complete envelope header + first frame, then
        // dies before the second declared frame: the first frame is
        // delivered, the death is typed mid-frame.
        let (srv, mut raw) = raw_pair();
        let mut env = Vec::new();
        batch::encode_batch(&[WireMsg::Retire { slot: 1 }, WireMsg::Shutdown], &mut env);
        let mut one = Vec::new();
        codec::encode(&WireMsg::Retire { slot: 1 }, &mut one);
        raw.write_all(&env[..batch::ENV_HEADER_LEN + one.len()]).unwrap();
        raw.flush().unwrap();
        assert_eq!(srv.recv().unwrap(), WireMsg::Retire { slot: 1 });
        drop(raw);
        assert_eq!(srv.recv(), Err(TransportError::Disconnected { mid_frame: true }));
    }

    #[test]
    fn garbage_bytes_are_a_codec_error() {
        let (srv, mut raw) = raw_pair();
        raw.write_all(&[0xde, 0xad, 0xbe, 0xef, 0x00, 0x01, 0x02, 0x03]).unwrap();
        raw.flush().unwrap();
        match srv.recv() {
            Err(TransportError::Codec(CodecError::BadMagic(_))) => {}
            other => panic!("expected BadMagic codec error, got {other:?}"),
        }
    }

    #[test]
    fn stats_count_measured_and_logical() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![4, 2, 8], vec![1.0; 64]);
        let msg = WireMsg::AttnOut { layer: 0, out: t };
        let logical = msg.wire_bytes() as u64;
        a.send(msg).unwrap();
        b.recv().unwrap();
        for st in [a.stats(), b.stats()] {
            let c = st.class(MsgClass::AttnOut);
            assert_eq!(c.msgs, 1);
            assert_eq!(c.logical_bytes, logical);
            assert!(c.serialized_bytes > c.logical_bytes, "frame adds header overhead");
            assert!(st.overhead_ratio().unwrap() < 1.2, "overhead must be small");
        }
    }

    #[test]
    fn batched_burst_flushes_as_one_envelope() {
        let (a, b) = pair().unwrap();
        let bf0 = batched_frames().get();
        let t = HostTensor::f32(vec![2, 2, 4], vec![0.25; 16]);
        a.send_buffered(WireMsg::Retire { slot: 4 }).unwrap();
        a.send_buffered(WireMsg::StepKv { layer: 0, k: t.clone(), v: t.clone() }).unwrap();
        a.send_buffered(WireMsg::KvStatsReq).unwrap();
        // nothing on the wire yet: the peer must time out
        assert!(b.recv_timeout(Duration::from_millis(30)).unwrap().is_none());
        a.flush().unwrap();
        assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot: 4 });
        assert_eq!(b.recv().unwrap(), WireMsg::StepKv { layer: 0, k: t.clone(), v: t });
        assert_eq!(b.recv().unwrap(), WireMsg::KvStatsReq);
        // counters are process-global; other tests may add to them too
        assert!(batched_frames().get() >= bf0 + 3);
    }

    #[test]
    fn send_after_send_buffered_preserves_fifo() {
        let (a, b) = pair().unwrap();
        a.send_buffered(WireMsg::Retire { slot: 1 }).unwrap();
        a.send_buffered(WireMsg::Retire { slot: 2 }).unwrap();
        // unbatched send must push the batch out first
        a.send(WireMsg::KvStatsReq).unwrap();
        assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot: 1 });
        assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot: 2 });
        assert_eq!(b.recv().unwrap(), WireMsg::KvStatsReq);
    }

    #[test]
    fn close_flushes_partially_buffered_envelope() {
        // the graceful-drain fix: frames buffered but not yet flushed
        // still reach the peer intact before the FIN
        let (a, b) = pair().unwrap();
        a.send_buffered(WireMsg::Retire { slot: 8 }).unwrap();
        a.send_buffered(WireMsg::Shutdown).unwrap();
        a.close();
        assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot: 8 });
        assert_eq!(b.recv().unwrap(), WireMsg::Shutdown);
        assert_eq!(b.recv(), Err(TransportError::Disconnected { mid_frame: false }));
    }

    #[test]
    fn flush_on_empty_pending_is_a_cheap_noop() {
        let (a, _b) = pair().unwrap();
        let wv0 = writev_calls().get();
        a.flush().unwrap();
        a.flush().unwrap();
        // no pending frames: no writev syscalls from these flushes (the
        // counter may still move concurrently from parallel tests, so
        // only assert it when quiet)
        let _ = wv0;
    }

    #[test]
    fn poll_fd_is_available_on_unix() {
        let (a, _b) = pair().unwrap();
        assert_eq!(a.poll_fd().is_some(), cfg!(unix));
    }

    #[test]
    fn connect_timeout_to_dead_port_errors_quickly() {
        // bind-then-drop: the port existed but nobody listens now
        let addr = {
            let l = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0)).unwrap();
            l.local_addr().unwrap()
        };
        let t0 = Instant::now();
        let r = TcpTransport::connect_timeout(addr, Duration::from_millis(500));
        assert!(r.is_err(), "nobody listens there");
        assert!(t0.elapsed() < Duration::from_secs(5), "must not hang");
    }
}
