//! Real-socket transport: one TCP loopback connection per leader↔worker
//! link, speaking the [`super::codec`] frame format.
//!
//! Unlike the paced in-process link (which moves `Arc` pointers and charges
//! *modelled* bytes), every message here is genuinely serialized, written
//! to a kernel socket, read back and deserialized — so `--transport tcp`
//! proves the whole decode/prefill protocol survives a real wire, and its
//! [`WireStats`] report the *actual* frame bytes next to the logical
//! `wire_bytes()` model.
//!
//! Design notes:
//! * **Write path**: a frame is assembled in a reusable scratch buffer and
//!   flushed with a single `write_all` (`TCP_NODELAY` is set, so small
//!   control frames don't sit in Nagle's buffer behind an ACK).
//! * **Read path**: a persistent receive buffer accumulates socket reads
//!   and [`super::codec::decode_frame`] is retried on every fill. Partial
//!   frames survive short reads *and* `recv_timeout` expiry without losing
//!   stream sync (the buffer simply keeps the prefix).
//! * **Failure taxonomy**: an empty read (`Ok(0)`) means the peer is gone
//!   and maps to [`TransportError::Disconnected`] — with `mid_frame: true`
//!   when the receive buffer still holds a frame prefix (the peer died
//!   between frames it promised), `false` on a clean frame boundary.
//!   Reset/aborted/broken-pipe socket errors map to `Disconnected` too
//!   (the kernel saw the peer vanish before we read the FIN). Frame
//!   validation failures surface as [`TransportError::Codec`]; everything
//!   else is [`TransportError::Io`] tagged with the failing operation.
//! * **Graceful shutdown**: the protocol-level `WireMsg::Shutdown` drains
//!   the worker loop first; dropping an endpoint then closes the socket
//!   (`shutdown(Both)`), and a peer blocked in `recv` gets a typed
//!   `Disconnected` error instead of a hang.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::stats::{MsgClass, WireStats};
use super::{codec, Transport, TransportError, TransportKind};
use crate::obs;
use crate::workers::messages::WireMsg;

const READ_CHUNK: usize = 64 * 1024;

/// Socket error kinds that mean "the peer is gone", not "the syscall
/// failed": the wire contract wants those typed as `Disconnected` so the
/// leader's death detection doesn't have to pattern-match io kinds.
fn disconnect_kind(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::NotConnected
    )
}

struct WriteHalf {
    stream: TcpStream,
    /// Reusable frame-assembly buffer (write buffering without `BufWriter`:
    /// one syscall per frame, no flush bookkeeping).
    scratch: Vec<u8>,
}

struct ReadHalf {
    stream: TcpStream,
    /// Accumulated-but-unparsed stream bytes (may hold a partial frame).
    buf: Vec<u8>,
    /// Last read timeout applied to the socket (avoid a syscall per recv).
    timeout: Option<Duration>,
}

/// One endpoint of a leader↔worker TCP link.
pub struct TcpTransport {
    writer: Mutex<WriteHalf>,
    reader: Mutex<ReadHalf>,
    stats: Mutex<WireStats>,
    peer: SocketAddr,
}

impl TcpTransport {
    /// Wrap an established stream (sets `TCP_NODELAY`).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<TcpTransport> {
        stream.set_nodelay(true)?;
        let peer = stream.peer_addr()?;
        let rd = stream.try_clone()?;
        Ok(TcpTransport {
            writer: Mutex::new(WriteHalf { stream, scratch: Vec::with_capacity(4096) }),
            reader: Mutex::new(ReadHalf { stream: rd, buf: Vec::with_capacity(4096), timeout: None }),
            stats: Mutex::new(WireStats::new()),
            peer,
        })
    }

    /// Connect to a listening peer.
    pub fn connect(addr: SocketAddr) -> std::io::Result<TcpTransport> {
        TcpTransport::from_stream(TcpStream::connect(addr)?)
    }

    /// Remote endpoint address.
    pub fn peer_addr(&self) -> SocketAddr {
        self.peer
    }

    /// Close both directions; a peer blocked in `recv` unblocks with an
    /// error. Idempotent (drop calls it too).
    pub fn close(&self) {
        let w = obs::lock(&self.writer);
        let _ = w.stream.shutdown(Shutdown::Both);
    }

    fn recv_inner(&self, timeout: Option<Duration>) -> Result<Option<WireMsg>, TransportError> {
        // spans socket wait + deframe; on the calling thread's track
        let _sp = obs::span("wire", "tcp_recv");
        let mut r = obs::lock(&self.reader);
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match codec::decode_frame(&r.buf) {
                Ok(Some((msg, used))) => {
                    r.buf.drain(..used);
                    obs::lock(&self.stats).record(MsgClass::of(&msg), msg.wire_bytes(), used);
                    return Ok(Some(msg));
                }
                Ok(None) => {} // need more bytes
                Err(e) => return Err(TransportError::Codec(e)),
            }
            // compute the remaining budget; expire before a zero-duration
            // timeout (set_read_timeout(Some(0)) is an error in std)
            let want = match deadline {
                None => None,
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    Some(d - now)
                }
            };
            // re-arm the socket timeout only when the armed value is
            // meaningfully off (steady-state recv_timeout(T) calls reuse
            // the armed T instead of paying a setsockopt per message).
            // Overshoot is bounded by the tolerance: the deadline checks
            // above and below stay authoritative.
            let rearm = match (r.timeout, want) {
                (None, None) => false,
                (Some(armed), Some(remaining)) => {
                    let tol = Duration::from_millis(5);
                    armed > remaining + tol || armed + tol < remaining
                }
                _ => true,
            };
            if rearm {
                r.stream
                    .set_read_timeout(want)
                    .map_err(|e| TransportError::io("tcp set timeout", &e))?;
                r.timeout = want;
            }
            match r.stream.read(&mut chunk) {
                // empty read: the peer closed. A non-empty parse buffer at
                // this point is a frame prefix that will never complete —
                // an abrupt mid-frame death, not a clean shutdown.
                Ok(0) => {
                    return Err(TransportError::Disconnected { mid_frame: !r.buf.is_empty() })
                }
                Ok(n) => r.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return Ok(None);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if disconnect_kind(e.kind()) => {
                    return Err(TransportError::Disconnected { mid_frame: !r.buf.is_empty() })
                }
                Err(e) => return Err(TransportError::io("tcp read", &e)),
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&self, msg: WireMsg) -> Result<(), TransportError> {
        let class = MsgClass::of(&msg);
        let logical = msg.wire_bytes();
        let _sp = obs::span("wire", "tcp_send").arg("bytes", logical as i64);
        let mut w = obs::lock(&self.writer);
        w.scratch.clear();
        let frame = codec::encode(&msg, &mut w.scratch);
        let WriteHalf { stream, scratch } = &mut *w;
        stream.write_all(scratch).map_err(|e| {
            if disconnect_kind(e.kind()) {
                TransportError::Disconnected { mid_frame: false }
            } else {
                TransportError::io("tcp send", &e)
            }
        })?;
        drop(w);
        obs::lock(&self.stats).record(class, logical, frame);
        Ok(())
    }

    fn recv(&self) -> Result<WireMsg, TransportError> {
        match self.recv_inner(None)? {
            Some(m) => Ok(m),
            // no deadline was armed, so the expiry path cannot be taken
            None => unreachable!("recv without timeout cannot expire"),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        self.recv_inner(Some(timeout))
    }

    fn stats(&self) -> WireStats {
        *obs::lock(&self.stats)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.close();
    }
}

/// Create a connected loopback pair: bind an ephemeral 127.0.0.1 listener,
/// connect, accept. The two endpoints are real kernel sockets — hand one to
/// a worker thread and keep the other on the leader.
pub fn pair() -> std::io::Result<(TcpTransport, TcpTransport)> {
    let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))?;
    let addr = listener.local_addr()?;
    let client = TcpStream::connect(addr)?;
    let (server, _) = listener.accept()?;
    Ok((TcpTransport::from_stream(server)?, TcpTransport::from_stream(client)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::CodecError;
    use crate::runtime::host::HostTensor;

    /// A (TcpTransport, raw TcpStream) pair for byte-level peer misbehavior.
    fn raw_pair() -> (TcpTransport, TcpStream) {
        let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (TcpTransport::from_stream(server).unwrap(), client)
    }

    #[test]
    fn roundtrip_over_real_socket() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![2, 2, 4], (0..16).map(|i| i as f32).collect());
        a.send(WireMsg::AttnOut { layer: 3, out: t.clone() }).unwrap();
        let got = b.recv().unwrap();
        assert_eq!(got, WireMsg::AttnOut { layer: 3, out: t });
    }

    #[test]
    fn bidirectional_and_ordered() {
        let (a, b) = pair().unwrap();
        for slot in 0..10u32 {
            a.send(WireMsg::Retire { slot }).unwrap();
        }
        b.send(WireMsg::KvStatsReq).unwrap();
        for slot in 0..10u32 {
            assert_eq!(b.recv().unwrap(), WireMsg::Retire { slot });
        }
        assert_eq!(a.recv().unwrap(), WireMsg::KvStatsReq);
    }

    #[test]
    fn recv_timeout_preserves_partial_then_completes() {
        let (a, b) = pair().unwrap();
        // idle link: timeout fires, nothing lost
        assert!(b.recv_timeout(Duration::from_millis(20)).unwrap().is_none());
        a.send(WireMsg::Shutdown).unwrap();
        let got = b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, Some(WireMsg::Shutdown));
    }

    #[test]
    fn threaded_echo() {
        let (a, b) = pair().unwrap();
        let h = std::thread::spawn(move || loop {
            let msg = b.recv().unwrap();
            if msg == WireMsg::Shutdown {
                return;
            }
            b.send(msg).unwrap();
        });
        let t = HostTensor::f32(vec![8, 64], vec![0.5; 512]);
        for layer in 0..4 {
            a.send(WireMsg::StepKv { layer, k: t.clone(), v: t.clone() }).unwrap();
            let got = a.recv().unwrap();
            assert_eq!(got, WireMsg::StepKv { layer, k: t.clone(), v: t.clone() });
        }
        a.send(WireMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn closed_peer_is_clean_boundary_disconnect() {
        let (a, b) = pair().unwrap();
        drop(b);
        assert_eq!(a.recv(), Err(TransportError::Disconnected { mid_frame: false }));
    }

    #[test]
    fn mid_frame_death_is_typed_as_such() {
        // The peer writes a frame *prefix* then dies: the unfinished bytes
        // in the parse buffer prove the stream was cut inside a frame.
        let (srv, mut raw) = raw_pair();
        let mut frame = Vec::new();
        codec::encode(&WireMsg::Retire { slot: 7 }, &mut frame);
        assert!(frame.len() > 4);
        raw.write_all(&frame[..frame.len() / 2]).unwrap();
        raw.flush().unwrap();
        drop(raw);
        assert_eq!(srv.recv(), Err(TransportError::Disconnected { mid_frame: true }));
    }

    #[test]
    fn garbage_bytes_are_a_codec_error() {
        let (srv, mut raw) = raw_pair();
        raw.write_all(&[0xde, 0xad, 0xbe, 0xef, 0x00, 0x01, 0x02, 0x03]).unwrap();
        raw.flush().unwrap();
        match srv.recv() {
            Err(TransportError::Codec(CodecError::BadMagic(_))) => {}
            other => panic!("expected BadMagic codec error, got {other:?}"),
        }
    }

    #[test]
    fn stats_count_measured_and_logical() {
        let (a, b) = pair().unwrap();
        let t = HostTensor::f32(vec![4, 2, 8], vec![1.0; 64]);
        let msg = WireMsg::AttnOut { layer: 0, out: t };
        let logical = msg.wire_bytes() as u64;
        a.send(msg).unwrap();
        b.recv().unwrap();
        for st in [a.stats(), b.stats()] {
            let c = st.class(MsgClass::AttnOut);
            assert_eq!(c.msgs, 1);
            assert_eq!(c.logical_bytes, logical);
            assert!(c.serialized_bytes > c.logical_bytes, "frame adds header overhead");
            assert!(st.overhead_ratio().unwrap() < 1.2, "overhead must be small");
        }
    }
}
