//! Measured-vs-logical wire accounting, broken down by message class.
//!
//! Every transport counts each [`WireMsg`] it moves into a [`WireStats`]
//! table under its [`MsgClass`], recording two byte counts side by side:
//!
//! * **logical** — [`WireMsg::wire_bytes`], the payload size the network
//!   *model* charges (tensor bytes + small control fields). This is what
//!   the serving simulator and the paced in-process link have always used.
//! * **serialized** — the bytes a codec frame actually occupies on a real
//!   socket (header + dtype/shape metadata + payload). Only serializing
//!   transports ([`crate::net::tcp::TcpTransport`]) fill this in; the
//!   in-process adapter leaves it at 0.
//!
//! Comparing the two per class validates the simulator's `wire_bytes()`
//! model against reality on every `--transport tcp` run: serialized must be
//! ≥ logical, with the overhead ratio bounded by the (small, fixed) framing
//! metadata — see `ServeMetrics::wire_stats` and the `net_e2e` tests.

use crate::workers::messages::WireMsg;

/// Coarse message classes for wire accounting (the tensor-bearing protocol
/// messages individually; the small KV-lifecycle/control messages pooled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgClass {
    StepQ,
    StepKv,
    Prefill,
    AttnOut,
    Control,
}

impl MsgClass {
    pub const COUNT: usize = 5;
    pub const ALL: [MsgClass; MsgClass::COUNT] = [
        MsgClass::StepQ,
        MsgClass::StepKv,
        MsgClass::Prefill,
        MsgClass::AttnOut,
        MsgClass::Control,
    ];

    pub fn of(msg: &WireMsg) -> MsgClass {
        match msg {
            WireMsg::StepQ { .. } => MsgClass::StepQ,
            WireMsg::StepKv { .. } => MsgClass::StepKv,
            WireMsg::PrefillChunk { .. } => MsgClass::Prefill,
            WireMsg::AttnOut { .. } => MsgClass::AttnOut,
            WireMsg::Retire { .. }
            | WireMsg::MapBlocks { .. }
            | WireMsg::KvStatsReq
            | WireMsg::KvStats { .. }
            | WireMsg::WorkerError { .. }
            | WireMsg::Hello { .. }
            | WireMsg::Welcome { .. }
            | WireMsg::Shutdown => MsgClass::Control,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MsgClass::StepQ => "step_q",
            MsgClass::StepKv => "step_kv",
            MsgClass::Prefill => "prefill",
            MsgClass::AttnOut => "attn_out",
            MsgClass::Control => "control",
        }
    }

    fn idx(self) -> usize {
        match self {
            MsgClass::StepQ => 0,
            MsgClass::StepKv => 1,
            MsgClass::Prefill => 2,
            MsgClass::AttnOut => 3,
            MsgClass::Control => 4,
        }
    }
}

/// Counters for one message class.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassStats {
    pub msgs: u64,
    /// Modelled payload bytes (`WireMsg::wire_bytes`).
    pub logical_bytes: u64,
    /// Actual serialized frame bytes (0 on non-serializing transports).
    pub serialized_bytes: u64,
}

impl ClassStats {
    fn accumulate(&mut self, other: &ClassStats) {
        self.msgs += other.msgs;
        self.logical_bytes += other.logical_bytes;
        self.serialized_bytes += other.serialized_bytes;
    }
}

/// Per-class wire traffic through one transport endpoint (both directions:
/// an endpoint counts every message it sends *and* receives, so the leader
/// side of a link sees the link's full traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireStats {
    classes: [ClassStats; MsgClass::COUNT],
}

impl Default for WireStats {
    fn default() -> Self {
        WireStats { classes: [ClassStats::default(); MsgClass::COUNT] }
    }
}

impl WireStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one message of `class`.
    pub fn record(&mut self, class: MsgClass, logical_bytes: usize, serialized_bytes: usize) {
        let c = &mut self.classes[class.idx()];
        c.msgs += 1;
        c.logical_bytes += logical_bytes as u64;
        c.serialized_bytes += serialized_bytes as u64;
    }

    pub fn class(&self, class: MsgClass) -> ClassStats {
        self.classes[class.idx()]
    }

    /// Sum another endpoint's counters into this one (pool aggregation).
    pub fn merge(&mut self, other: &WireStats) {
        for c in MsgClass::ALL {
            self.classes[c.idx()].accumulate(&other.classes[c.idx()]);
        }
    }

    /// Traffic counted since `baseline` was snapshotted (counters are
    /// monotonic, so per-class saturating subtraction is exact). Lets a
    /// serve session report *its own* traffic even though transport
    /// endpoints count from pipeline start.
    pub fn delta_since(&self, baseline: &WireStats) -> WireStats {
        let mut out = WireStats::new();
        for c in MsgClass::ALL {
            let now = self.classes[c.idx()];
            let base = baseline.classes[c.idx()];
            out.classes[c.idx()] = ClassStats {
                msgs: now.msgs.saturating_sub(base.msgs),
                logical_bytes: now.logical_bytes.saturating_sub(base.logical_bytes),
                serialized_bytes: now.serialized_bytes.saturating_sub(base.serialized_bytes),
            };
        }
        out
    }

    /// `(class, counters)` for every class (including empty ones).
    pub fn iter(&self) -> impl Iterator<Item = (MsgClass, ClassStats)> + '_ {
        MsgClass::ALL.iter().map(move |&c| (c, self.classes[c.idx()]))
    }

    /// Totals across classes.
    pub fn total(&self) -> ClassStats {
        let mut t = ClassStats::default();
        for c in &self.classes {
            t.accumulate(c);
        }
        t
    }

    /// serialized / logical across all traffic, when both were measured.
    /// `None` until a serializing transport recorded something.
    pub fn overhead_ratio(&self) -> Option<f64> {
        let t = self.total();
        if t.serialized_bytes == 0 || t.logical_bytes == 0 {
            None
        } else {
            Some(t.serialized_bytes as f64 / t.logical_bytes as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::host::HostTensor;

    #[test]
    fn classes_cover_every_variant() {
        let t = HostTensor::zeros_f32(vec![1, 1, 4]);
        assert_eq!(
            MsgClass::of(&WireMsg::StepQ {
                layer: 0,
                slots: vec![0],
                q: t.clone(),
                lens: vec![0],
                seq_bucket: 8,
                overlap: false,
            }),
            MsgClass::StepQ
        );
        assert_eq!(
            MsgClass::of(&WireMsg::StepKv { layer: 0, k: t.clone(), v: t.clone() }),
            MsgClass::StepKv
        );
        assert_eq!(MsgClass::of(&WireMsg::Retire { slot: 1 }), MsgClass::Control);
        assert_eq!(MsgClass::of(&WireMsg::Shutdown), MsgClass::Control);
        assert_eq!(
            MsgClass::of(&WireMsg::AttnOut { layer: 0, out: t }),
            MsgClass::AttnOut
        );
    }

    #[test]
    fn record_merge_total() {
        let mut a = WireStats::new();
        a.record(MsgClass::StepQ, 100, 120);
        a.record(MsgClass::StepQ, 100, 120);
        a.record(MsgClass::Control, 0, 12);
        let mut b = WireStats::new();
        b.record(MsgClass::AttnOut, 50, 0);
        a.merge(&b);

        assert_eq!(a.class(MsgClass::StepQ).msgs, 2);
        assert_eq!(a.class(MsgClass::StepQ).logical_bytes, 200);
        assert_eq!(a.class(MsgClass::StepQ).serialized_bytes, 240);
        let t = a.total();
        assert_eq!(t.msgs, 4);
        assert_eq!(t.logical_bytes, 250);
        assert_eq!(t.serialized_bytes, 252);
        assert!((a.overhead_ratio().unwrap() - 252.0 / 250.0).abs() < 1e-12);
        assert_eq!(WireStats::new().overhead_ratio(), None);
    }

    #[test]
    fn delta_since_isolates_new_traffic() {
        let mut w = WireStats::new();
        w.record(MsgClass::StepQ, 100, 120);
        let baseline = w;
        w.record(MsgClass::StepQ, 100, 120);
        w.record(MsgClass::AttnOut, 50, 60);
        let d = w.delta_since(&baseline);
        assert_eq!(d.class(MsgClass::StepQ).msgs, 1);
        assert_eq!(d.class(MsgClass::StepQ).logical_bytes, 100);
        assert_eq!(d.class(MsgClass::AttnOut).serialized_bytes, 60);
        assert_eq!(w.delta_since(&w).total().msgs, 0);
    }
}
