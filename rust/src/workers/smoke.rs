//! Artifact-free trace smoke session: a scripted leader driving ONE real
//! attention worker (`run_attn_worker`, native backend, in-process
//! transport), instrumented with the same obs span vocabulary the real
//! pipeline emits.
//!
//! Purpose: CI and the `lamina trace-smoke` subcommand need a serve-shaped
//! session that produces a full leader + wire + worker + kernel span tree
//! **without PJRT artifacts** (the real leader needs `make artifacts`).
//! The worker and kernel spans here are genuine — they come from the
//! instrumentation inside `attn_worker` and `NativeBackend`, running on a
//! real paged-KV arena — only the leader's model slices are scripted
//! (synthetic Q/K/V instead of PJRT outputs).
//!
//! `kill_worker_mid` poisons the protocol halfway through (a `StepKv`
//! with no preceding `StepQ`), making the worker loop error out and die
//! mid-session — the drop-safety contract says its open spans still close
//! via `Drop` and the exported trace stays well-formed.

use crate::kernels::AttnBackendKind;
use crate::kvcache::KvDtype;
use crate::net::{inproc, Transport};
use crate::netsim::stack::{FHBN, LINE_RATE_400G};
use crate::obs::{self, ArgVal};
use crate::runtime::host::HostTensor;

use super::messages::WireMsg;
use super::{run_attn_worker, AttnWorkerCfg, ModelGeom, PAD_SLOT};

/// What the scripted session did (the trace itself lives in `obs::trace`;
/// callers `start()` before and `stop()` after).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SmokeReport {
    /// Completed decode iterations (each spans both layers).
    pub decode_steps: usize,
    /// Attention replies received (prefill + decode).
    pub replies: usize,
    /// The worker died mid-session (only with `kill_worker_mid`).
    pub worker_died: bool,
}

fn tensor(shape: &[usize], salt: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::f32(
        shape.to_vec(),
        (0..n).map(|i| salt + (i as f32) * 0.125 - (i % 7) as f32).collect(),
    )
}

/// Geometry of the smoke model (small enough to run anywhere, big enough
/// that every wire/kernel path sees real work).
const LAYERS: usize = 2;
const SEQ_BUCKET: usize = 64;

/// Run the scripted session: one chunked-prefill pass on slot 0, then
/// `steps` decode iterations over a padded 3-row batch, then shutdown.
/// With `kill_worker_mid` the protocol is poisoned halfway instead and the
/// session reports a dead worker rather than erroring.
pub fn run_trace_smoke(steps: usize, kill_worker_mid: bool) -> Result<SmokeReport, String> {
    // context = 3 prefill tokens + one appended token per step; keep it
    // inside the smoke arena's max_seq
    let steps = steps.min(SEQ_BUCKET - 4);
    let (leader, worker) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
    let cfg = AttnWorkerCfg {
        // deliberately nonexistent: the native backend must not need it
        artifacts_dir: std::path::PathBuf::from("artifacts-not-needed"),
        shard: 0,
        n_shards: 1,
        slots: 4,
        kv_block_size: 4,
        kv_dtype: KvDtype::F32,
        backend: AttnBackendKind::Native,
        geom: Some(ModelGeom {
            layers: LAYERS,
            kv_heads: 4,
            head_dim: 16,
            max_seq: SEQ_BUCKET,
        }),
        trust_welcome: false,
    };
    let h = std::thread::spawn(move || run_attn_worker(cfg, worker));

    // membership handshake: the worker's first frame is its Hello, and
    // the data plane only opens after our Welcome (the worker builds its
    // arena from the negotiated geometry)
    {
        let _sp = obs::span("leader", "handshake").arg("epoch", 1);
        match leader.recv()? {
            WireMsg::Hello { codec_version, .. }
                if codec_version == crate::net::codec::FORMAT_VERSION as u32 => {}
            other => return Err(format!("expected Hello, got {other:?}")),
        }
        leader.send(WireMsg::Welcome {
            epoch: 1,
            kv_start: 0,
            kv_count: 4,
            slots: 4,
            kv_block_size: 4,
            layers: LAYERS as u32,
            head_dim: 16,
            max_seq: SEQ_BUCKET as u32,
        })?;
    }

    let mut replies = 0usize;
    let mut worker_died = false;

    let recv_reply = |layer: usize| -> Result<Option<WireMsg>, String> {
        let _sp = obs::span("wire", "recv_attn").arg("layer", layer as i64);
        match leader.recv()? {
            WireMsg::AttnOut { .. } => Ok(None),
            WireMsg::WorkerError { msg } => Ok(Some(WireMsg::WorkerError { msg })),
            other => Err(format!("unexpected reply {other:?}")),
        }
    };

    // one chunked-prefill pass on slot 0 (3 tokens, every layer)
    {
        let _sp = obs::span("leader", "prefill-chunk")
            .arg("slot", 0)
            .arg("cached", 0)
            .arg("valid", 3);
        for layer in 0..LAYERS {
            let salt = 50.0 + layer as f32;
            {
                let _sp = obs::span("wire", "send_prefill").arg("layer", layer as i64).arg("slot", 0);
                leader.send(WireMsg::PrefillChunk {
                    layer,
                    slot: 0,
                    q: tensor(&[3, 8, 16], salt),
                    k: tensor(&[3, 4, 16], salt + 0.25),
                    v: tensor(&[3, 4, 16], salt - 0.25),
                    cached: 0,
                    valid: 3,
                    seq_bucket: SEQ_BUCKET,
                })?;
            }
            if let Some(WireMsg::WorkerError { msg }) = recv_reply(layer)? {
                return Err(format!("worker during prefill: {msg}"));
            }
            replies += 1;
        }
    }

    // decode iterations over a padded batch: slot 0 continues its context,
    // slots 1 and 3 decode from empty, row 2 is padding
    let mut lens = [3i32, 0, 0];
    let mut decode_steps = 0usize;
    'steps: for step in 0..steps {
        let kill_now = kill_worker_mid && step == steps / 2;
        let slots = vec![0u32, 1, PAD_SLOT, 3];
        let lens_v = vec![lens[0], lens[1], 0, lens[2]];
        let _sp_step = obs::span("leader", "decode-step")
            .arg("rows", 3)
            .arg("bucket", 4)
            .arg("seq_bucket", SEQ_BUCKET as i64);
        if obs::trace::enabled() {
            obs::instant(
                "leader",
                "step-trace",
                vec![
                    ("reqs", ArgVal::S(format!("{:?}", [0u64, 1, 2]))),
                    ("slots", ArgVal::S(format!("{slots:?}"))),
                    ("lens", ArgVal::S(format!("{lens_v:?}"))),
                    ("bucket", ArgVal::I(4)),
                    ("seq_bucket", ArgVal::I(SEQ_BUCKET as i64)),
                ],
            );
        }
        for layer in 0..LAYERS {
            let salt = 7.0 + step as f32 * 3.0 + layer as f32;
            if kill_now && layer == 1 {
                // poison the protocol: StepKv without StepQ errors the
                // worker loop out mid-session
                let _sp = obs::span("wire", "send_kv").arg("layer", layer as i64);
                leader.send(WireMsg::StepKv {
                    layer,
                    k: tensor(&[4, 4, 16], salt + 0.5),
                    v: tensor(&[4, 4, 16], salt - 0.5),
                })?;
                drop(_sp);
                match recv_reply(layer)? {
                    Some(WireMsg::WorkerError { .. }) => {
                        worker_died = true;
                        break 'steps;
                    }
                    _ => return Err("poisoned worker must report an error".into()),
                }
            }
            {
                let _sp = obs::span("wire", "send_q").arg("layer", layer as i64);
                leader.send(WireMsg::StepQ {
                    layer,
                    slots: slots.clone(),
                    q: tensor(&[4, 8, 16], salt),
                    lens: lens_v.clone(),
                    seq_bucket: SEQ_BUCKET,
                    overlap: false,
                })?;
            }
            {
                let _sp = obs::span("wire", "send_kv").arg("layer", layer as i64);
                leader.send(WireMsg::StepKv {
                    layer,
                    k: tensor(&[4, 4, 16], salt + 0.5),
                    v: tensor(&[4, 4, 16], salt - 0.5),
                })?;
            }
            if let Some(WireMsg::WorkerError { msg }) = recv_reply(layer)? {
                return Err(format!("worker during decode: {msg}"));
            }
            replies += 1;
        }
        decode_steps += 1;
        for l in lens.iter_mut() {
            *l += 1;
        }
    }

    if !worker_died {
        let _sp = obs::span("wire", "retire").arg("slot", 0);
        leader.send(WireMsg::Retire { slot: 0 })?;
        drop(_sp);
        leader.send(WireMsg::Shutdown)?;
    }
    let _ = h.join();
    Ok(SmokeReport { decode_steps, replies, worker_died })
}
